"""Fig. 9 (W_A): interactive-only workload, arrival-rate sweep for the
small/large/mixed model configurations; SLO attainment + per-instance
throughput for Chiron vs Llumnix (untuned + tuned)."""
from benchmarks.common import Row, chiron, llumnix, llumnix_tuned, run_sim
from repro.sim.workload import WorkloadSpec

RATES = {"llama-8b": (40.0, 120.0, 240.0), "llama-70b": (10.0, 25.0, 50.0)}
N_REQ = 1200


def _spec(model, rate, seed=0):
    return WorkloadSpec(n_requests=N_REQ, arrival_rate=rate,
                        interactive_frac=1.0, model=model, seed=seed)


def run():
    rows = []
    for model, rates in RATES.items():
        for rate in rates:
            spec = _spec(model, rate)
            ctrls = {
                "chiron": chiron(model),
                "llumnix": llumnix(model),
                "llumnix_tuned": llumnix_tuned(_spec(model, rate, seed=1),
                                               model),
            }
            for name, ctrl in ctrls.items():
                res, wall = run_sim(spec, ctrl, max_time=900)
                rows.append(Row(
                    f"fig9/{model}/rate{rate:g}/{name}", wall * 1e6,
                    slo_pct=round(100 * res.slo_attainment(), 1),
                    per_inst_tok_s=round(res.per_instance_throughput()),
                    peak_chips=res.peak_chips,
                    gpu_hours=round(res.gpu_hours(), 3)))
    return rows
