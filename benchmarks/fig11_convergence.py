"""Fig. 11 + Fig. 12: local-autoscaler batch-size convergence across
serving-optimization configurations, and convergence time (8B vs 70B).

The update interval is the instance's own step time (observe-and-adapt
cadence), so the 70B converges ~slower in wall time than the 8B exactly as
the paper reports."""
import time

from benchmarks.common import Row
from repro.core.backpressure import LocalMetrics
from repro.core.local_autoscaler import LocalAutoscaler
from repro.sim.perf_model import PerfModel

CONFIGS = {
    "baseline": dict(),
    "prefix_caching": dict(prefix_caching=True),
    "spec_decode": dict(speculative_decoding=True),
    "both": dict(prefix_caching=True, speculative_decoding=True),
}


def _converge(pm: PerfModel, itl_slo: float, max_updates=200):
    s = LocalAutoscaler(itl_slo=itl_slo, init_batch=8, max_batch=4096)
    wall = 0.0
    conv_t = None
    for i in range(max_updates):
        b = s.max_batch_size
        itl = pm.itl(b, 1024.0)
        wall += max(itl, 1e-3) * 10       # update every ~10 decode steps
        s.update(LocalMetrics(itl, pm.throughput(b, 1024.0), itl_slo))
        if conv_t is None and s.converged(window=8, tol=0.15):
            conv_t = wall
    tail = s.history[-8:]
    return sum(tail) / len(tail), conv_t or wall


def run():
    rows = []
    for model in ("llama-8b", "llama-70b"):
        for cfg_name, kw in CONFIGS.items():
            pm = PerfModel(model, **kw)
            t0 = time.perf_counter()
            final_b, conv_t = _converge(pm, itl_slo=0.2)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(Row(f"fig11/{model}/{cfg_name}", us,
                            converged_batch=round(final_b),
                            convergence_s=round(conv_t, 1)))
    # fig12 headline: convergence time ratio 70B/8B
    b8 = PerfModel("llama-8b")
    b70 = PerfModel("llama-70b")
    _, t8 = _converge(b8, 0.2)
    _, t70 = _converge(b70, 0.2)
    rows.append(Row("fig12/convergence_ratio", 0.0,
                    t_8b_s=round(t8, 1), t_70b_s=round(t70, 1),
                    ratio=round(t70 / max(t8, 1e-9), 2)))
    return rows
