"""Kernel micro-benchmarks: wall time per call of the jitted ref backend on
CPU (the TPU kernels are dry-run-only here), plus FLOP-derived intensity."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.kernels import ops


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    B, n_kv, group, D, page, mp = 8, 8, 4, 128, 16, 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, n_kv, group, D), jnp.float32)
    kp = jax.random.normal(ks[1], (512, page, n_kv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (512, page, n_kv, D), jnp.float32)
    bt = jax.random.randint(ks[3], (B, mp), 0, 512, dtype=jnp.int32)
    ln = jnp.full((B,), mp * page, jnp.int32)
    us = _time(ops.paged_attention, q, kp, vp, bt, ln, backend="ref")
    flops = 2 * 2 * B * n_kv * group * D * mp * page
    rows.append(Row("kernels/paged_attention_ref", us,
                    gflops=round(flops / 1e9, 2),
                    seq=mp * page))

    S = 2048
    q2 = jax.random.normal(ks[0], (1, 8, S, 128), jnp.float32)
    k2 = jax.random.normal(ks[1], (1, 2, S, 128), jnp.float32)
    v2 = jax.random.normal(ks[2], (1, 2, S, 128), jnp.float32)
    us = _time(ops.flash_prefill, q2, k2, v2, backend="ref")
    rows.append(Row("kernels/flash_prefill_ref", us,
                    gflops=round(2 * 2 * 8 * S * S * 128 / 2 / 1e9, 2)))

    b, s, h, p, n = 2, 2048, 16, 64, 64
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[0], (b, s, n))
    us = _time(ops.ssd_scan, x, dt, A, Bm, Cm, chunk=128, backend="ref")
    rows.append(Row("kernels/ssd_scan_ref", us, seq=s, heads=h))
    return rows
