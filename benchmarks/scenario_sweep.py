"""Scenario sweep + simulator-engine benchmark.

Three sections:

- ``scenario/<name>``: every registered scenario (repro.sim.scenarios)
  built columnar (``build_trace``) and run end-to-end on the event-driven
  core with the Chiron controller (multi-model and failure-injection
  scenarios pass their extra sim_kwargs through). Per-scenario results —
  events/s, wall time, SLO attainment, per-model SLOs — are also written
  machine-readable to ``BENCH_scenarios.json`` at the repo root so the
  perf trajectory is tracked across PRs.
- ``fig19_equiv``: the fig19_timeline workload run on both engines; the
  instance-count timelines must agree within one control interval
  (``decisions_match``).
- ``speedup``: a 100k-request bursty trace (batch backlog + interactive
  burst spikes) on (a) the event core, (b) the tuned fixed-tick loop at
  dt=0.25 (post-PR data plane), and (c) the seed's fixed-tick loop whose
  batch queue re-sorts on every service pass — the O(n^2 log n) drain the
  event core replaces. (c) runs under a wall-clock budget and is reported
  as a lower bound when it exceeds it; a small-n curve shows its
  superlinear growth.

Env knobs: ``SCENARIO_SWEEP_N`` (speedup trace size, default 100000),
``SCENARIO_SWEEP_LEGACY_BUDGET`` (seconds, default 120),
``SCENARIO_SWEEP_REPEATS`` (best-of-k scenario timing, default 3),
``SCENARIO_SWEEP_MILLION=0`` (skip the million-request replay row),
``SCENARIO_SWEEP_MILLION_N`` (its request count, default 1000000).
"""
from __future__ import annotations

import json
import os
import signal
import time
from collections import deque
from typing import List, Optional

from benchmarks.common import MAX_CHIPS, Row, chiron
from repro.serving.request import Request, RequestState, RequestType
from repro.sim.cluster import SimCluster
from repro.sim.metrics import decisions_match
from repro.sim.scenarios import SCENARIOS, build, build_trace
from repro.sim.simulator import (default_perf_factory, simulate_events,
                                 simulate_fixed_tick, simulate_fleet)
from repro.sim.workload import WorkloadSpec, generate


class SeedFcfsQueue:
    """The seed's global queue, reconstructed for the baseline row: the
    batch side is a plain list that is re-sorted whenever the head is
    served in deadline/FCFS order (one sort per routing pass, exactly the
    scaling bug the heap queue fixes). No listener API, so the batch
    autoscaler falls back to re-clustering a snapshot every control tick
    (the pre-incremental behaviour)."""

    _MODEL = "llama-8b"              # the seed queue was single-model

    def __init__(self):
        self.interactive = deque()
        self._list: List[Request] = []
        self._sorted = False

    def push(self, req: Request) -> None:
        if req.request_type == RequestType.INTERACTIVE:
            self.interactive.append(req)
        else:
            self._list.append(req)
            self._sorted = False

    def requeue(self, req: Request) -> None:
        if req.request_type == RequestType.INTERACTIVE:
            self.interactive.appendleft(req)
        else:
            self._list.append(req)
            self._sorted = False

    # --- model-keyed protocol (single lane): routing asks per model now
    def interactive_models(self) -> List[str]:
        return [self._MODEL] if self.interactive else []

    def batch_models(self) -> List[str]:
        return [self._MODEL] if self._list else []

    def n_interactive_for(self, model=None) -> int:
        return len(self.interactive)

    def n_batch_for(self, model=None) -> int:
        return len(self._list)

    def peek_interactive(self, model=None) -> Optional[Request]:
        return self.interactive[0] if self.interactive else None

    def pop_interactive(self, model=None) -> Optional[Request]:
        return self.interactive.popleft() if self.interactive else None

    def _sort(self) -> None:
        self._list.sort(key=lambda r: (r.saved_kv is None, r.deadline,
                                       r.arrival_time))

    def peek_batch(self, model=None) -> Optional[Request]:
        if not self._list:
            return None
        if not self._sorted:           # one sort per routing pass
            self._sort()
            self._sorted = True
        return self._list[0]

    def pop_batch_fcfs(self, model=None) -> Optional[Request]:
        """Seed semantics: the whole list re-sorts on every pop."""
        if not self._list:
            return None
        self._sort()
        return self._list.pop(0)

    def iter_batch(self, model=None):
        return iter(self._list)

    @property
    def n_interactive(self) -> int:
        return len(self.interactive)

    @property
    def n_batch(self) -> int:
        return len(self._list)

    def __len__(self) -> int:
        return self.n_interactive + self.n_batch


class _Budget(Exception):
    pass


def _run_budgeted(fn, budget_s: float):
    """Run fn() under SIGALRM; returns (result, wall) or (None, budget)."""
    def _raise(signum, frame):
        raise _Budget()
    old = signal.signal(signal.SIGALRM, _raise)
    signal.alarm(int(budget_s))
    t0 = time.perf_counter()
    try:
        out = fn()
        return out, time.perf_counter() - t0
    except _Budget:
        return None, budget_s
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _speedup_trace(n: int, seed: int = 1):
    """Bursty 100k-class trace: a deadline-driven batch backlog (the
    ~2000+-queued regime where the paper's estimator sharpens, Fig. 14)
    under an interactive stream arriving in spikes. Columnar end to end —
    the event core materializes requests lazily."""
    from repro.sim.workload import Trace
    n_backlog = int(n * 0.9)
    backlog, _ = build_trace("backlog_drain", n_requests=n_backlog,
                             seed=seed, backlog_frac=1.0,
                             batch_ttft_slo=2400.0)
    bursts, kw = build_trace("burst_spikes", n_requests=n - n_backlog,
                             seed=seed + 1, n_bursts=6, burst_rate=120.0,
                             gap=300.0, interactive_frac=1.0)
    trace = Trace.concat([backlog, bursts]).sorted_by_arrival()
    return trace, max(kw["max_time"], 3000.0)


def _finish_stats(res, reqs):
    done = sum(r.state == RequestState.FINISHED for r in reqs)
    return dict(finished=done, slo=round(res.slo_attainment(), 3),
                gpu_hours=round(res.gpu_hours(), 2))


def run():
    rows = []
    json_rows = []

    # ---- scenario library on the event core (columnar build); fleet
    # scenarios run their Fleet through the multi-cluster loop instead.
    # Runs are deterministic, so each scenario repeats and keeps the
    # fastest wall time — events/s feeds the cross-PR trend gate
    # (scripts/bench_trend.py) and must not encode background load.
    repeats = int(os.environ.get("SCENARIO_SWEEP_REPEATS", "3"))
    for name, sc in sorted(SCENARIOS.items()):
        wall = float("inf")
        for _ in range(max(repeats, 1)):
            trace, kw = build_trace(name, seed=3)
            t0 = time.perf_counter()
            if "fleet" in kw:
                res = simulate_fleet(trace, kw["fleet"](),
                                     max_time=kw["max_time"], warm_start=1,
                                     failures=kw.get("failures"),
                                     degradations=kw.get("degradations"),
                                     outages=kw.get("outages"),
                                     flash_crowds=kw.get("flash_crowds"),
                                     detector=kw.get("detector"),
                                     overload=kw.get("overload"))
            else:
                # overload scenarios cap the cluster (sustained saturation
                # is the point); everything else gets the full budget
                cluster = SimCluster(default_perf_factory(),
                                     max_chips=kw.get("max_chips",
                                                      MAX_CHIPS))
                ctrl = chiron(models=kw["models"]) if "models" in kw \
                    else chiron()
                res = simulate_events(trace, ctrl, cluster,
                                      max_time=kw["max_time"], warm_start=2,
                                      failures=kw.get("failures"),
                                      degradations=kw.get("degradations"),
                                      outages=kw.get("outages"),
                                      flash_crowds=kw.get("flash_crowds"),
                                      detector=kw.get("detector"),
                                      overload=kw.get("overload"))
            wall = min(wall, time.perf_counter() - t0)
        extra = {}
        recov = res.recovery_metrics()
        if recov:
            extra["ttr_s"] = round(recov[0]["time_to_recover_s"], 1)
            extra["dip"] = round(recov[0]["max_attainment_dip"], 3)
        if res.failures:
            extra["failures"] = res.failures
        if res.degradations:
            extra["degradations"] = res.degradations
        if res.clusters:
            extra["migrations"] = res.migrations
            extra["egress_gb"] = round(res.egress_bytes / 1e9, 4)
            extra["batch_shares"] = "|".join(
                f"{c.name}={c.served_batch}" for c in res.clusters)
        rows.append(Row(f"scenario/{name}", wall * 1e6,
                        n=trace.n, dur_s=round(res.duration),
                        peak_chips=res.peak_chips,
                        hysteresis=round(res.hysteresis, 2),
                        events_per_s=round(res.n_events / max(wall, 1e-9)),
                        **extra, **_finish_stats(res, res.requests)))
        jrow = {
            "scenario": name, "n_requests": trace.n,
            "wall_s": round(wall, 3),
            "events": res.n_events,
            "events_per_s": round(res.n_events / max(wall, 1e-9), 1),
            "sim_duration_s": round(res.duration, 1),
            "slo_attainment": round(res.slo_attainment(), 4),
            "slo_by_model": {m: round(v, 4)
                             for m, v in res.slo_by_model().items()},
            "completion_rate": round(res.completion_rate(), 4),
            "goodput": round(res.goodput(), 4),
            "goodput_interactive": round(
                res.goodput(RequestType.INTERACTIVE), 4),
            "gpu_hours": round(res.gpu_hours(), 3),
            "peak_chips": res.peak_chips,
            "hysteresis": round(res.hysteresis, 3),
            "failures": res.failures,
            "degradations": res.degradations,
        }
        jrow.update({k: round(v, 4)
                     for k, v in res.outcome_rates().items()})
        if recov:
            # chaos scenarios: first-shock recovery scorecard feeds the
            # bench_trend gate (time-to-recover regressions fail)
            sh = recov[0]
            jrow["skipped_injections"] = res.skipped_injections
            jrow["time_to_detect_s"] = round(sh["time_to_detect_s"], 2)
            jrow["time_to_recover_s"] = round(sh["time_to_recover_s"], 2)
            jrow["max_attainment_dip"] = round(sh["max_attainment_dip"], 4)
            jrow["window_attainment"] = round(sh["window_attainment"], 4)
            jrow["window_by_tenant"] = {
                t: round(v, 4) for t, v in sh["window_by_tenant"].items()}
        if res.clusters:
            jrow["migrations"] = res.migrations
            jrow["handbacks"] = res.handbacks
            jrow["egress_gb"] = round(res.egress_bytes / 1e9, 5)
            jrow["egress_cost_usd"] = round(res.egress_cost_usd, 5)
            jrow["fleet_cost_usd"] = round(
                sum(c.cost_usd() for c in res.clusters), 3)
            jrow["clusters"] = {
                c.name: {"region": c.region,
                         "accelerator": c.accelerator,
                         "gpu_hours": round(c.gpu_hours(), 3),
                         "peak_chips": c.peak_chips,
                         "served_interactive": c.served_interactive,
                         "served_batch": c.served_batch,
                         "slo_interactive": round(c.slo_interactive(), 4),
                         "remote_served": c.remote_served}
                for c in res.clusters}
        json_rows.append(jrow)

    # ---- flight-recorder overhead: diurnal with telemetry on vs off.
    # The committed ``telemetry_overhead_frac`` is the PR 8 acceptance
    # number (events/s within 5% of telemetry-off) and bench_trend
    # schema-checks both telemetry fields. Estimator: the second of two
    # back-to-back runs measures a few percent slower than the first
    # regardless of configuration (turbo/cache decay — an off-vs-off
    # control reproduces it), so per-pair ratios and best-of-k are both
    # biased; instead alternate the arm order every pair (each arm gets
    # equal first/second draws) and take the ratio of per-arm *medians*,
    # which cancels the position bias and is robust to container noise.
    walls = {True: [], False: []}
    for i in range(max(repeats, 10)):
        for tel in ((True, False) if i % 2 == 0 else (False, True)):
            trace, kw = build_trace("diurnal", seed=3)
            cluster = SimCluster(default_perf_factory(),
                                 max_chips=MAX_CHIPS)
            t0 = time.perf_counter()
            res = simulate_events(trace, chiron(), cluster,
                                  max_time=kw["max_time"], warm_start=2,
                                  telemetry=tel)
            w = time.perf_counter() - t0
            walls[tel].append(w)
            if tel:
                res_on = res
    wall_on = sorted(walls[True])[len(walls[True]) // 2]
    wall_off = sorted(walls[False])[len(walls[False]) // 2]
    overhead = wall_on / wall_off - 1.0
    rec = res_on.telemetry
    rows.append(Row("scenario/diurnal_telemetry", wall_on * 1e6,
                    n=trace.n,
                    events_per_s=round(res_on.n_events / wall_on),
                    overhead=f"{overhead:+.1%}",
                    decisions=rec.decisions.n, spans=rec.spans.n,
                    **_finish_stats(res_on, res_on.requests)))
    json_rows.append({
        "scenario": "diurnal_telemetry", "n_requests": trace.n,
        "wall_s": round(wall_on, 3),
        "events": res_on.n_events,
        "events_per_s": round(res_on.n_events / max(wall_off, 1e-9), 1),
        "telemetry_events_per_s": round(
            res_on.n_events / max(wall_on, 1e-9), 1),
        "telemetry_overhead_frac": round(overhead, 4),
        "sim_duration_s": round(res_on.duration, 1),
        "slo_attainment": round(res_on.slo_attainment(), 4),
        "completion_rate": round(res_on.completion_rate(), 4),
        "decision_rows": rec.decisions.n,
        "signal_rows": rec.signals.n,
        "cluster_tick_rows": rec.cticks.n,
        "span_rows": rec.spans.n,
    })

    # ---- million-request replay: the scale point the columnar hot path
    # is sized for, in the committed baseline so bench_trend's wall-clock
    # gate tracks it across PRs. One run (no best-of: it is long);
    # SCENARIO_SWEEP_MILLION=0 opts out for quick local sweeps.
    if os.environ.get("SCENARIO_SWEEP_MILLION", "1") != "0":
        n_m = int(os.environ.get("SCENARIO_SWEEP_MILLION_N", "1000000"))
        trace, kw = build_trace("trace_replay", n_requests=n_m, seed=3)
        cluster = SimCluster(default_perf_factory(), max_chips=MAX_CHIPS)
        t0 = time.perf_counter()
        res = simulate_events(trace, chiron(), cluster,
                              max_time=kw["max_time"], warm_start=2)
        wall = time.perf_counter() - t0
        rows.append(Row("scenario/million_replay", wall * 1e6, n=trace.n,
                        wall_s=round(wall, 2),
                        events_per_s=round(res.n_events / wall),
                        **_finish_stats(res, res.requests)))
        json_rows.append({
            "scenario": "million_replay", "n_requests": trace.n,
            "wall_s": round(wall, 3),
            "events": res.n_events,
            "events_per_s": round(res.n_events / wall, 1),
            "sim_duration_s": round(res.duration, 1),
            "slo_attainment": round(res.slo_attainment(), 4),
            "slo_by_model": {m: round(v, 4)
                             for m, v in res.slo_by_model().items()},
            "completion_rate": round(res.completion_rate(), 4),
            "gpu_hours": round(res.gpu_hours(), 3),
            "peak_chips": res.peak_chips,
            "hysteresis": round(res.hysteresis, 3),
            "failures": res.failures,
            "degradations": res.degradations,
        })

    # machine-readable perf trajectory (tracked across PRs)
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_scenarios.json")
    with open(out_path, "w") as f:
        json.dump({"scenarios": json_rows}, f, indent=1, sort_keys=True)

    # ---- fig19 workload: event vs fixed-tick decision equivalence.
    # The event engine runs in sparse fixed-tick mode (quantize=dt) so both
    # engines batch arrivals/completions on the same grid.
    spec = WorkloadSpec(n_requests=2000, arrival_rate=30.0,
                        interactive_frac=1.0, batch_queue_size=30000,
                        batch_ttft_slo=1800.0, model="llama-8b", seed=5)
    res_e = simulate_events(generate(spec),
                            chiron(), SimCluster(default_perf_factory(),
                                                 max_chips=MAX_CHIPS),
                            max_time=2400, warm_start=2, quantize=0.25)
    res_f = simulate_fixed_tick(generate(spec),
                                chiron(), SimCluster(default_perf_factory(),
                                                     max_chips=MAX_CHIPS),
                                dt=0.25, max_time=2400, warm_start=2)
    frac, dev = decisions_match(res_e, res_f, interval=1.0,
                                slack_intervals=1)
    rows.append(Row("fig19_equiv/full_chiron", 0.0,
                    match_frac=round(frac, 4), max_count_dev=dev,
                    scale_actions_event=res_e.scale_ups + res_e.scale_downs,
                    scale_actions_fixed=res_f.scale_ups + res_f.scale_downs,
                    gpu_h_event=round(res_e.gpu_hours(), 2),
                    gpu_h_fixed=round(res_f.gpu_hours(), 2)))

    # batch-autoscaler-driven arm (Algorithm 2 decides instance counts;
    # no knife-edge local/TBP feedback amplifying data-plane noise): the
    # instance-count timelines must be identical within one interval
    spec_b = WorkloadSpec(n_requests=1, arrival_rate=1.0,
                          interactive_frac=0.0, batch_queue_size=30000,
                          batch_ttft_slo=1800.0, model="llama-8b", seed=5)

    def ctrl_b():
        return chiron(local_enabled=False, static_batch=64)
    res_e = simulate_events(generate(spec_b), ctrl_b(),
                            SimCluster(default_perf_factory(),
                                       max_chips=MAX_CHIPS),
                            max_time=2400, quantize=0.25)
    res_f = simulate_fixed_tick(generate(spec_b), ctrl_b(),
                                SimCluster(default_perf_factory(),
                                           max_chips=MAX_CHIPS),
                                dt=0.25, max_time=2400)
    frac, dev = decisions_match(res_e, res_f, interval=1.0,
                                slack_intervals=1)
    rows.append(Row("fig19_equiv/batch_scaling", 0.0,
                    match_frac=round(frac, 4), max_count_dev=dev,
                    identical_within_one_interval=(frac >= 0.95
                                                   and dev <= 1)))

    # ---- 100k bursty trace: event vs fixed vs seed baseline
    n = int(os.environ.get("SCENARIO_SWEEP_N", "100000"))
    budget = float(os.environ.get("SCENARIO_SWEEP_LEGACY_BUDGET", "120"))

    reqs, max_time = _speedup_trace(n)
    cluster = SimCluster(default_perf_factory(), max_chips=MAX_CHIPS)
    t0 = time.perf_counter()
    res = simulate_events(reqs, chiron(), cluster, max_time=max_time,
                          warm_start=2)
    wall_event = time.perf_counter() - t0
    rows.append(Row("speedup/event", wall_event * 1e6, n=n,
                    wall_s=round(wall_event, 2),
                    **_finish_stats(res, res.requests)))

    reqs_f, _ = _speedup_trace(n)
    cluster = SimCluster(default_perf_factory(), max_chips=MAX_CHIPS)
    t0 = time.perf_counter()
    res_fx = simulate_fixed_tick(reqs_f, chiron(), cluster, dt=0.25,
                                 max_time=max_time, warm_start=2)
    wall_fixed = time.perf_counter() - t0
    rows.append(Row("speedup/fixed_dt0.25", wall_fixed * 1e6, n=n,
                    wall_s=round(wall_fixed, 2),
                    speedup_event=round(wall_fixed / wall_event, 1),
                    **_finish_stats(res_fx, res_fx.requests)))

    # seed baseline growth curve (small n, full runs)
    import repro.sim.simulator as sim_mod
    for n_small in (1000, 4000):
        reqs_s, mt = _speedup_trace(n_small)
        cluster = SimCluster(default_perf_factory(), max_chips=MAX_CHIPS)
        orig = sim_mod.GlobalQueue
        sim_mod.GlobalQueue = SeedFcfsQueue
        try:
            t0 = time.perf_counter()
            simulate_fixed_tick(reqs_s, chiron(), cluster, dt=0.25,
                                max_time=mt, warm_start=2)
            w = time.perf_counter() - t0
        finally:
            sim_mod.GlobalQueue = orig
        rows.append(Row(f"speedup/seed_fixed_n{n_small}", w * 1e6,
                        n=n_small, wall_s=round(w, 2)))

    # seed baseline at full n under a wall-clock budget
    def _seed_full():
        reqs_l, _ = _speedup_trace(n)
        cluster = SimCluster(default_perf_factory(), max_chips=MAX_CHIPS)
        orig = sim_mod.GlobalQueue
        sim_mod.GlobalQueue = SeedFcfsQueue
        try:
            return simulate_fixed_tick(reqs_l, chiron(), cluster, dt=0.25,
                                       max_time=max_time, warm_start=2)
        finally:
            sim_mod.GlobalQueue = orig
    out, wall_seed = _run_budgeted(_seed_full, budget)
    if out is None:
        rows.append(Row("speedup/seed_fixed_full", wall_seed * 1e6, n=n,
                        wall_s=f">{wall_seed:.0f} (budget exceeded)",
                        speedup_event=f">{wall_seed / wall_event:.0f}x"))
    else:
        rows.append(Row("speedup/seed_fixed_full", wall_seed * 1e6, n=n,
                        wall_s=round(wall_seed, 2),
                        speedup_event=round(wall_seed / wall_event, 1)))
    return rows
