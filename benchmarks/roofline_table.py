"""§Roofline: read the dry-run JSONL records and emit the per-(arch x
shape) roofline table (terms in seconds, bottleneck, useful-FLOPs ratio)."""
import json
import os

from benchmarks.common import Row

_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
RESULTS = os.path.join(_DIR, "dryrun_optimized.jsonl")
_FALLBACK = os.path.join(_DIR, "dryrun_single_pod.jsonl")


def run():
    rows = []
    path = RESULTS if os.path.exists(RESULTS) else _FALLBACK
    if not os.path.exists(path):
        rows.append(Row("roofline/missing", 0.0,
                        note="run repro.launch.dryrun --all --out first"))
        return rows
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    # keep the latest record per (arch, shape)
    latest = {}
    for r in recs:
        latest[(r["arch"], r["shape"])] = r
    for (arch, shape), r in sorted(latest.items()):
        if r["status"] != "ok":
            rows.append(Row(f"roofline/{arch}/{shape}", 0.0, status="FAIL"))
            continue
        rows.append(Row(
            f"roofline/{arch}/{shape}", r.get("total_s", 0) * 1e6,
            compute_ms=round(r["compute_s"] * 1e3, 3),
            memory_ms=round(r["memory_s"] * 1e3, 3),
            collective_ms=round(r["collective_s"] * 1e3, 3),
            bottleneck=r["bottleneck"],
            useful=round(r["useful_flops_ratio"], 3),
            mem_gib=round(r["bytes_per_device"] / 2**30, 2)))
    return rows
