"""Beyond-paper: Chiron's local autoscaler across ALL assigned
architectures (the paper evaluates Llama only).

For each assigned architecture, run Algorithm 1 in closed loop against
that architecture's perf model and report the converged batch size, its
distance to the analytic optimum, and the serving character the
controller discovered — e.g. attention-free mamba2 supports much larger
batches at the same ITL SLO because its state is O(1) (DESIGN.md §5)."""
import time

from benchmarks.common import Row
from repro.configs import ASSIGNED_ARCHS
from repro.core.backpressure import LocalMetrics
from repro.core.local_autoscaler import LocalAutoscaler
from repro.sim.perf_model import PerfModel

ITL_SLO = 0.2
CTX = 1024.0


def run():
    rows = []
    for arch in ASSIGNED_ARCHS:
        pm = PerfModel(arch)
        t0 = time.perf_counter()
        s = LocalAutoscaler(itl_slo=ITL_SLO, init_batch=8, max_batch=8192)
        for _ in range(80):
            b = s.max_batch_size
            s.update(LocalMetrics(pm.itl(b, CTX), pm.throughput(b, CTX),
                                  ITL_SLO))
        us = (time.perf_counter() - t0) * 1e6
        tail = s.history[-8:]
        conv = sum(tail) / len(tail)
        opt = pm.optimal_batch(ITL_SLO, CTX, max_batch=8192)
        rows.append(Row(
            f"arch_sweep/{arch}", us,
            chips=pm.chips,
            converged_batch=round(conv),
            optimal_batch=opt,
            rel_err_pct=round(100 * abs(conv - opt) / max(opt, 1), 1),
            itl_ms=round(pm.itl(int(conv), CTX) * 1e3, 1),
            tok_per_s=round(pm.throughput(int(conv), CTX))))
    return rows
