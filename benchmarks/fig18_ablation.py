"""Fig. 18: ablation — full Chiron vs Local-only (utilization global) vs
Global-only (static batch) vs Llumnix, on the mixed W_B workload."""
from benchmarks.common import Row, chiron, llumnix, run_sim
from repro.serving.request import RequestType
from repro.sim.workload import WorkloadSpec


def run():
    rows = []
    # sized so the warm-start capacity alone cannot make the batch deadline
    # (forces the global level) and a static batch size leaves throughput
    # on the table (exposes the local level)
    spec_kw = dict(n_requests=600, arrival_rate=25.0, interactive_frac=1.0,
                   batch_queue_size=20000, batch_ttft_slo=120.0,
                   model="llama-8b", seed=4)
    arms = {
        "chiron_full": chiron(),
        "chiron_local_only": chiron(global_enabled=False),
        "chiron_global_only": chiron(local_enabled=False, static_batch=64),
        "llumnix": llumnix(),
    }
    for name, ctrl in arms.items():
        res, wall = run_sim(WorkloadSpec(**spec_kw), ctrl, max_time=1800)
        rows.append(Row(f"fig18/{name}", wall * 1e6,
                        slo_pct=round(100 * res.slo_attainment(), 1),
                        batch_ttft_pct=round(
                            100 * res.ttft_attainment(RequestType.BATCH), 1),
                        per_inst_tok_s=round(res.per_instance_throughput()),
                        gpu_hours=round(res.gpu_hours(), 3)))
    return rows
