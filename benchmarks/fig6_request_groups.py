"""Fig. 6: request groups prevent autoscaling hysteresis.

Microbenchmark isolating the §2.3 claim: N batch requests with staggered
deadlines are served either (a) individually — a per-request policy adds an
instance when a request nears its deadline and retires it when that
request's work drains (the churny pre-Chiron behaviour), or (b) in
deadline-clustered request groups via Algorithm 2 — instances are added in
bulk per group and retired once per group.

Reported: hysteresis (= total scaling actions / scale-ups), total scaling
actions, and effective throughput (group executions amortize instance
warm-up; individual scaling pays the model-load time per action).
"""
import time

from benchmarks.common import Row
from repro.core.global_autoscaler import BatchAutoscaler
from repro.core.request_groups import make_request_groups
from repro.core.waiting_time import WaitingTimeEstimator
from repro.serving.request import make_batch

N = 600
THROUGHPUT = 12_000.0        # tokens/s per instance
MEAN_OUT = 270.0
LOAD_TIME = 15.0


def _requests():
    # 6 deadline cohorts arriving interleaved
    reqs = []
    for i in range(N):
        ttft = 300.0 * (1 + i % 6)
        reqs.append(make_batch(128, int(MEAN_OUT), arrival=0.0,
                               ttft_slo=ttft))
    return reqs


def _estimator():
    est = WaitingTimeEstimator()
    est.output_model.mu, est.output_model.sigma = MEAN_OUT, 80.0
    return est


def _simulate(grouped: bool):
    """Event loop at 5 s ticks; returns (ups, downs, busy_time, makespan)."""
    reqs = _requests()
    remaining = {r.req_id: r for r in reqs}
    scaler = BatchAutoscaler(_estimator(), THROUGHPUT,
                             group_k=0 if grouped else -1)
    t, instances, ups, downs = 0.0, 0, 0, 0
    pending = []                              # (ready_time, count)
    served_tokens = 0.0
    while remaining and t < 3600.0:
        provisioned = instances + sum(c for _, c in pending)
        if grouped:
            # Algorithm 2: bulk add per request group, retire when drained
            queued = sorted(remaining.values(), key=lambda r: r.deadline)
            dec = scaler.update(queued, t, n_batch_instances=provisioned,
                                n_active_batch_requests=0)
            if dec.add_instances:
                ups += dec.add_instances
                pending.append((t + LOAD_TIME, dec.add_instances))
        else:
            # per-request reactive policy (pre-Chiron): track the number of
            # individually-urgent requests up and down every tick
            urgent = sum(1 for r in remaining.values()
                         if r.deadline - t < LOAD_TIME + 60.0)
            target = min(urgent, 32)
            if target > provisioned:
                ups += target - provisioned
                pending.append((t + LOAD_TIME, target - provisioned))
            elif instances > target:
                downs += instances - target
                instances = target
        for rt, c in list(pending):           # instances come online
            if t >= rt:
                instances += c
                pending.remove((rt, c))
        # serve (per-request policy trickles; grouped serves in bulk)
        if instances:
            per_tick = instances * THROUGHPUT * 5.0
            cap = per_tick if grouped else min(per_tick,
                                               instances * MEAN_OUT)
            while remaining and cap > 0:
                r = min(remaining.values(), key=lambda q: q.deadline)
                need = MEAN_OUT
                if cap < need:
                    break
                cap -= need
                served_tokens += need
                del remaining[r.req_id]
        if grouped and not remaining and instances:
            downs += instances
            instances = 0
        t += 5.0
    if instances:
        downs += instances
    thr = served_tokens / max(t, 1e-9)
    hyst = (ups + downs) / max(ups, 1)
    return ups, downs, hyst, thr, t


def run():
    rows = []
    t0 = time.perf_counter()
    g = _simulate(grouped=True)
    ng = _simulate(grouped=False)
    us = (time.perf_counter() - t0) * 1e6 / 2
    rows.append(Row("fig6/groups", us, scale_ups=g[0], scale_downs=g[1],
                    hysteresis=round(g[2], 2),
                    tok_per_s=round(g[3])))
    rows.append(Row("fig6/individual", us, scale_ups=ng[0],
                    scale_downs=ng[1], hysteresis=round(ng[2], 2),
                    tok_per_s=round(ng[3])))
    rows.append(Row("fig6/summary", 0.0,
                    action_reduction=round(
                        (ng[0] + ng[1]) / max(g[0] + g[1], 1), 1),
                    throughput_gain=round(g[3] / max(ng[3], 1e-9), 2)))
    return rows
