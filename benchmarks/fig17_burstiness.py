"""Fig. 17: SLO attainment vs arrival burstiness (Gamma CV sweep).
Over-provisioning theta=1/3 absorbs bursts up to a point, then degrades."""
from benchmarks.common import Row, chiron, run_sim
from repro.sim.workload import WorkloadSpec


def run():
    rows = []
    for cv in (1.0, 2.0, 4.0, 8.0, 16.0):
        spec = WorkloadSpec(n_requests=800, arrival_rate=40.0,
                            process="gamma", cv=cv, model="llama-8b", seed=3)
        res, wall = run_sim(spec, chiron("llama-8b", theta=1 / 3),
                            max_time=900)
        rows.append(Row(f"fig17/cv{cv:g}", wall * 1e6,
                        slo_pct=round(100 * res.slo_attainment(), 1),
                        p99_ttft_s=round(res.p99_ttft(), 2),
                        peak_chips=res.peak_chips))
    return rows
