"""Fig. 14: queue waiting-time estimation accuracy (R^2) vs queue size."""
import time

import numpy as np

from benchmarks.common import Row
from repro.core.waiting_time import OutputLengthModel, WaitingTimeEstimator
from repro.sim.workload import OUTPUT_MU, OUTPUT_SIGMA


def _r2(qsize: int, theta: float, trials: int = 200, seed: int = 0) -> float:
    """R^2 of estimated vs actual waiting time across requests sitting at
    random positions of a queue of size ``qsize`` (paper Fig. 14: with more
    requests ahead, the CLT tightens the per-request estimate)."""
    rng = np.random.default_rng(seed)
    m = OutputLengthModel()
    for x in rng.lognormal(OUTPUT_MU, OUTPUT_SIGMA, 500):
        m.observe(int(min(x, 2048)))
    est = WaitingTimeEstimator(output_model=m)
    actual, pred = [], []
    for _ in range(trials):
        q = int(rng.integers(1, qsize + 1))     # requests ahead
        outs = np.clip(rng.lognormal(OUTPUT_MU, OUTPUT_SIGMA, q),
                       4, 2048).astype(int)
        actual.append(outs.sum() / theta)
        pred.append(est.waiting_time(q, theta))
    actual = np.asarray(actual)
    pred = np.asarray(pred)
    ss_res = np.sum((actual - pred) ** 2)
    ss_tot = np.sum((actual - actual.mean()) ** 2)
    return float(1 - ss_res / ss_tot) if ss_tot > 0 else 1.0


def run():
    rows = []
    for model, theta in (("llama-8b", 12000.0), ("llama-70b", 10000.0)):
        for q in (10, 50, 200, 500, 2000):
            t0 = time.perf_counter()
            r2 = _r2(q, theta)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(Row(f"fig14/{model}/q{q}", us, r2=round(r2, 4)))
    return rows
