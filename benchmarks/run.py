"""Benchmark runner: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  fig3   ITL/throughput vs batch size          (perf model)
  fig6   request-group hysteresis              (sim, via fig19 module)
  fig9   W_A interactive sweep                 (sim)
  fig10  W_B batch-queue sweep                 (sim)
  fig11  local-autoscaler convergence          (closed loop)
  fig12  convergence time 8B vs 70B            (closed loop)
  fig13  queue size vs batch TTFT SLO          (sim)
  fig14  waiting-time estimator R^2            (statistical)
  fig16  ITL SLO sweep table                   (sim)
  fig17  burstiness robustness                 (sim)
  fig18  ablation                              (sim)
  fig19  GPUs-over-time + fig2 GPU savings     (sim)
  kernels  micro-benchmarks                    (jit on CPU)
  roofline per-(arch x shape) dry-run terms    (reads results/)

Run a subset: ``python -m benchmarks.run fig9 fig18``.
"""
import importlib
import sys
import time

MODULES = [
    "fig3_batch_tradeoff",
    "fig6_request_groups",
    "fig9_interactive",
    "fig10_batch",
    "fig11_convergence",
    "fig13_queue_slo",
    "fig14_estimator",
    "fig16_itl_sweep",
    "fig17_burstiness",
    "fig18_ablation",
    "fig19_timeline",
    "scenario_sweep",
    "arch_sweep",
    "appendix_a1_load_time",
    "kernels_micro",
    "roofline_table",
]


def main() -> None:
    want = sys.argv[1:]
    mods = [m for m in MODULES
            if not want or any(w in m for w in want)]
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in mods:
        mod = importlib.import_module(f"benchmarks.{name}")
        try:
            for row in mod.run():
                row.print()
        except Exception as e:
            print(f"{name}/ERROR,0,{type(e).__name__}={e}")
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
