"""Fig. 13: queue size maintained for varying batch TTFT SLO — longer
deadlines let Chiron hold bigger queues and multiplex more."""
from benchmarks.common import Row, chiron, run_sim
from repro.sim.workload import WorkloadSpec


def run():
    rows = []
    for ttft in (600.0, 1800.0, 3600.0):
        spec = WorkloadSpec(n_requests=600, arrival_rate=20.0,
                            interactive_frac=1.0, batch_queue_size=15000,
                            batch_ttft_slo=ttft, model="llama-8b", seed=6)
        res, wall = run_sim(spec, chiron(), max_time=2400)
        qmax = max((p.q_batch for p in res.timeline), default=0)
        qmean = sum(p.q_batch for p in res.timeline) / max(len(res.timeline), 1)
        rows.append(Row(f"fig13/ttft{ttft:g}", wall * 1e6,
                        mean_queue=round(qmean),
                        peak_queue=qmax,
                        gpu_hours=round(res.gpu_hours(), 3),
                        batch_done_pct=round(100 * res.completion_rate(), 1)))
    return rows
