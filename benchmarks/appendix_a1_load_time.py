"""Appendix A.1: impact of model loading times.

When load time >> interactive TTFT SLO, over-provisioning (and therefore
Chiron's mixed-instance multiplexing) is essential; when load time is
small (<3B-parameter models), elastic scaling suffices and the global
autoscaler's value shrinks while local batch adaptation stays useful.
Sweep the instance load time and report over-provisioned GPU hours and
SLO attainment at fixed burstiness. Also exercises auto-Theta (the paper's
'Theta from historical arrival spikes')."""
from benchmarks.common import Row, chiron, run_sim
from repro.sim.cluster import SimCluster
from repro.sim.simulator import default_perf_factory, simulate
from repro.sim.workload import WorkloadSpec, generate


def run():
    rows = []
    for load in (2.0, 15.0, 60.0):
        spec = WorkloadSpec(n_requests=4000, arrival_rate=80.0,
                            process="gamma", cv=6.0, model="llama-8b",
                            seed=12)
        reqs = generate(spec)
        ctrl = chiron("llama-8b", auto_theta=True, theta_refresh=20.0)
        cluster = SimCluster(default_perf_factory(), max_chips=400,
                             load_time=load)
        import time as _t
        t0 = _t.perf_counter()
        res = simulate(reqs, ctrl, cluster, max_time=900, warm_start=1)
        wall = (_t.perf_counter() - t0) * 1e6
        rows.append(Row(
            f"appendix_a1/load{load:g}s", wall,
            slo_pct=round(100 * res.slo_attainment(), 1),
            gpu_hours=round(res.gpu_hours(), 3),
            peak_chips=res.peak_chips,
            theta_final=round(ctrl.interactive_scaler.theta, 3),
            p99_ttft_s=round(res.p99_ttft(), 2)))
    return rows
