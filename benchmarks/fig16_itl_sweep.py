"""Fig. 16 (table): ITL SLO sweep for Llama-70B — %SLOs met, throughput,
GPUs required (relative to the tightest SLO)."""
from benchmarks.common import Row, chiron, run_sim
from repro.serving.request import INTERACTIVE_ITL_SLO, SLO
from repro.sim.workload import WorkloadSpec, generate

# The paper sweeps 0.1..100 s on A100s where high-batch ITL is ~100-200 ms.
# Our v5e-16 instances decode ~10x faster (Fig. 3 bench), so the equivalent
# sweep — from "ITL binds hard" to "never binds" — is scaled down 10x.
SLOS = (0.01, 0.02, 0.05, 0.2, 2.0)


def run():
    rows = []
    base_chips = None
    for itl_slo in SLOS:
        spec = WorkloadSpec(n_requests=800, arrival_rate=40.0,
                            model="llama-70b", seed=2)
        ctrl = chiron("llama-70b", itl_slo_interactive=itl_slo)
        # patch request SLOs to the swept value
        res, wall = run_sim_with_slo(spec, ctrl, itl_slo)
        chips = max(res.peak_chips, 1)
        if base_chips is None:
            base_chips = chips
        rows.append(Row(f"fig16/itl_slo_{itl_slo:g}", wall * 1e6,
                        slo_pct=round(100 * res.slo_attainment(), 1),
                        req_per_s=round(res.request_throughput(), 2),
                        gpus_rel_pct=round(100 * chips / base_chips)))
    return rows


def run_sim_with_slo(spec, ctrl, itl_slo):
    import time as _t
    from repro.sim.cluster import SimCluster
    from repro.sim.simulator import default_perf_factory, simulate
    reqs = generate(spec)
    for r in reqs:
        r.slo = SLO(r.slo.ttft, itl_slo)
    cluster = SimCluster(default_perf_factory(), max_chips=400)
    t0 = _t.perf_counter()
    res = simulate(reqs, ctrl, cluster, max_time=900, warm_start=2)
    return res, _t.perf_counter() - t0
