"""Fig. 19 / Appendix A.2: GPUs over time — interactive stream, then a
large batch dump; Chiron multiplexes + bulk-adds near deadline, Llumnix
scales out immediately. Also emits the Fig. 2 (right) headline (GPU
savings). Request-group hysteresis has its own microbench (fig6)."""
from benchmarks.common import MAX_CHIPS, Row, chiron, llumnix, run_sim
from repro.sim.workload import WorkloadSpec


def _spec(seed=5):
    return WorkloadSpec(n_requests=2000, arrival_rate=30.0,
                        interactive_frac=1.0, batch_queue_size=30000,
                        batch_ttft_slo=1800.0, model="llama-8b", seed=seed)


def run():
    rows = []
    runs = {}
    for name, ctrl in (("chiron", chiron()), ("llumnix", llumnix())):
        res, wall = run_sim(_spec(), ctrl, max_time=2400)
        runs[name] = res
        # timeline: chips at 8 evenly spaced marks over the run
        step = max(res.duration / 8, 1.0)
        marks = {}
        for p in res.timeline:
            key = int(p.t // step)
            marks.setdefault(key, p.chips)
        tl = ";".join(f"t{int(step*k)}s:{v}"
                      for k, v in sorted(marks.items())[:9])
        rows.append(Row(f"fig19/{name}", wall * 1e6,
                        gpu_hours=round(res.gpu_hours(), 3),
                        peak_chips=res.peak_chips,
                        hysteresis=round(res.hysteresis, 2),
                        scale_ups=res.scale_ups,
                        timeline=tl.replace(";", "|")))
    c, l = runs["chiron"], runs["llumnix"]
    rows.append(Row("fig2/gpu_savings", 0.0,
                    chiron_gpu_h=round(c.gpu_hours(), 3),
                    llumnix_gpu_h=round(l.gpu_hours(), 3),
                    savings_pct=round(100 * (1 - c.gpu_hours() /
                                             max(l.gpu_hours(), 1e-9)), 1)))
    return rows
