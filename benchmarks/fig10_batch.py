"""Fig. 10 (W_B): interactive + batch workload, batch-queue sweep with a
fixed interactive arrival rate; Chiron vs Llumnix (untuned + tuned)."""
from benchmarks.common import Row, chiron, llumnix, llumnix_tuned, run_sim
from repro.serving.request import RequestType
from repro.sim.workload import WorkloadSpec

# interactive rate fixed (paper: 50 rps for 8B, 10 rps for 70B); batch
# queue dumped at t=0, sweep its size
SETUPS = {"llama-8b": (50.0, (5_000, 20_000, 60_000)),
          "llama-70b": (10.0, (2_000, 8_000, 20_000))}


def _spec(model, rate, qsize, seed=0):
    return WorkloadSpec(n_requests=600, arrival_rate=rate,
                        interactive_frac=1.0, batch_queue_size=qsize,
                        batch_ttft_slo=1800.0, model=model, seed=seed)


def run():
    rows = []
    for model, (rate, qsizes) in SETUPS.items():
        for q in qsizes:
            spec = _spec(model, rate, q)
            ctrls = {
                "chiron": chiron(model),
                "llumnix": llumnix(model),
                "llumnix_tuned": llumnix_tuned(
                    _spec(model, rate, min(qsizes), seed=1), model),
            }
            for name, ctrl in ctrls.items():
                res, wall = run_sim(spec, ctrl, max_time=2400)
                rows.append(Row(
                    f"fig10/{model}/q{q}/{name}", wall * 1e6,
                    slo_pct=round(100 * res.slo_attainment(), 1),
                    slo_batch_pct=round(
                        100 * res.slo_attainment(RequestType.BATCH), 1),
                    per_inst_tok_s=round(res.per_instance_throughput()),
                    completed_pct=round(100 * res.completion_rate(), 1),
                    gpu_hours=round(res.gpu_hours(), 3)))
    return rows
