"""Shared benchmark helpers: run wrappers, tuned-Llumnix sweep, CSV rows."""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.serving.request import RequestState, RequestType
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController, LlumnixController
from repro.sim.metrics import RunResult
from repro.sim.simulator import default_perf_factory, simulate
from repro.sim.workload import WorkloadSpec, generate

MAX_CHIPS = 400          # elastic-cloud cap (paper: 50 A100s; we budget
                         # the v5e-chip equivalent)


class Row:
    """One CSV output row: name,us_per_call,derived."""

    def __init__(self, name: str, us_per_call: float, **derived):
        self.name = name
        self.us = us_per_call
        self.derived = derived

    def print(self):
        d = ";".join(f"{k}={v}" for k, v in self.derived.items())
        print(f"{self.name},{self.us:.1f},{d}")


def run_sim(spec: WorkloadSpec, controller, *, max_time=1800.0,
            warm_start=2, max_chips=MAX_CHIPS, **kw) -> Tuple[RunResult, float]:
    reqs = generate(spec)
    cluster = SimCluster(default_perf_factory(), max_chips=max_chips)
    t0 = time.perf_counter()
    res = simulate(reqs, controller, cluster, max_time=max_time,
                   warm_start=warm_start, **kw)
    wall = time.perf_counter() - t0
    return res, wall


def chiron(model="llama-8b", **kw) -> ChironController:
    return ChironController(model=model, **kw)


def llumnix(model="llama-8b", **kw) -> LlumnixController:
    return LlumnixController(model=model, **kw)


def llumnix_tuned(spec: WorkloadSpec, model="llama-8b",
                  grid=None) -> LlumnixController:
    """Per-workload parameter sweep (the paper's 'Llumnix (tuned)')."""
    grid = grid or [
        dict(low=0.2, high=0.6, static_batch=64),
        dict(low=0.3, high=0.8, static_batch=128),
        dict(low=0.4, high=0.9, static_batch=256),
        dict(low=0.3, high=0.8, static_batch=320),
    ]
    best, best_key = None, None
    for params in grid:
        res, _ = run_sim(spec, llumnix(model, **params), max_time=1200)
        key = (round(res.slo_attainment(), 3), res.request_throughput())
        if best_key is None or key > best_key:
            best_key, best = key, params
    return llumnix(model, **best)


def goodput(res: RunResult) -> float:
    ok = sum(r.slo_met() for r in res.requests)
    return ok / res.gpu_hours() if res.gpu_hours() > 0 else 0.0
