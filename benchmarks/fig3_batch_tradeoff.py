"""Fig. 3: inter-token latency and token throughput vs batch size
(Llama-8B and Llama-70B), from the roofline-calibrated perf model."""
import time

from benchmarks.common import Row
from repro.sim.perf_model import PerfModel


def run():
    rows = []
    for model in ("llama-8b", "llama-70b"):
        pm = PerfModel(model)
        t0 = time.perf_counter()
        pts = [(b, pm.itl(b, 1024.0), pm.throughput(b, 1024.0))
               for b in (1, 8, 32, 64, 128, 256, 320, 384, 512, 1024)]
        us = (time.perf_counter() - t0) * 1e6 / len(pts)
        peak_b, _, peak_thr = max(pts, key=lambda p: p[2])
        for b, itl, thr in pts:
            rows.append(Row(f"fig3/{model}/b{b}", us,
                            itl_ms=round(itl * 1e3, 2),
                            tok_per_s=round(thr),
                            inflection_batch=peak_b))
    return rows
