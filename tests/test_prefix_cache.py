"""Engine-level prefix caching + chunked prefill (Fig. 11 knobs, real)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.serving.engine import Engine
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import RequestState, make_interactive


def test_prefix_cache_lookup_longest():
    pc = PrefixCache(max_entries=4)
    pc.store([1, 2, 3], "c3")
    pc.store([1, 2, 3, 4, 5], "c5")
    cache, n = pc.lookup([1, 2, 3, 4, 5, 6, 7])
    assert cache == "c5" and n == 5
    cache, n = pc.lookup([1, 2, 3, 4])        # c3 is the longest STRICT prefix
    assert cache == "c3" and n == 3
    cache, n = pc.lookup([9, 9])
    assert cache is None and n == 0
    assert pc.hits == 2 and pc.misses == 1


def test_prefix_cache_lru_eviction():
    pc = PrefixCache(max_entries=2)
    pc.store([1], "a")
    pc.store([2], "b")
    pc.store([3], "c")
    assert len(pc) == 2
    assert pc.lookup([1, 0])[0] is None       # evicted
    assert pc.lookup([3, 0])[0] == "c"


def _run_engine(eng, reqs, max_steps=200):
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.waiting or eng.n_active) and steps < max_steps:
        eng.step()
        steps += 1
    return steps


def test_engine_prefix_hit_and_correctness():
    cfg = get_smoke_config("granite-8b")
    shared = np.arange(10, 26, dtype=np.int32) % cfg.vocab_size

    def mk(extra):
        r = make_interactive(16 + len(extra), 6)
        r.prompt_tokens = np.concatenate([shared, np.asarray(extra, np.int32)])
        return r

    # engine WITH prefix caching
    eng = Engine(cfg, max_slots=2, max_len=64, dtype=jnp.float32,
                 prefix_cache_entries=8)
    reqs = [mk([1, 2, 3]), mk([4, 5, 6]), mk([7, 8, 9])]
    # serialize so the first prompt is cached before the others arrive
    _run_engine(eng, reqs[:1])
    _run_engine(eng, reqs[1:])
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert eng.prefix_cache.hits >= 1

    # identical workload WITHOUT caching must produce the same tokens
    eng2 = Engine(cfg, max_slots=2, max_len=64, dtype=jnp.float32)
    reqs2 = [mk([1, 2, 3]), mk([4, 5, 6]), mk([7, 8, 9])]
    _run_engine(eng2, reqs2[:1])
    _run_engine(eng2, reqs2[1:])
    for a, b in zip(reqs, reqs2):
        assert a.tokens_generated == b.tokens_generated


def test_engine_chunked_prefill():
    cfg = get_smoke_config("granite-8b")
    eng = Engine(cfg, max_slots=2, max_len=96, dtype=jnp.float32,
                 prefill_chunk=8)
    r = make_interactive(29, 5)   # 29 tokens -> chunks 8+8+8+5
    _run_engine(eng, [r])
    assert r.state == RequestState.FINISHED
    assert r.tokens_generated >= r.output_len
