"""Randomized engine soak: arbitrary submit/step/resize/preempt sequences
must preserve the serving invariants (no lost requests, batch-size bound
respected, monotone progress, finished => complete)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.serving.engine import Engine
from repro.serving.request import RequestState, make_batch, make_interactive


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engine_random_soak(seed):
    rng = np.random.default_rng(seed)
    cfg = get_smoke_config("olmo-1b")
    eng = Engine(cfg, max_slots=4, max_len=64, dtype=jnp.float32)
    all_reqs = []
    preempted_pool = []

    for step in range(60):
        op = rng.random()
        if op < 0.25 and len(all_reqs) < 14:
            mk = make_interactive if rng.random() < 0.5 else make_batch
            r = mk(int(rng.integers(4, 12)), int(rng.integers(2, 10)))
            all_reqs.append(r)
            eng.submit(r)
        elif op < 0.30:
            eng.set_max_batch_size(int(rng.integers(1, 5)))
        elif op < 0.35:
            v = eng.preempt_one_batch(0.0)
            if v is not None:
                preempted_pool.append(v)
        elif op < 0.45 and preempted_pool:
            eng.submit(preempted_pool.pop())
        else:
            stats = eng.step()
            # engine contract: internally-preempted victims are handed to
            # the caller (the router) for requeueing via StepStats
            preempted_pool.extend(stats.preempted)

        # ---- invariants
        assert eng.n_active <= eng.max_slots
        states = {}
        for r in all_reqs:
            states[r.req_id] = r.state
        running_ids = {s.request.req_id for s in eng.slots if s.active}
        waiting_ids = {r.req_id for r in eng.waiting}
        pool_ids = {r.req_id for r in preempted_pool}
        for r in all_reqs:
            locs = [r.req_id in running_ids, r.req_id in waiting_ids,
                    r.req_id in pool_ids,
                    r.state == RequestState.FINISHED]
            assert sum(locs) == 1, (r.req_id, r.state, locs)
            if r.state == RequestState.FINISHED:
                assert r.tokens_generated >= r.output_len
                assert r.finish_time is not None

    # drain: everything must finish
    for r in preempted_pool:
        eng.submit(r)
    preempted_pool.clear()
    eng.set_max_batch_size(4)
    for _ in range(300):
        if not (eng.waiting or eng.n_active):
            break
        eng.step()
    for r in all_reqs:
        assert r.state == RequestState.FINISHED, r.req_id
        assert r.tokens_generated >= r.output_len
