"""Cluster-simulation invariants + paper-level behaviour ordering."""
import pytest

from repro.serving.request import RequestState, RequestType
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController, LlumnixController
from repro.sim.perf_model import PerfModel
from repro.sim.simulator import default_perf_factory, simulate
from repro.sim.workload import WorkloadSpec, generate, theta_from_history


def _run(ctrl, reqs, max_chips=200, **kw):
    cluster = SimCluster(default_perf_factory(), max_chips=max_chips)
    return simulate(reqs, ctrl, cluster, max_time=kw.pop("max_time", 900),
                    warm_start=kw.pop("warm_start", 2), **kw)


def test_conservation_all_requests_terminate():
    spec = WorkloadSpec(n_requests=200, arrival_rate=15.0, seed=3)
    reqs = generate(spec)
    res = _run(ChironController(), reqs)
    states = [r.state for r in reqs]
    assert all(s == RequestState.FINISHED for s in states)
    # each finished exactly once with sane bookkeeping
    for r in reqs:
        assert r.tokens_generated == r.output_len
        assert r.finish_time is not None and r.first_token_time is not None
        assert r.finish_time >= r.first_token_time >= r.arrival_time


def test_gpu_accounting_positive():
    spec = WorkloadSpec(n_requests=100, arrival_rate=10.0, seed=4)
    res = _run(ChironController(), generate(spec))
    assert res.gpu_hours() > 0
    assert res.peak_chips > 0
    assert res.duration > 0


def test_mixed_workload_completes_with_multiplexing():
    spec = WorkloadSpec(n_requests=150, arrival_rate=10.0,
                        interactive_frac=0.7, batch_ttft_slo=600.0, seed=5)
    reqs = generate(spec)
    res = _run(ChironController(), reqs, max_time=1200)
    assert res.completion_rate() == 1.0
    assert res.slo_attainment(RequestType.INTERACTIVE) > 0.5


def test_chiron_beats_llumnix_on_batch_efficiency():
    """Paper §6.2/Fig 19: with a batch queue + interactive stream, Chiron
    multiplexes the queue into spare capacity and uses fewer GPU-hours."""
    def mk(seed=7):
        return generate(WorkloadSpec(
            n_requests=150, arrival_rate=8.0, interactive_frac=1.0,
            batch_queue_size=400, batch_ttft_slo=900.0, seed=seed))

    res_c = _run(ChironController(), mk(), max_time=1500)
    res_l = _run(LlumnixController(), mk(), max_time=1500)
    assert res_c.completion_rate() > 0.95
    # efficiency: fewer chip-hours per completed request
    eff_c = res_c.gpu_hours() / max(sum(
        r.state == RequestState.FINISHED for r in res_c.requests), 1)
    eff_l = res_l.gpu_hours() / max(sum(
        r.state == RequestState.FINISHED for r in res_l.requests), 1)
    assert eff_c < eff_l, (eff_c, eff_l)


def test_hysteresis_lower_with_groups():
    spec = WorkloadSpec(n_requests=100, arrival_rate=5.0,
                        interactive_frac=1.0, batch_queue_size=300,
                        batch_ttft_slo=600.0, seed=8)
    res = _run(ChironController(), generate(spec), max_time=1200)
    # grouped batch scaling adds instances in bulk: few scaling actions
    assert res.scale_ups < 25


def test_theta_from_history():
    reqs = generate(WorkloadSpec(n_requests=500, arrival_rate=20.0, seed=9,
                                 process="gamma", cv=3.0))
    th = theta_from_history(reqs)
    assert 0.0 < th <= 1.0


def test_perf_model_fig3_shape():
    """Fig. 3: ITL monotone in batch; throughput has an inflection."""
    pm = PerfModel("llama-8b")
    itls = [pm.itl(b, 1024) for b in (1, 32, 128, 256, 512, 1024)]
    assert all(a <= b * 1.001 for a, b in zip(itls, itls[1:]))
    thr = [pm.throughput(b, 1024) for b in (1, 32, 128, 256, 512, 1024)]
    peak = max(thr)
    assert thr.index(peak) not in (0, len(thr) - 1)   # interior inflection
    assert thr[-1] < peak
