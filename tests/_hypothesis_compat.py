"""Optional-`hypothesis` shim so the suite collects without the dependency.

Property-based tests are a tier-2 nicety; the tier-1 suite must collect and
run its example-based tests on a bare interpreter.  Import hypothesis
through this module::

    from _hypothesis_compat import given, settings, st

When hypothesis is installed the real objects are re-exported unchanged.
When it is missing, ``@given(...)``-decorated tests (and stateful
``RuleBasedStateMachine.TestCase`` classes) turn into skips while plain
tests in the same module keep running.  Install the real package via
``pip install -r requirements-dev.txt``.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import HealthCheck, assume, given, settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                     precondition, rule)
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on bare images
    HAVE_HYPOTHESIS = False

    _SKIP_REASON = "hypothesis not installed (see requirements-dev.txt)"

    def given(*_args, **_kwargs):
        """Replace the test with a zero-arg skip (strategies never run)."""
        def deco(fn):
            def _skipped():
                pytest.skip(_SKIP_REASON)
            _skipped.__name__ = getattr(fn, "__name__", "test_hypothesis")
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    class settings:                                   # noqa: N801
        """Accepts any kwargs; as a decorator it is the identity."""

        def __init__(self, *_args, **_kwargs):
            pass

        def __call__(self, fn):
            return fn

    def assume(_condition):
        return True

    class HealthCheck:
        all = staticmethod(lambda: [])
        too_slow = filter_too_much = data_too_large = None

    class _Strategy:
        """Inert placeholder: composes/chains to itself, draws nothing."""

        def __call__(self, *_a, **_k):
            return self

        def __getattr__(self, _name):
            return self

    class _Strategies:
        def __getattr__(self, _name):
            return _Strategy()

    st = _Strategies()

    def rule(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def precondition(_pred):
        def deco(fn):
            return fn
        return deco

    def invariant(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class RuleBasedStateMachine:
        """Stub whose TestCase skips (state machines need real hypothesis)."""

        class TestCase:
            settings = None

            def runTest(self):                        # noqa: N802
                pytest.skip(_SKIP_REASON)

            def test_state_machine_skipped(self):
                pytest.skip(_SKIP_REASON)


__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "RuleBasedStateMachine",
           "assume", "given", "invariant", "precondition", "rule",
           "settings", "st"]
