"""Phase-attribution smoke test for ``scripts/profile_sim.py``.

Runs the profiler harness in ``--phases --json`` mode as a subprocess
(the same way CI and trend tooling invoke it) and checks the
machine-readable contract: the six event-loop phases are present, their
wall-clock laps are positive, and the loop total stays within the
documented envelope of the end-to-end wall (lap overhead is two clock
reads per phase, so the sum can never dwarf the wall it decomposes).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(ROOT, "scripts", "profile_sim.py")

PHASES = ("arrivals", "heap_drain", "control", "routing", "sweep",
          "sampling")


def _run_json(*argv):
    out = subprocess.run(
        [sys.executable, SCRIPT, *argv, "--json"],
        capture_output=True, text=True, check=True, cwd=ROOT)
    return json.loads(out.stdout)


@pytest.mark.parametrize("scenario", ["diurnal"])
def test_phases_json_contract(scenario):
    rep = _run_json(scenario, "-n", "400", "--phases")
    assert rep["scenario"] == scenario
    assert rep["events"] > 0
    assert rep["wall_s"] > 0
    assert rep["events_per_s"] == pytest.approx(
        rep["events"] / rep["wall_s"])
    assert 0.0 <= rep["completion_rate"] <= 1.0
    phases = rep["phases"]
    assert set(phases) == set(PHASES)
    assert all(v >= 0.0 for v in phases.values())
    total = sum(phases.values())
    # the six laps tile the loop body: nonempty, and bounded by the
    # end-to-end wall plus lap overhead slack
    assert 0.0 < total <= rep["wall_s"] * 1.5


def test_plain_json_has_no_phases():
    rep = _run_json("diurnal", "-n", "200")
    assert "phases" not in rep
    assert rep["events"] > 0
