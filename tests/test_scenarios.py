"""Scenario registry smoke: every scenario builds columnar and completes
on the event core; the three trace-plane scenarios (multi_model_fleet,
trace_replay, instance_failures) get behaviour checks."""
import numpy as np
import pytest

from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController
from repro.sim.scenarios import SCENARIOS, build, build_trace
from repro.sim.simulator import (FailurePlan, default_perf_factory,
                                 simulate_events)
from repro.sim.trace_io import save_trace
from repro.sim.workload import Trace

NEW_SCENARIOS = ("multi_model_fleet", "trace_replay", "instance_failures")


def _run(trace, kw, max_chips=200, **extra):
    ctrl = ChironController(models=kw["models"]) if "models" in kw \
        else ChironController()
    cluster = SimCluster(default_perf_factory(), max_chips=max_chips)
    return simulate_events(trace, ctrl, cluster, max_time=kw["max_time"],
                           warm_start=2, failures=kw.get("failures"),
                           **extra)


def test_registry_contains_trace_plane_scenarios():
    for name in NEW_SCENARIOS:
        assert name in SCENARIOS, name


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_smoke(name):
    """Small-n end-to-end: columnar build, event core, all work finishes."""
    trace, kw = build_trace(name, n_requests=250, seed=1)
    assert isinstance(trace, Trace)
    assert np.all(np.diff(trace.arrival) >= 0)
    res = _run(trace, kw)
    assert res.completion_rate() == 1.0, name
    # legacy list path agrees on shape
    reqs, _ = build(name, n_requests=250, seed=1)
    assert len(reqs) == trace.n


def test_multi_model_fleet_reports_per_model_slo():
    trace, kw = build_trace("multi_model_fleet", n_requests=500, seed=2)
    assert len(trace.models) >= 2
    assert len(set(trace.model_idx.tolist())) >= 2
    res = _run(trace, kw, max_chips=400)
    assert res.completion_rate() == 1.0
    s = res.summary()
    per_model = {k: v for k, v in s.items() if k.startswith("slo_model:")}
    assert len(per_model) >= 2
    assert set(per_model) == {f"slo_model:{m}" for m in kw["models"]}


def test_trace_replay_from_file(tmp_path):
    """trace_replay(path=...) replays a saved trace byte-for-byte."""
    synth, kw = build_trace("trace_replay", n_requests=300, seed=3)
    p = str(tmp_path / "replay.csv")
    save_trace(synth, p)
    replay, kw2 = build_trace("trace_replay", n_requests=300, seed=99,
                              path=p)
    assert np.array_equal(replay.arrival, synth.arrival)
    assert np.array_equal(replay.prompt_len, synth.prompt_len)
    res = _run(replay, kw2)
    assert res.completion_rate() == 1.0


def test_instance_failures_scenario_injects_and_recovers():
    trace, kw = build_trace("instance_failures", n_requests=500, seed=4)
    assert isinstance(kw["failures"], FailurePlan)
    res = _run(trace, kw)
    assert res.failures >= 1
    assert res.completion_rate() == 1.0
    # seed determinism end to end (same trace seed -> same plan -> same run)
    trace_b, kw_b = build_trace("instance_failures", n_requests=500, seed=4)
    res_b = _run(trace_b, kw_b)
    assert res.summary() == res_b.summary()
