"""Sliding-window attention: ring-buffer decode must match the windowed
full-sequence forward — the mechanism that makes long_500k decode O(window)
for dense architectures (DESIGN.md §6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model

WINDOW = 16
S = 48


@pytest.mark.parametrize("arch", ["granite-8b", "zamba2-2.7b"])
def test_windowed_decode_matches_windowed_forward(arch):
    cfg = get_smoke_config(arch).with_(sliding_window=WINDOW)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = model.example_batch(2, S, jax.random.PRNGKey(1),
                                dtype=jnp.float32)
    toks = batch["tokens"]
    full, _ = model.forward(params, batch)     # windowed mask in forward

    n_extra = 6
    prompt = {**batch, "tokens": toks[:, :S - n_extra]}
    last, cache = model.prefill(params, prompt, dtype=jnp.float32)
    # ring-buffer cache is capped at the window
    if "k" in cache:
        assert cache["k"].shape[2] == WINDOW
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, S - n_extra - 1]),
                               atol=5e-3, rtol=5e-3)
    for i in range(n_extra):
        pos = S - n_extra + i
        logits, cache = model.decode_step(params, toks[:, pos:pos + 1],
                                          cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, pos]),
                                   atol=5e-3, rtol=5e-3)


def test_window_restricts_attention():
    """Tokens beyond the window must not influence the output."""
    cfg = get_smoke_config("granite-8b").with_(sliding_window=8)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    key = jax.random.PRNGKey(2)
    t1 = jax.random.randint(key, (1, 32), 0, cfg.vocab_size, dtype=jnp.int32)
    # perturb a token far outside the window of the last position
    t2 = t1.at[0, 2].set((t1[0, 2] + 7) % cfg.vocab_size)
    f1, _ = model.forward(params, {"tokens": t1})
    f2, _ = model.forward(params, {"tokens": t2})
    # last position attends only to the final 8 tokens -> unchanged
    np.testing.assert_allclose(np.asarray(f1[:, -1]), np.asarray(f2[:, -1]),
                               atol=1e-5)
    # but position 3 (inside the perturbed token's window) changes
    assert float(jnp.max(jnp.abs(f1[:, 3] - f2[:, 3]))) > 1e-3
