"""Property-based tests for the paged KV allocator invariants."""
import pytest
from _hypothesis_compat import (RuleBasedStateMachine, given, invariant,
                                precondition, rule, settings, st)

from repro.serving.kv_manager import OutOfPagesError, PagedKVManager


def test_basic_alloc_free():
    m = PagedKVManager(num_pages=10, page_size=16)
    pages = m.allocate(1, 40)          # 3 pages
    assert len(pages) == 3
    assert m.free_pages == 7
    m.free(1)
    assert m.free_pages == 10
    m.check_invariants()


def test_append_grows_page():
    m = PagedKVManager(num_pages=4, page_size=4)
    m.allocate(1, 4)
    assert m.used_pages == 1
    for _ in range(4):
        m.append_token(1)
    assert m.used_pages == 2
    assert m.seq_tokens(1) == 8
    m.check_invariants()


def test_out_of_pages():
    m = PagedKVManager(num_pages=2, page_size=4)
    m.allocate(1, 8)
    with pytest.raises(OutOfPagesError):
        m.allocate(2, 1)
    with pytest.raises(OutOfPagesError):
        m.append_token(1)
    m.check_invariants()


def test_swap_out_in_roundtrip():
    m = PagedKVManager(num_pages=4, page_size=4)
    m.allocate(1, 10)
    assert m.used_pages == 3
    m.swap_out(1)
    assert m.free_pages == 4
    assert not m.has_seq(1)
    m.allocate(2, 16)
    with pytest.raises(OutOfPagesError):
        m.swap_in(1)
    m.free(2)
    pages = m.swap_in(1)
    assert len(pages) == 3
    assert m.seq_tokens(1) == 10
    m.check_invariants()


class KVStateMachine(RuleBasedStateMachine):
    """Random alloc/append/free/swap sequences never violate invariants."""

    def __init__(self):
        super().__init__()
        self.m = PagedKVManager(num_pages=32, page_size=4)
        self.live = set()
        self.on_host = set()
        self.next_id = 0

    @rule(n_tokens=st.integers(1, 40))
    def allocate(self, n_tokens):
        sid = self.next_id
        self.next_id += 1
        try:
            self.m.allocate(sid, n_tokens)
            self.live.add(sid)
        except OutOfPagesError:
            pass

    @precondition(lambda self: self.live - self.on_host)
    @rule(data=st.data())
    def append(self, data):
        sid = data.draw(st.sampled_from(sorted(self.live - self.on_host)))
        try:
            self.m.append_token(sid)
        except OutOfPagesError:
            pass

    @precondition(lambda self: self.live)
    @rule(data=st.data())
    def free(self, data):
        sid = data.draw(st.sampled_from(sorted(self.live)))
        self.m.free(sid)
        self.live.discard(sid)
        self.on_host.discard(sid)

    @precondition(lambda self: self.live - self.on_host)
    @rule(data=st.data())
    def swap_out(self, data):
        sid = data.draw(st.sampled_from(sorted(self.live - self.on_host)))
        self.m.swap_out(sid)
        self.on_host.add(sid)

    @precondition(lambda self: self.on_host)
    @rule(data=st.data())
    def swap_in(self, data):
        sid = data.draw(st.sampled_from(sorted(self.on_host)))
        try:
            self.m.swap_in(sid)
            self.on_host.discard(sid)
        except OutOfPagesError:
            pass

    @invariant()
    def invariants_hold(self):
        self.m.check_invariants()


TestKVStateMachine = KVStateMachine.TestCase
TestKVStateMachine.settings = settings(max_examples=30,
                                       stateful_step_count=40,
                                       deadline=None)
