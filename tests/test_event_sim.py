"""Event-driven simulator core: equivalence with the fixed-tick reference,
conservation invariants, and throughput scaling."""
import time

import pytest

from repro.serving.request import RequestState, RequestType
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController, LlumnixController
from repro.sim.metrics import decisions_match
from repro.sim.simulator import (default_perf_factory, simulate,
                                 simulate_events, simulate_fixed_tick)
from repro.sim.workload import WorkloadSpec, generate


def _cluster(max_chips=400):
    return SimCluster(default_perf_factory(), max_chips=max_chips)


def test_simulate_dispatches_engines():
    spec = WorkloadSpec(n_requests=50, arrival_rate=10.0, seed=2)
    res_e = simulate(generate(spec), ChironController(), _cluster(),
                     max_time=300, warm_start=1)
    res_f = simulate(generate(spec), ChironController(), _cluster(),
                     max_time=300, warm_start=1, engine="fixed")
    assert res_e.completion_rate() == res_f.completion_rate() == 1.0
    with pytest.raises(ValueError):
        simulate([], ChironController(), _cluster(), engine="nope")


def test_event_engine_conservation():
    spec = WorkloadSpec(n_requests=300, arrival_rate=20.0,
                        interactive_frac=0.7, batch_ttft_slo=600.0, seed=11)
    reqs = generate(spec)
    res = simulate_events(reqs, ChironController(), _cluster(),
                          max_time=1200, warm_start=2)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    for r in reqs:
        assert r.tokens_generated == r.output_len
        assert r.finish_time is not None and r.first_token_time is not None
        assert r.finish_time >= r.first_token_time >= r.arrival_time
    assert res.gpu_hours() > 0 and res.peak_chips > 0


def test_event_engine_llumnix_baseline_runs():
    spec = WorkloadSpec(n_requests=150, arrival_rate=10.0, seed=13)
    reqs = generate(spec)
    res = simulate_events(reqs, LlumnixController(), _cluster(200),
                          max_time=900, warm_start=2)
    assert res.completion_rate() == 1.0


def test_event_matches_fixed_tick_batch_scaling_decisions():
    """Same trace, same controller -> identical instance-count timeline
    within one control interval. Exercised on the Algorithm-2-driven arm
    (static batch size): global scaling decisions must be engine-invariant.
    The event engine runs in sparse fixed-tick mode (quantize=dt) so both
    engines batch arrivals and completions on the same grid."""
    spec = WorkloadSpec(n_requests=1, arrival_rate=1.0,
                        interactive_frac=0.0, batch_queue_size=6000,
                        batch_ttft_slo=900.0, seed=5)

    def ctrl():
        return ChironController(local_enabled=False, static_batch=64)
    res_e = simulate_events(generate(spec), ctrl(), _cluster(),
                            max_time=1500, quantize=0.25)
    res_f = simulate_fixed_tick(generate(spec), ctrl(), _cluster(),
                                dt=0.25, max_time=1500)
    frac, dev = decisions_match(res_e, res_f, interval=1.0,
                                slack_intervals=1)
    assert frac >= 0.9, (frac, dev)
    assert dev <= 1, dev
    # and the aggregate run statistics agree closely
    assert res_e.completion_rate() == res_f.completion_rate() == 1.0
    assert abs(res_e.duration - res_f.duration) <= \
        0.1 * max(res_f.duration, 1.0)


def test_event_aggregates_track_fixed_on_mixed_workload():
    """Full Chiron (local + global) has knife-edge feedback that amplifies
    tick-level noise, so per-tick counts can transiently differ — but the
    run-level outcomes must stay close across engines."""
    spec = WorkloadSpec(n_requests=400, arrival_rate=20.0,
                        interactive_frac=0.8, batch_queue_size=2000,
                        batch_ttft_slo=600.0, seed=17)
    res_e = simulate_events(generate(spec), ChironController(), _cluster(),
                            max_time=1500, warm_start=2, quantize=0.25)
    res_f = simulate_fixed_tick(generate(spec), ChironController(),
                                _cluster(), dt=0.25, max_time=1500,
                                warm_start=2)
    assert res_e.completion_rate() == res_f.completion_rate() == 1.0
    assert abs(res_e.duration - res_f.duration) <= \
        0.15 * max(res_f.duration, 1.0)
    assert abs(res_e.gpu_hours() - res_f.gpu_hours()) <= \
        0.3 * max(res_f.gpu_hours(), 1e-6)


def test_event_engine_not_slower_than_fixed_on_backlog():
    """Throughput regression guard: on a deadline-driven backlog the event
    core must beat the fixed-tick loop at dt=0.25."""
    def trace():
        return generate(WorkloadSpec(n_requests=200, arrival_rate=10.0,
                                     interactive_frac=1.0,
                                     batch_queue_size=12000,
                                     batch_ttft_slo=1200.0, seed=19))
    t0 = time.perf_counter()
    res_e = simulate_events(trace(), ChironController(), _cluster(),
                            max_time=1800, warm_start=2)
    wall_e = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_f = simulate_fixed_tick(trace(), ChironController(), _cluster(),
                                dt=0.25, max_time=1800, warm_start=2)
    wall_f = time.perf_counter() - t0
    assert res_e.completion_rate() == 1.0
    assert wall_e < wall_f, (wall_e, wall_f)


def test_idle_periods_cost_no_events():
    """A long dead gap between two request groups must not blow up the
    timeline or the wall time: control parks while quiescent."""
    reqs = generate(WorkloadSpec(n_requests=50, arrival_rate=10.0, seed=23))
    late = generate(WorkloadSpec(n_requests=50, arrival_rate=10.0, seed=24))
    for r in late:
        r.arrival_time += 3000.0
    allr = sorted(reqs + late, key=lambda r: r.arrival_time)
    t0 = time.perf_counter()
    res = simulate_events(allr, ChironController(), _cluster(200),
                          max_time=7200, warm_start=1)
    wall = time.perf_counter() - t0
    assert res.completion_rate() == 1.0
    assert res.duration > 3000.0
    assert wall < 5.0, f"idle gap cost {wall:.1f}s wall"
