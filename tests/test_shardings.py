"""Sharding rules: spec validity (in-process) + an 8-fake-device execution
check (subprocess, so the device-count flag can't leak into other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.shardings import param_spec
from repro.launch.steps import input_specs, resolve_config


def test_param_spec_divisibility_fallback():
    # vocab 50280 (mamba2) is not divisible by 16 -> replicated
    spec = param_spec(("emb", "tok"), (50280, 2048), 16)
    assert all(s is None for s in spec)
    spec = param_spec(("emb", "tok"), (50304, 2048), 16)
    assert spec[0] == "model"


def test_param_spec_moe_f_sharded():
    # f-sharded TP is preferred (uniform with the shard_map expert block,
    # §Perf A4); expert-parallel is the fallback when f doesn't divide
    spec = param_spec(("layers", "moe", "w_gate"), (24, 60, 2048, 1408), 16)
    assert spec[1] is None and spec[3] == "model"
    spec = param_spec(("layers", "moe", "w_down"), (28, 64, 1408, 2048), 16)
    assert spec[2] == "model"
    # f not divisible -> expert parallel fallback
    spec = param_spec(("layers", "moe", "w_gate"), (24, 64, 2048, 1000), 16)
    assert spec[1] == "model"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_input_specs_build(arch, shape):
    """Every (arch x shape) produces well-formed ShapeDtypeStructs without
    touching devices."""
    sp = INPUT_SHAPES[shape]
    cfg = resolve_config(get_config(arch), sp)
    specs = input_specs(cfg, sp)
    assert "params" in specs
    if sp.kind == "train":
        assert specs["batch"]["tokens"].shape == (sp.global_batch, sp.seq_len)
    elif sp.kind == "decode":
        assert specs["tokens"].shape == (sp.global_batch, 1)
        assert "cache" in specs


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.launch import shardings as sh
    from repro.models import Model

    cfg = get_smoke_config("granite-8b").with_(vocab_size=512)
    model = Model(cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = model.example_batch(4, 32, jax.random.PRNGKey(1),
                                dtype=jnp.float32)
    ref_logits, _ = model.forward(params, batch)

    p_spec = jax.eval_shape(lambda: params)
    p_sh = sh.param_shardings(mesh, p_spec)
    b_sh = sh.batch_shardings(mesh, jax.eval_shape(lambda: batch))
    with mesh:
        f = jax.jit(lambda p, b: model.forward(p, b)[0],
                    in_shardings=(p_sh, b_sh))
        out = f(params, batch)
    err = float(jnp.max(jnp.abs(out - ref_logits)))
    print(json.dumps({"err": err, "n_dev": len(jax.devices())}))
""")


def test_sharded_forward_matches_single_device():
    """Run the same smoke model on a (2,4) mesh of 8 host devices; the
    sharded result must match the unsharded one."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_dev"] == 8
    assert rec["err"] < 2e-3, rec
