"""Invariant auditor: fixture violations for every rule, clean negatives,
and the suppression grammar — plus the gate that the repo's own tree is
clean (``python -m repro.analysis src`` exits 0).

Fixtures run through :func:`repro.analysis.analyze_code` with synthetic
paths: paths outside the ``repro`` package get the full rule set, so the
mirror rules are testable without writing files into ``src/``.
"""
import json
import os
import subprocess
import sys
import textwrap

from repro.analysis import analyze_code, run_analysis

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rules(findings):
    return [(f.rule, f.line) for f in findings]


def _analyze(code, path="fixture.py", rules=None):
    return analyze_code(textwrap.dedent(code), path=path, rules=rules)


# ------------------------------------------------------------ MIR101/102
def test_mir101_unsynced_request_state_write_flagged():
    findings = _analyze("""\
        def finish(req, t):
            req.state = RequestState.FINISHED
            req.finish_time = t
    """)
    assert ("MIR101", 2) in _rules(findings)
    assert ("MIR101", 3) in _rules(findings)


def test_mir101_paired_ledger_write_is_clean():
    findings = _analyze("""\
        def finish(req, led, t):
            req.state = RequestState.FINISHED
            led.state[req.row] = FINISHED
            req.finish_time = t
            led.finish_time[req.row] = t
    """)
    assert not [f for f in findings if f.rule == "MIR101"]


def test_mir101_instance_state_write_not_confused_with_request():
    # `state` is also an InstanceState attribute — only RequestState
    # writes are the Request mirror
    findings = _analyze("""\
        def activate(inst):
            inst.state = InstanceState.ACTIVE
    """)
    assert not findings


def test_mir102_plane_scalar_write_flagged_and_sync_clears_it():
    flagged = _analyze("""\
        def grow(self):
            self._n_dec += 1
    """)
    assert _rules(flagged) == [("MIR102", 2)]
    clean = _analyze("""\
        def grow(self):
            self._n_dec += 1
            self._sync_plane()
    """)
    assert not clean


def test_mir102_container_write_needs_sync():
    flagged = _analyze("""\
        def swap(self, i, seq):
            self.running[i] = seq
    """)
    assert _rules(flagged) == [("MIR102", 2)]
    clean = _analyze("""\
        def swap(self, i, seq):
            self.running[i] = seq
            self._sync_plane()
    """)
    assert not clean


# ---------------------------------------------------------------- MIR104
def test_mir104_terminal_write_needs_matching_terminal_column():
    # a FINISHED column write satisfies MIR101's pairing but not MIR104's
    # same-terminal requirement: the object says REJECTED, the column says
    # FINISHED — the overload accounting identity would silently drift
    findings = _analyze("""\
        def refuse(req, led):
            req.state = RequestState.REJECTED
            led.state[req.row] = FINISHED
    """)
    assert ("MIR104", 2) in _rules(findings)
    assert not [f for f in findings if f.rule == "MIR101"]


def test_mir104_paired_terminal_write_is_clean():
    for term in ("REJECTED", "SHED", "EXPIRED", "FINISHED"):
        findings = _analyze(f"""\
            def drop(req, led):
                req.state = RequestState.{term}
                led.state[req.row] = {term}
        """)
        assert not [f for f in findings if f.rule == "MIR104"]


def test_mir104_suppression_comment():
    findings = _analyze("""\
        def refuse(req, led):
            req.state = RequestState.SHED  # mirror-sync: ok(test)
            led.state[req.row] = FINISHED
    """)
    assert not [f for f in findings if f.rule == "MIR104"]


def test_mir_rules_scoped_to_sim_and_serving():
    code = """\
        def finish(req):
            req.state = RequestState.FINISHED
    """
    assert _analyze(code, path="src/repro/sim/cluster.py")
    assert _analyze(code, path="src/repro/serving/engine.py")
    # elsewhere in the package the mirrors don't exist
    assert not _analyze(code, path="src/repro/launch/serve.py")


def test_init_functions_exempt_from_mirror_pairing():
    findings = _analyze("""\
        def __init__(self):
            self.active = False
    """)
    assert not findings


# ------------------------------------------------------------- DET201/202
def test_det201_unseeded_rng_flagged_seeded_clean():
    findings = _analyze("""\
        import random
        import numpy as np

        def jitter():
            a = random.random()
            b = np.random.rand(4)
            rng = np.random.default_rng(0)
            c = rng.random()
            d = random.Random(3).random()
            return a, b, c, d
    """)
    assert [(f.rule, f.line) for f in findings if f.rule == "DET201"] \
        == [("DET201", 5), ("DET201", 6)]


def test_det202_wall_clock_flagged_outside_exempt_dirs():
    code = """\
        import time

        def stamp():
            return time.time()
    """
    assert _rules(_analyze(code, path="src/repro/sim/foo.py")) \
        == [("DET202", 4)]
    assert not _analyze(code, path="scripts/foo.py")
    assert not _analyze(code, path="benchmarks/foo.py")


# --------------------------------------------------------------- DET203
def test_det203_set_iteration_flagged_sorted_clean():
    findings = _analyze("""\
        def review(a, b):
            for k in set(a) | set(b):
                print(k)
            for k in sorted(set(a) | set(b)):
                print(k)
            out = [x for x in {1, 2, 3}]
            return out
    """)
    assert [(f.rule, f.line) for f in findings if f.rule == "DET203"] \
        == [("DET203", 2), ("DET203", 6)]


# --------------------------------------------------------------- DET204
def test_det204_heap_keys_need_total_order_tiebreaker():
    findings = _analyze("""\
        import heapq

        def push(heap, t, inst, seq):
            heapq.heappush(heap, inst)
            heapq.heappush(heap, (t, inst))
            heapq.heappush(heap, (t, next(seq), inst))
            heapq.heappush(heap, (t, inst._epoch, inst))
    """)
    assert [(f.rule, f.line) for f in findings if f.rule == "DET204"] \
        == [("DET204", 4), ("DET204", 5)]


# --------------------------------------------------------------- DET205
def test_det205_raw_event_time_compare_flagged_epsilon_clean():
    findings = _analyze("""\
        def poll(inst, now):
            if inst.ready_time <= now:
                fire(inst)
            if inst.ready_time <= now + 1e-9:
                fire(inst)
            if inst.ready_time != now:
                pass
    """)
    assert [(f.rule, f.line) for f in findings if f.rule == "DET205"] \
        == [("DET205", 2)]


# ------------------------------------------------------------- LINT301/302
def test_lint301_unused_import_flagged_used_clean():
    findings = _analyze("""\
        import os
        import sys
        from math import ceil, floor

        def up(x):
            return ceil(x), sys.argv
    """)
    assert [(f.rule, f.line, f.message) for f in findings
            if f.rule == "LINT301"] \
        == [("LINT301", 1, "`os` is imported but never used"),
            ("LINT301", 3, "`floor` is imported but never used")]


def test_lint301_skips_init_py_reexports():
    assert not _analyze("import os\n", path="pkg/__init__.py")


def test_lint302_mutable_default_flagged_none_clean():
    findings = _analyze("""\
        def push(x, acc=[]):
            acc.append(x)
            return acc

        def safe(x, acc=None):
            return acc
    """)
    assert [(f.rule, f.line) for f in findings if f.rule == "LINT302"] \
        == [("LINT302", 1)]


# ---------------------------------------------------------- suppressions
def test_line_suppression_mirror_and_lint():
    findings = _analyze("""\
        def finish(req, t):
            req.state = RequestState.FINISHED  # mirror-sync: ok(test)
            req.finish_time = t
    """)
    assert _rules(findings) == [("MIR101", 3)]


def test_standalone_comment_suppression_covers_next_line():
    findings = _analyze("""\
        def poll(inst, now):
            # repro-lint: ok(DET205, clamped at call sites)
            if inst.ready_time <= now:
                fire(inst)
    """)
    assert not findings


def test_def_line_suppression_covers_whole_function():
    findings = _analyze("""\
        def finish(req, t):  # mirror-sync: ok(caller settles the ledger)
            req.state = RequestState.FINISHED
            req.finish_time = t
    """)
    assert not findings


def test_module_pragma_exempts_all_mirror_rules():
    findings = _analyze("""\
        # mirror-sync: module ok(no columnar mirrors in this module)
        def finish(req, t):
            req.state = RequestState.FINISHED
            req.finish_time = t
    """)
    assert not findings


def test_lint_suppression_is_rule_specific():
    findings = _analyze("""\
        def poll(inst, now):
            # repro-lint: ok(DET201, wrong rule id)
            if inst.ready_time <= now:
                fire(inst)
    """)
    assert _rules(findings) == [("DET205", 3)]


# -------------------------------------------------------- rule filtering
def test_rules_filter_selects_by_prefix():
    code = """\
        import os

        def finish(req):
            req.state = RequestState.FINISHED
    """
    only_mir = _analyze(code, rules=["MIR"])
    # the bare terminal write trips both the pairing rule (MIR101) and
    # the same-terminal rule (MIR104)
    assert {f.rule for f in only_mir} == {"MIR101", "MIR104"}
    only_lint = _analyze(code, rules=["LINT301"])
    assert {f.rule for f in only_lint} == {"LINT301"}


# -------------------------------------------------- the repo's own tree
def test_repo_tree_is_clean():
    findings = run_analysis([os.path.join(REPO, "src")])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_json_output_and_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import os\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad), "--json"],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc[0]["rule"] == "LINT301" and doc[0]["line"] == 1

    good = tmp_path / "good.py"
    good.write_text("X = 1\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(good)],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0
