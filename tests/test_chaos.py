"""Correlated-failure chaos plane: zone outages with staged capacity
return, flash-crowd demand shocks, noisy slow-node detection, injection
hardening (empty-fleet victim slots), recovery metrics, and the tenant
column's end-to-end round trip."""
import numpy as np
import pytest

from repro.sim.cluster import (SLOW_SUSPECT_RATIO, DetectorConfig,
                               InstanceType, SimCluster)
from repro.sim.controllers import ChironController
from repro.sim.ledger import RequestLedger
from repro.sim.scenarios import build_trace
from repro.sim.simulator import (FailurePlan, FlashCrowdPlan, OutagePlan,
                                 default_perf_factory, simulate_events,
                                 simulate_fleet)
from repro.sim.trace_io import load_trace, save_trace
from repro.sim.workload import Trace, make_trace

MODEL = "llama-8b"


def _cluster(max_chips=400):
    return SimCluster(default_perf_factory(), max_chips=max_chips)


def _run_single(name, seed=0, *, n=2000, telemetry=None,
                shadow_verify=None):
    trace, kw = build_trace(name, n_requests=n, seed=seed)
    ctrl = ChironController(models=kw["models"]) if "models" in kw \
        else ChironController()
    return simulate_events(trace, ctrl, _cluster(), max_time=kw["max_time"],
                           warm_start=2, outages=kw.get("outages"),
                           flash_crowds=kw.get("flash_crowds"),
                           telemetry=telemetry, shadow_verify=shadow_verify)


def _fingerprint(res):
    return (res.scale_ups, res.scale_downs, res.peak_chips, res.n_events,
            res.failures, res.skipped_injections, res.duration,
            res.chip_seconds, tuple(res.shocks),
            tuple((p.t, p.n_interactive, p.n_mixed, p.n_batch, p.chips,
                   p.q_interactive, p.q_batch) for p in res.timeline))


def _steady_trace(n=300, rate=12.0, seed=0, t0=0.0):
    rng = np.random.default_rng(seed)
    times = t0 + np.cumsum(rng.exponential(1.0 / rate, n))
    ins = np.full(n, 100, dtype=np.int64)
    outs = np.full(n, 60, dtype=np.int64)
    return make_trace(times, ins, outs, np.ones(n, dtype=bool))


# ------------------------------------------------------------ zone outage
def test_zone_outage_single_engine_dips_and_recovers():
    res = _run_single("zone_outage", seed=0, n=3000)
    assert res.failures > 0                    # victims crashed at once
    assert res.skipped_injections == 0
    (shock,) = res.shocks
    assert shock.kind == "outage"
    (rec,) = res.recovery_metrics()
    assert rec["max_attainment_dip"] > 0.05    # the outage visibly hurts
    assert rec["time_to_detect_s"] >= 0.0      # re-provisioning observed
    assert rec["time_to_recover_s"] >= 0.0     # ...and attainment returns
    assert rec["time_to_recover_s"] != -1.0
    # per-tenant attainment is reported during the shock window
    assert set(rec["window_by_tenant"]) == {"acme", "globex"}


def test_zone_outage_fleet_reprovisions_within_horizon():
    trace, kw = build_trace("zone_outage", n_requests=3000, seed=0)
    res = simulate_fleet(trace, kw["fleet"](), max_time=kw["max_time"],
                         warm_start=1, outages=kw["outages"],
                         telemetry=True)
    assert res.failures > 0
    (rec,) = res.recovery_metrics()
    assert rec["time_to_detect_s"] >= 0.0
    assert rec["time_to_recover_s"] >= 0.0
    rep = res.telemetry.replay()
    assert rep["outages"] == 1
    assert rep["restores"] == kw["outages"].recovery_stages
    # the surviving cluster keeps interactive attainment usable
    assert rec["window_attainment"] > 0.5


def test_outage_withholds_and_restores_capacity_in_stages():
    trace = _steady_trace(400, rate=10.0, seed=1)
    span = trace.duration
    plan = OutagePlan(start=0.3 * span, duration=0.15 * span,
                      recovery_stages=3, stage_interval=5.0, seed=1)
    cluster = _cluster(max_chips=200)
    res = simulate_events(trace, ChironController(), cluster,
                          max_time=span + 600.0, warm_start=2,
                          outages=plan)
    # every withheld tranche came back: full budget restored by run end
    assert cluster.max_chips == 200
    assert res.completion_rate() == 1.0


def test_outage_unknown_fleet_cluster_raises():
    trace, kw = build_trace("zone_outage", n_requests=500, seed=0,
                            victim="not-a-zone")
    with pytest.raises(ValueError, match="not-a-zone"):
        simulate_fleet(trace, kw["fleet"](), max_time=kw["max_time"],
                       outages=kw["outages"])


# ------------------------------------------------------------ flash crowd
def test_flash_crowd_single_engine_discovers_model():
    res = _run_single("flash_crowd", seed=0, n=3000)
    (shock,) = res.shocks
    assert shock.kind == "flash_crowd" and shock.label == "llama-70b"
    by_model = res.slo_by_model()
    assert "llama-70b" in by_model             # the crowd got served
    (rec,) = res.recovery_metrics()
    assert rec["time_to_recover_s"] >= 0.0     # recovered (or never dipped)
    assert res.completion_rate() > 0.95


def test_flash_crowd_fleet_engine_serves_crowd():
    from repro.sim.fleet import ClusterSpec, Fleet, FleetTopology
    trace, kw = build_trace("flash_crowd", n_requests=2500, seed=1)
    fleet = Fleet([ClusterSpec("us-a", "us", max_chips=200),
                   ClusterSpec("us-b", "us", max_chips=200)],
                  FleetTopology(("us",)),
                  models=("llama-8b", "llama-70b"))
    res = simulate_fleet(trace, fleet, max_time=kw["max_time"],
                         warm_start=1, flash_crowds=kw["flash_crowds"],
                         telemetry=True)
    assert "llama-70b" in res.slo_by_model()
    assert res.telemetry.replay()["flash_crowds"] == 1
    (rec,) = res.recovery_metrics()
    assert rec["kind"] == "flash_crowd"


def test_flash_crowd_arrivals_ramp_then_plateau():
    plan = FlashCrowdPlan(start=100.0, ramp=60.0, duration=300.0,
                          peak_rate=10.0, seed=3)
    times = plan.arrival_times()
    assert np.array_equal(times, plan.arrival_times())   # seeded
    assert float(times.min()) >= plan.start
    assert float(times.max()) <= plan.end_time() + 1e-9
    # the ramp's first half carries fewer arrivals than the same-width
    # plateau slice (rate climbs zero -> peak across the ramp)
    first = np.count_nonzero(times < plan.start + 30.0)
    mid = np.count_nonzero((times >= plan.start + 120.0)
                           & (times < plan.start + 150.0))
    assert first < mid


# ------------------------------------------- injection hardening (draws)
def test_failure_on_empty_fleet_is_skipped_not_shifted():
    trace = _steady_trace(200, rate=10.0, seed=2, t0=50.0)
    plan = FailurePlan(times=[1.0, 60.0], seed=9)
    res = simulate_events(trace, ChironController(), _cluster(),
                          max_time=trace.duration + 600.0, warm_start=0,
                          failures=plan)
    # t=1.0 fires before any instance exists -> counted, not crashed
    assert res.skipped_injections == 1
    assert res.failures == 1


def test_chaos_runs_are_seed_deterministic():
    a = _run_single("zone_outage", seed=4, n=1200)
    b = _run_single("zone_outage", seed=4, n=1200)
    assert _fingerprint(a) == _fingerprint(b)


# ------------------------------------ telemetry / shadow decision parity
@pytest.mark.parametrize("scenario", ["zone_outage", "flash_crowd"])
def test_chaos_telemetry_shadow_bit_identical(scenario):
    off = _run_single(scenario, seed=2, n=1200)
    on = _run_single(scenario, seed=2, n=1200, telemetry=True,
                     shadow_verify=True)
    assert off.telemetry is None and on.telemetry is not None
    assert _fingerprint(off) == _fingerprint(on)


# ----------------------------------------------------- noisy detection
def _active_instance(cluster):
    inst = cluster.provision(MODEL, InstanceType.MIXED, 0.0, static_batch=8)
    inst.ready_time = 0.0
    inst.activate_if_ready(0.0)
    return inst


def test_detector_flags_slow_instance_from_samples():
    cluster = _cluster(40)
    inst = _active_instance(cluster)
    for _ in range(3):                        # warm the window healthy
        inst.update_health()
    assert not inst.suspected_slow
    inst.slow_factor = 4.0
    ticks = 0
    while not inst.suspected_slow and ticks < 10:
        inst.update_health()
        ticks += 1
    assert inst.suspected_slow and ticks <= 6
    inst.slow_factor = 1.0
    for _ in range(10):
        inst.update_health()
    assert not inst.suspected_slow             # clears after recovery


def test_detector_noise_perturbs_observations():
    """Detection runs on noisy observed samples, not the fluid-exact
    ratio: with noise on, the EWMA never equals the true slow factor."""
    noisy = _cluster(40)
    noisy.detector = DetectorConfig(window=1, noise=0.3, seed=5)
    exact = _cluster(40)
    exact.detector = DetectorConfig(window=1, noise=0.0)
    a, b = _active_instance(noisy), _active_instance(exact)
    a.slow_factor = b.slow_factor = 4.0
    for _ in range(8):
        a.update_health()
        b.update_health()
    assert a.suspected_slow and b.suspected_slow
    assert a.health_ewma != pytest.approx(b.health_ewma, abs=1e-6)


def test_detector_false_positive_and_negative_knobs():
    fp = _cluster(40)
    fp.detector = DetectorConfig(window=1, fp_rate=1.0, noise=0.0)
    healthy = _active_instance(fp)
    for _ in range(8):
        healthy.update_health()
    assert healthy.suspected_slow              # every sample a false alarm

    fn = _cluster(40)
    fn.detector = DetectorConfig(window=1, fn_rate=1.0, noise=0.0)
    slow = _active_instance(fn)
    slow.slow_factor = 8.0
    for _ in range(8):
        slow.update_health()
    assert not slow.suspected_slow             # every sample masked


def test_detector_config_threads_through_engine():
    trace = _steady_trace(150, rate=8.0, seed=3)
    det = DetectorConfig(window=3, alpha=0.7, noise=0.2, seed=11)
    res = simulate_events(trace, ChironController(), _cluster(),
                          max_time=trace.duration + 600.0, warm_start=1,
                          detector=det)
    assert res.completion_rate() == 1.0


def test_detector_median_suppresses_single_outlier():
    """One bad sample in a window of healthy ones must not quarantine the
    instance — the median statistic absorbs isolated outliers."""
    cluster = _cluster(40)
    cluster.detector = DetectorConfig(window=5, noise=0.0)
    inst = _active_instance(cluster)
    for _ in range(5):                         # warm window, all healthy
        inst.update_health()
    inst.slow_factor = 6.0                     # one-tick transient blip
    inst.update_health()
    inst.slow_factor = 1.0
    for _ in range(4):
        inst.update_health()
        assert not inst.suspected_slow         # median-of-5 holds the line


# --------------------------------------------------------- tenant column
def _tenant_trace(n=40, seed=0):
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(0.2, n))
    ins = np.full(n, 64, dtype=np.int64)
    outs = np.full(n, 32, dtype=np.int64)
    tidx = (rng.random(n) < 0.4).astype(np.int32)
    return make_trace(times, ins, outs, np.ones(n, dtype=bool),
                      tenant_idx=tidx, tenants=("acme", "globex"))


@pytest.mark.parametrize("ext", ["csv", "jsonl"])
def test_tenant_column_roundtrips_through_trace_io(tmp_path, ext):
    tr = _tenant_trace()
    path = str(tmp_path / f"t.{ext}")
    save_trace(tr, path)
    back = load_trace(path)
    names = [tr.tenants[i] for i in tr.tenant_idx]
    names_back = [back.tenants[i] for i in back.tenant_idx]
    assert names_back == names
    assert set(back.tenants) == {"acme", "globex"}


def test_tenantless_trace_io_omits_column(tmp_path):
    tr = _steady_trace(20)
    path = str(tmp_path / "t.csv")
    save_trace(tr, path)
    with open(path) as f:
        assert "tenant" not in f.readline()
    assert load_trace(path).tenants == ()


def test_tenant_column_concat_and_ledger():
    a = _tenant_trace(20, seed=1)
    b = _steady_trace(10)                      # tenant-less folds in as ""
    merged = Trace.concat([a, b])
    assert "acme" in merged.tenants and "" in merged.tenants
    led = RequestLedger.from_trace(a)
    assert led.tenants == ("acme", "globex")
    assert np.array_equal(led.tenant_idx, a.tenant_idx)
    # materialized requests carry the tenant name
    reqs = a.materialize()
    assert [r.tenant for r in reqs] == [a.tenants[i] for i in a.tenant_idx]
    assert b.materialize()[0].tenant is None
