"""Columnar queue plane: lane mechanics and reference equivalence.

Complements ``test_global_queue.py`` (service-order API behaviour) with
the struct-of-arrays internals introduced by the queue-plane refactor:
amortized-doubling column growth, head compaction, ``push_front``
headroom regrow, resume-lane priority, listener notification on the
overflow/resume paths, and a randomized operation-level differential
against :class:`ReferenceGlobalQueue` (the object flavour whose pop
order the columnar plane must reproduce bit-for-bit).
"""
import random

from repro.analysis.shadow import ShadowVerifier
from repro.serving.global_queue import (_LANE_CAP0, GlobalQueue,
                                        ReferenceGlobalQueue)
from repro.serving.request import make_batch, make_interactive


# ------------------------------------------------------- lane mechanics
def test_lane_growth_doubles_capacity_and_preserves_fifo():
    """Pushing past the preallocated capacity regrows the columns in
    place; FIFO order and the key-column mirrors survive every regrow."""
    q = GlobalQueue()
    reqs = [make_interactive(10, 10, arrival=float(i))
            for i in range(3 * _LANE_CAP0)]
    for i, r in enumerate(reqs):
        r.row = i                       # give the row mirror a live value
        q.push(r)
    lane = q._ilanes["llama-8b"]
    assert lane.cap >= 3 * _LANE_CAP0        # amortized doubling happened
    assert lane.cap % _LANE_CAP0 == 0
    for i in range(lane.head, lane.tail):    # columns mirror payloads
        r = lane.req_objs[i]
        assert lane.arrival[i] == r.arrival_time
        assert lane.deadline[i] == r.deadline
        assert lane.row[i] == r.row
    assert [q.pop_interactive() for _ in range(len(reqs))] == reqs
    assert q.pop_interactive() is None
    assert q.n_interactive == 0


def test_lane_regrow_compacts_drained_head():
    """A push at full capacity with a drained head compacts the live
    window back to offset 0 instead of doubling."""
    q = GlobalQueue()
    first = [make_interactive(10, 10, arrival=float(i))
             for i in range(_LANE_CAP0)]
    for r in first:
        q.push(r)
    half = _LANE_CAP0 // 2
    for r in first[:half]:
        assert q.pop_interactive() is r
    lane = q._ilanes["llama-8b"]
    assert lane.head == half and lane.tail == _LANE_CAP0
    extra = make_interactive(10, 10, arrival=99.0)
    q.push(extra)                       # tail == cap: compacting regrow
    assert lane.cap == _LANE_CAP0       # live + gap still fits: no double
    assert lane.head == 0 and lane.tail == half + 1
    rest = [q.pop_interactive() for _ in range(half + 1)]
    assert rest == first[half:] + [extra]


def test_front_requeue_regrows_with_headroom_and_pops_lifo():
    """``push_front`` at head 0 regrows with front headroom; preempted
    entries pop most-recent-first ahead of the whole FIFO."""
    q = GlobalQueue()
    base = make_interactive(10, 10, arrival=0.0)
    q.push(base)
    victims = [make_interactive(10, 10, arrival=float(i + 1))
               for i in range(10)]
    for v in victims:                   # each front push lands at head-1
        q.requeue(v)
    lane = q._ilanes["llama-8b"]
    assert lane.tail - lane.head == 11
    assert lane.seq[lane.head] < 0      # front stamps count downward
    got = [q.pop_interactive() for _ in range(11)]
    assert got == victims[::-1] + [base]


def test_front_requeue_beats_other_models_in_global_order():
    """Front stamps are negative, so a preempted request outranks every
    ordinary arrival in the cross-lane min-seq pick — not just its own
    model's lane."""
    q = GlobalQueue()
    other = make_interactive(10, 10, arrival=0.0, model="m-b")
    q.push(other)                       # seq 0, queued first
    mine = make_interactive(10, 10, arrival=1.0, model="m-a")
    q.push(mine)
    assert q.pop_interactive("m-a") is mine
    q.requeue(mine)                     # preempted: front stamp -1
    assert q.pop_interactive() is mine  # outranks the earlier arrival
    assert q.pop_interactive() is other


def test_resume_lane_priority_across_models():
    """Saved-KV requeues serve before any fresh batch work — even an
    urgent-deadline request of another model — and FIFO among
    themselves; per-model pops keep ignoring other models' resumes."""
    q = GlobalQueue()
    urgent = make_batch(10, 10, arrival=0.0, model="m-a", ttft_slo=10.0)
    q.push(urgent)
    resumes = []
    for i in range(2):
        r = make_batch(10, 10, arrival=5.0 + i, model="m-b",
                       ttft_slo=1000.0)
        r.saved_kv = ("sim", 64.0)
        q.requeue(r)
        resumes.append(r)
    assert q.n_batch_for("m-b") == 2
    assert set(q.batch_models()) == {"m-a", "m-b"}
    assert q.pop_batch_fcfs("m-a") is urgent     # filtered: no m-b resume
    q.push(urgent)
    assert [q.pop_batch_fcfs() for _ in range(3)] == resumes + [urgent]


def test_listener_sees_overflow_and_resume_paths():
    """Adds/removes fire on the overflow-heap path (an out-of-order
    arrival that cannot extend a lane) and the resume path, and a
    model-filtered listener only hears its model."""
    q = GlobalQueue()
    late_deadline = make_batch(10, 10, arrival=10.0, ttft_slo=500.0)
    q.push(late_deadline)               # deadline 510
    early_deadline = make_batch(10, 10, arrival=0.0, ttft_slo=500.0)
    q.push(early_deadline)              # deadline 500: sorts before the
                                        # same-class lane tail → overflow
    assert q._boflow["llama-8b"]        # really took the heap path
    resume = make_batch(10, 10, arrival=1.0, model="m-b", ttft_slo=500.0)
    resume.saved_kv = ("sim", 8.0)

    events = []

    class L:
        def __init__(self, tag):
            self.tag = tag

        def on_add(self, r):
            events.append(("add", self.tag, r))

        def on_remove(self, r):
            events.append(("rm", self.tag, r))

    q.attach_batch_listener(L("all"))   # replays in service order
    assert events == [("add", "all", early_deadline),
                      ("add", "all", late_deadline)]
    events.clear()
    q.attach_batch_listener(L("b"), model="m-b")   # nothing to replay
    assert events == []
    q.requeue(resume)
    assert events == [("add", "all", resume), ("add", "b", resume)]
    events.clear()
    assert q.pop_batch_fcfs() is resume
    assert q.pop_batch_fcfs() is early_deadline    # heap pop notifies too
    assert q.pop_batch_fcfs() is late_deadline
    assert [e for e in events if e[1] == "b"] == [("rm", "b", resume)]
    assert [e[2] for e in events if e[1] == "all"] == \
        [resume, early_deadline, late_deadline]


# ------------------------------------------- reference differential test
def _random_request(rng: random.Random, i: int):
    model = rng.choice(("m-a", "m-b", "m-c"))
    arrival = i * 0.25
    if rng.random() < 0.5:
        return make_interactive(16, 8, arrival=arrival, model=model)
    return make_batch(16, 8, arrival=arrival, model=model,
                      ttft_slo=rng.choice((50.0, 100.0, 500.0)))


def test_random_ops_match_reference_queue():
    """Operation-level differential: a seeded adversarial mix of pushes,
    filtered/unfiltered pops, front requeues, and resume requeues must
    return identical objects from the columnar plane and the object
    reference, with the shadow verifier's column rebuild passing
    throughout."""
    rng = random.Random(1234)
    q, ref = GlobalQueue(), ReferenceGlobalQueue()
    verifier = ShadowVerifier()
    popped = []
    n_made = 0
    for step in range(2000):
        roll = rng.random()
        if roll < 0.45:
            r = _random_request(rng, n_made)
            n_made += 1
            q.push(r)
            ref.push(r)
        elif roll < 0.65:
            model = rng.choice((None, "m-a", "m-b", "m-c"))
            a, b = q.pop_interactive(model), ref.pop_interactive(model)
            assert a is b, step
            if a is not None:
                popped.append(a)
        elif roll < 0.85:
            model = rng.choice((None, "m-a", "m-b", "m-c"))
            a, b = q.pop_batch_fcfs(model), ref.pop_batch_fcfs(model)
            assert a is b, step
            if a is not None:
                popped.append(a)
        elif popped:
            r = popped.pop(rng.randrange(len(popped)))
            if r.request_type.value == "batch" and rng.random() < 0.5:
                r.saved_kv = ("sim", 32.0)
            q.requeue(r)
            ref.requeue(r)
        assert len(q) == len(ref)
        assert q.n_interactive == ref.n_interactive
        assert q.n_batch == ref.n_batch
        if step % 100 == 0:
            verifier.verify_queue(q)
            assert q.interactive == ref.interactive
            # the flat batch views agree per model (the cross-model
            # concatenation order is a debug-view artifact: the
            # reference sorts globally, the plane groups by model)
            qb, rb = q.batch, ref.batch
            assert sorted(map(id, qb)) == sorted(map(id, rb))
            for m in ("m-a", "m-b", "m-c"):
                assert [r for r in qb if r.model == m] == \
                    [r for r in rb if r.model == m]
    assert verifier.queue_checks > 0
    # full drain must agree to the last element
    while True:
        a, b = q.pop_interactive(), ref.pop_interactive()
        assert a is b
        if a is None:
            break
    while True:
        a, b = q.pop_batch_fcfs(), ref.pop_batch_fcfs()
        assert a is b
        if a is None:
            break
    assert len(q) == len(ref) == 0


def test_drain_model_matches_reference_and_empties_lanes():
    rng = random.Random(7)
    q, ref = GlobalQueue(), ReferenceGlobalQueue()
    reqs = [_random_request(rng, i) for i in range(300)]
    for r in reqs:
        q.push(r)
        ref.push(r)
    for model in ("m-a", "m-b", "m-c"):
        assert q.drain_model(model) == ref.drain_model(model)
    assert len(q) == len(ref) == 0
    assert q.audit_counts() == (0, 0)
