"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles,
swept over shapes/dtypes (+ hypothesis for ragged lengths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------- paged attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,n_kv,group,D,page,max_pages", [
    (2, 2, 4, 128, 16, 4),
    (4, 1, 8, 128, 16, 8),
    (1, 4, 1, 256, 8, 16),
])
def test_paged_attention_sweep(dtype, B, n_kv, group, D, page, max_pages):
    ks = jax.random.split(KEY, 5)
    num_pages = max_pages * B + 1
    q = jax.random.normal(ks[0], (B, n_kv, group, D)).astype(dtype)
    kp = jax.random.normal(ks[1], (num_pages, page, n_kv, D)).astype(dtype)
    vp = jax.random.normal(ks[2], (num_pages, page, n_kv, D)).astype(dtype)
    bt = jax.random.randint(ks[3], (B, max_pages), 0, num_pages,
                            dtype=jnp.int32)
    lengths = jax.random.randint(ks[4], (B,), 1, max_pages * page + 1,
                                 dtype=jnp.int32)
    out_k = ops.paged_attention(q, kp, vp, bt, lengths, page_size=page,
                                backend="interpret")
    out_r = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(lengths=st.lists(st.integers(1, 64), min_size=3, max_size=3))
def test_paged_attention_ragged_lengths(lengths):
    B, n_kv, group, D, page = 3, 2, 2, 128, 16
    max_pages = 4
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, n_kv, group, D))
    kp = jax.random.normal(ks[1], (32, page, n_kv, D))
    vp = jax.random.normal(ks[2], (32, page, n_kv, D))
    bt = jax.random.randint(ks[3], (B, max_pages), 0, 32, dtype=jnp.int32)
    ln = jnp.asarray(lengths, jnp.int32)
    out_k = ops.paged_attention(q, kp, vp, bt, ln, page_size=page,
                                backend="interpret")
    out_r = ref.paged_attention_ref(q, kp, vp, bt, ln)
    np.testing.assert_allclose(out_k, out_r, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------- flash prefill
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,S,D,bq,bk", [
    (1, 2, 1, 256, 128, 64, 64),
    (2, 4, 4, 256, 128, 128, 64),
    (1, 8, 2, 512, 256, 128, 128),
])
def test_flash_prefill_sweep(dtype, B, H, Hkv, S, D, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D)).astype(dtype)
    out_k = ops.flash_prefill(q, k, v, block_q=bq, block_k=bk,
                              backend="interpret")
    out_r = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **_tol(dtype))


def test_flash_prefill_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 128))
    k = jax.random.normal(ks[1], (1, 2, 128, 128))
    v = jax.random.normal(ks[2], (1, 2, 128, 128))
    out_k = ops.flash_prefill(q, k, v, causal=False, block_q=64, block_k=64,
                              backend="interpret")
    out_r = ref.flash_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out_k, out_r, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------- ssd scan
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 64, 32, 32),
    (2, 256, 4, 64, 128, 64),
    (1, 512, 8, 32, 64, 128),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_k, h_k = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, backend="interpret")
    y_r, h_r = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y_k, y_r, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(h_k, h_r, atol=2e-3, rtol=2e-3)


def test_ssd_chunked_vs_sequential():
    """The chunked 'dual' form must equal the sequential recurrence."""
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 2, 192, 3, 32, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_c, h_c = ref.ssd_scan_ref(x, dt, A, B, C, chunk=64)
    y_s, h_s = ref.ssd_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y_c, y_s, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(h_c, h_s, atol=2e-3, rtol=2e-3)


def test_ssd_nonmultiple_seq_padding():
    """seq % chunk != 0 must work (serving gets arbitrary prompt lengths)."""
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 1, 100, 2, 32, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_c, h_c = ref.ssd_scan_ref(x, dt, A, B, C, chunk=32)
    y_s, h_s = ref.ssd_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y_c, y_s, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(h_c, h_s, atol=2e-3, rtol=2e-3)
