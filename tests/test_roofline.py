"""Roofline extraction: HLO collective parsing + term math."""
from repro.launch.roofline import (RooflineTerms, _shape_bytes,
                                   collective_bytes)

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[256,4096]{1,0} parameter(0)
  %ag = bf16[4096,4096]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs = bf16[16,128]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[8,64]{1,0} all-to-all(%z), dimensions={0}
  %cp = u32[2]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (bf16[32,32]{1,0}, bf16[32,32]{1,0}) all-gather-start(%v)
  %agd = bf16[32,32]{1,0} all-gather-done(%ags)
  ROOT %t = f32[1] tuple(%ar)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[256,4096]{1,0}") == 256 * 4096 * 2
    assert _shape_bytes("f32[1024]{0}") == 4096
    assert _shape_bytes("(bf16[2,2], f32[4])") == 8 + 16


def test_collective_parse():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 4096 * 4096 * 2 + 2 * 32 * 32 * 2
    assert out["all-reduce"] == 4096
    assert out["reduce-scatter"] == 16 * 128 * 2
    assert out["all-to-all"] == 8 * 64 * 4
    assert out["collective-permute"] == 8


def test_terms_bottleneck():
    t = RooflineTerms(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9,
                      model_flops=100e12)
    assert t.compute_s == 1.0
    assert t.memory_s == 2.0
    assert t.collective_s == 1.0
    assert t.bottleneck == "memory"
    assert 0 < t.useful_flops_ratio < 1
