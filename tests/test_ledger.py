"""Columnar hot path: RequestLedger unit tests + decision-equivalence
suite between the columnar core and the pre-refactor reference path.

The correctness bar for the struct-of-arrays refactor: seeded scenarios
must produce *identical* Algorithm-2 scaling decisions (scale actions,
peak chips, the instance-count timeline) and summary metrics (SLO
attainment, gpu_hours, completion) whether the engine runs

- the columnar default (arrival fast path + saturation memo + vectorized
  instance-plane catch-up + ledger metrics), or
- the reference flavour (``reference=True``: per-object catch-up, no
  memo, no fast path) with metrics reduced over ``Request`` objects.
"""
import math

import numpy as np
import pytest

from repro.serving.request import (Request, RequestState, RequestType,
                                   make_batch, make_interactive)
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController
from repro.sim.ledger import FINISHED, QUEUED, RUNNING, RequestLedger
from repro.sim.metrics import RunResult
from repro.sim.scenarios import build_trace
from repro.sim.simulator import (default_perf_factory, simulate_events,
                                 simulate_fleet)
from repro.sim.workload import Trace, WorkloadSpec, generate_trace


def _run(name, seed, *, reference=False, vec_min=None, n=0):
    trace, kw = build_trace(name, n_requests=n, seed=seed)
    cluster = SimCluster(default_perf_factory(), max_chips=400)
    if vec_min is not None:
        cluster.vec_min = vec_min
    ctrl = ChironController(models=kw["models"]) if "models" in kw \
        else ChironController()
    return simulate_events(trace, ctrl, cluster, max_time=kw["max_time"],
                           warm_start=2, failures=kw.get("failures"),
                           degradations=kw.get("degradations"),
                           reference=reference)


def _fingerprint(res: RunResult):
    return dict(
        scale_ups=res.scale_ups, scale_downs=res.scale_downs,
        peak_chips=res.peak_chips, n_events=res.n_events,
        duration=res.duration, chip_seconds=res.chip_seconds,
        failures=res.failures, degradations=res.degradations,
        timeline=[(p.t, p.n_interactive, p.n_mixed, p.n_batch, p.chips)
                  for p in res.timeline])


def _summaries_match(a: RunResult, b: RunResult):
    sa, sb = a.summary(), b.summary()
    assert set(sa) == set(sb)
    for k, v in sa.items():
        # decision-bearing metrics are exact; float reductions may
        # reassociate (vectorized vs sequential sums)
        assert math.isclose(v, sb[k], rel_tol=1e-9, abs_tol=1e-12), \
            (k, v, sb[k])


# ------------------------------------------------------- ledger unit tests
def test_ledger_from_trace_shares_workload_columns():
    trace = generate_trace(WorkloadSpec(n_requests=100, seed=1))
    led = RequestLedger.from_trace(trace)
    assert led.n == 100
    assert led.arrival is trace.arrival          # views, not copies
    assert np.all(led.state == QUEUED)
    assert np.all(np.isnan(led.finish_time))


def test_ledger_from_requests_stamps_rows_and_carries_state():
    reqs = [make_interactive(10, 5, 0.0), make_batch(20, 8, 1.0)]
    reqs[0].state = RequestState.FINISHED
    reqs[0].first_token_time = 0.5
    reqs[0].finish_time = 2.0
    reqs[0].tokens_generated = 5
    reqs[0].itl_samples.append(0.1)
    led = RequestLedger.from_requests(reqs)
    assert [r.row for r in reqs] == [0, 1]
    assert led.state[0] == FINISHED and led.state[1] == QUEUED
    assert led.first_token_time[0] == 0.5
    assert led.mean_itl[0] == 0.1
    assert not led.interactive[1]
    assert led.models == ("llama-8b",)


def test_ledger_extend_merges_vocabularies():
    t1 = generate_trace(WorkloadSpec(n_requests=10, seed=1, model="m-a"))
    t2 = generate_trace(WorkloadSpec(n_requests=10, seed=2, model="m-b"))
    led = RequestLedger(0)
    assert led.extend_from_trace(t1) == 0
    assert led.extend_from_trace(t2) == 10
    assert led.n == 20
    assert led.models == ("m-a", "m-b")
    assert set(led.model_idx[:10]) == {0} and set(led.model_idx[10:]) == {1}


def test_ledger_reductions_match_object_loops():
    """Every vectorized reduction must equal the Request-object loop it
    replaced, on the same finished run."""
    res = _run("multi_tenant_slo", seed=11, n=800)
    led_metrics = res
    obj_metrics = RunResult(
        requests=res.requests, timeline=res.timeline,
        chip_seconds=res.chip_seconds, peak_chips=res.peak_chips,
        scale_ups=res.scale_ups, scale_downs=res.scale_downs,
        duration=res.duration, ledger=None)
    assert led_metrics.ledger is not None
    for rtype in (None, RequestType.INTERACTIVE, RequestType.BATCH):
        assert led_metrics.slo_attainment(rtype) == \
            obj_metrics.slo_attainment(rtype)
        assert led_metrics.ttft_attainment(rtype) == \
            obj_metrics.ttft_attainment(rtype)
        assert led_metrics.p99_ttft(rtype) == obj_metrics.p99_ttft(rtype)
        assert math.isclose(led_metrics.mean_itl(rtype),
                            obj_metrics.mean_itl(rtype), rel_tol=1e-12)
    assert led_metrics.completion_rate() == obj_metrics.completion_rate()
    assert led_metrics.total_tokens() == obj_metrics.total_tokens()
    assert led_metrics.request_throughput() == \
        obj_metrics.request_throughput()
    assert led_metrics.slo_by_model() == obj_metrics.slo_by_model()
    assert led_metrics.models() == obj_metrics.models()


def test_ledger_rows_mirror_request_objects():
    res = _run("diurnal", seed=5, n=600)
    led = res.ledger
    for r in res.requests:
        assert r.row >= 0
        assert led.state[r.row] == FINISHED
        assert r.state == RequestState.FINISHED
        assert led.tokens_generated[r.row] == r.tokens_generated
        assert led.finish_time[r.row] == r.finish_time
        assert led.first_token_time[r.row] == r.first_token_time
        mean = sum(r.itl_samples) / len(r.itl_samples)
        assert led.mean_itl[r.row] == mean


def test_ledger_running_state_written_on_admit():
    cluster = SimCluster(default_perf_factory(), max_chips=40)
    cluster.event_mode = True
    led = RequestLedger.from_requests([make_interactive(64, 1000, 0.0)])
    cluster.ledger = led
    from repro.sim.cluster import InstanceType
    inst = cluster.provision("llama-8b", InstanceType.MIXED, 0.0,
                             static_batch=8)
    inst.ready_time = 0.0
    inst.activate_if_ready(0.0)
    req = make_interactive(64, 1000, 0.0)
    req.row = 0
    inst.admit(req, 0.0)
    assert led.state[0] == RUNNING


# ----------------------------------------------- decision equivalence suite
@pytest.mark.parametrize("name", ["diurnal", "burst_spikes",
                                  "multi_model_fleet"])
def test_columnar_core_matches_reference_decisions(name):
    """The satellite bar: seeded runs must produce identical Algorithm-2
    scaling decisions and summary metrics between the columnar core and
    the pre-refactor reference path."""
    fast = _run(name, seed=3)
    ref = _run(name, seed=3, reference=True)
    assert _fingerprint(fast) == _fingerprint(ref)
    _summaries_match(fast, ref)


@pytest.mark.parametrize("name", ["multi_model_fleet", "multi_tenant_slo",
                                  "backlog_drain"])
def test_vectorized_instance_plane_matches_scalar_catch_up(name):
    """Force the vectorized plane on every control tick (vec_min=1): the
    array pass must be bit-for-bit the scalar loop — including under
    mixed-instance eviction pressure, where stale heap heads must not
    leak into the vectorized completion ETAs."""
    vec = _run(name, seed=9, vec_min=1)
    ref = _run(name, seed=9, reference=True)
    assert _fingerprint(vec) == _fingerprint(ref)
    _summaries_match(vec, ref)


def test_multi_region_fleet_matches_reference_decisions():
    def run(reference):
        trace, kw = build_trace("multi_region", seed=3)
        return simulate_fleet(trace, kw["fleet"](), max_time=kw["max_time"],
                              warm_start=1, reference=reference)
    fast, ref = run(False), run(True)
    assert _fingerprint(fast) == _fingerprint(ref)
    assert [c.served_batch for c in fast.clusters] == \
        [c.served_batch for c in ref.clusters]
    assert fast.migrations == ref.migrations
    assert fast.egress_bytes == ref.egress_bytes
    _summaries_match(fast, ref)


def test_failure_and_degradation_paths_match_reference():
    for name in ("instance_failures", "slow_nodes"):
        fast = _run(name, seed=3)
        ref = _run(name, seed=3, reference=True)
        assert _fingerprint(fast) == _fingerprint(ref), name


# ------------------------------------------- inlined hot-path twin pinning
def test_itl_twins_pin_perf_model():
    """The hot path inlines PerfModel.itl three ways (SimInstance._itl_now,
    the block inside advance, InstancePlane._itl). Pin the callable twins
    bit-for-bit against PerfModel.itl across the feature-flag grid so a
    future PerfModel edit cannot silently fork the simulator physics.
    (advance's inline block is pinned transitively: the vectorized-vs-
    reference equivalence tests compare it against these.)"""
    from repro.sim.cluster import InstancePlane, InstanceType, SimInstance
    from repro.sim.perf_model import PerfModel
    cases = [
        dict(),
        dict(speculative_decoding=True),
        dict(prefix_caching=True),
        dict(speculative_decoding=True, prefix_caching=True,
             flops_scale=0.6, hbm_bw_scale=0.75),
    ]
    for kw in cases:
        perf = PerfModel("llama-8b", **kw)
        for slow in (1.0, 4.0):
            inst = SimInstance(perf, InstanceType.MIXED, 0.0,
                               static_batch=64)
            inst.slow_factor = slow
            plane = InstancePlane(cap=4)
            slot = plane.alloc(inst)
            plane.slow[slot] = slow
            # batch/context grid reaching past the KV-capacity inflection
            cap = perf.kv_capacity_tokens()
            ctxs = [1.0, 512.0, 2048.0, cap / 4, cap / 2]
            for b in (1, 8, 64, 512):
                for ctx in ctxs:
                    want = perf.itl(b, ctx) * slow
                    assert inst._itl_now(b, ctx) == want, (kw, slow, b, ctx)
                    got = plane._itl(np.array([slot]), np.array([b]),
                                     np.array([ctx]))
                    assert float(got[0]) == want, (kw, slow, b, ctx)


def test_scan_admit_pins_can_admit_best_fit():
    """_scan_admit is the fused twin of
    `_best_fit([i for i in pool if i.can_admit(req)])` — pin the choice on
    randomized pool states (fill levels, KV pressure, health, inactive
    members) so an admission-rule change cannot drift between them."""
    from repro.sim.cluster import InstanceType
    from repro.sim.controllers import _best_fit, _scan_admit
    rng = np.random.default_rng(0)
    cluster = SimCluster(default_perf_factory(), max_chips=4000)
    cluster.event_mode = True
    pool = []
    for k in range(8):
        inst = cluster.provision("llama-8b", InstanceType.MIXED, 0.0,
                                 static_batch=int(rng.integers(1, 6)))
        inst.ready_time = 0.0
        inst.activate_if_ready(0.0)
        pool.append(inst)
    for trial in range(200):
        req = make_interactive(int(rng.integers(1, 4000)), 10, 0.0)
        for inst in pool:
            inst.active = bool(rng.random() < 0.8)
            inst.health_ewma = 3.0 if rng.random() < 0.3 else 1.0
            n = int(rng.integers(0, inst.static_batch + 1))
            # fake fill without running the engine: aggregates only
            inst.running = {i: None for i in range(n)}
            inst._kv_prefill = float(rng.uniform(0, 2) * 200000)
            inst._kv_dec_base = 0.0
            inst._n_dec = 0
        want = _best_fit([i for i in pool if i.can_admit(req)])
        got, rej = _scan_admit(pool, req)
        assert got is want, trial
        # rej_slack invariant the positive-scan memo depends on: any
        # prompt longer than rej is wall-rejected by every instance this
        # scan wall-rejected (capacity/active rejections are
        # request-independent)
        for inst in pool:
            if inst.active and len(inst.running) < inst.max_batch_size \
                    and not inst.can_admit(req):
                assert inst._c_wall - (inst._kv_prefill
                                       + inst._kv_dec_base
                                       + inst._n_dec * inst.vclock) <= rej


# ------------------------------------------------------- materialize parity
def test_bulk_materialize_equals_constructor_requests():
    """Trace.materialize bypasses the dataclass __init__ — its objects
    must be field-for-field what the constructor would build (guards
    against Request field drift)."""
    trace = generate_trace(WorkloadSpec(n_requests=50, seed=4,
                                        interactive_frac=0.5))
    fast = trace.materialize(row0=0)
    slow = [Request(int(p), int(o),
                    RequestType.INTERACTIVE if c else RequestType.BATCH,
                    fast[i].slo, float(t), model=trace.models[m], row=i)
            for i, (t, p, o, c, m) in enumerate(zip(
                trace.arrival, trace.prompt_len, trace.output_len,
                trace.interactive, trace.model_idx))]
    import dataclasses
    names = {fld.name for fld in dataclasses.fields(Request)}
    for f, s in zip(fast, slow):
        for name in names:
            if name == "req_id":
                continue
            assert getattr(f, name) == getattr(s, name), name
    # bulk-built objects carry only non-default entries; every absent
    # field must resolve through a dataclass class-attribute default
    # equal to what the constructor would have stored
    assert set(fast[0].__dict__) <= names
    for name in names - set(fast[0].__dict__):
        assert getattr(Request, name) == getattr(slow[0], name), name
    # the one mutable factory default must stay per-instance
    assert "itl_samples" in fast[0].__dict__
    fast[0].itl_samples.append(1.0)
    assert not fast[1].itl_samples
