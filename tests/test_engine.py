"""Real continuous-batching engine: e2e serving, preemption, KV restore."""
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.serving.engine import Engine
from repro.serving.request import (RequestState, RequestType, make_batch,
                                   make_interactive)


@pytest.fixture(scope="module")
def engine_cfg():
    return get_smoke_config("granite-8b")


def _drain(eng, reqs, max_steps=300):
    steps = 0
    while (eng.waiting or eng.n_active) and steps < max_steps:
        eng.step()
        steps += 1
    return steps


def test_serves_all_requests(engine_cfg):
    eng = Engine(engine_cfg, max_slots=4, max_len=96, dtype=jnp.float32)
    reqs = [make_interactive(8 + i, 6 + i) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    _drain(eng, reqs)
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert r.tokens_generated >= r.output_len
        assert r.first_token_time is not None
        assert r.finish_time >= r.first_token_time


def test_max_batch_size_respected(engine_cfg):
    eng = Engine(engine_cfg, max_slots=4, max_len=64, max_batch_size=2,
                 dtype=jnp.float32)
    for i in range(4):
        eng.submit(make_interactive(8, 30))
    eng.step()
    assert eng.n_active <= 2


def test_interactive_preempts_batch(engine_cfg):
    eng = Engine(engine_cfg, max_slots=2, max_len=96, dtype=jnp.float32)
    b1 = make_batch(8, 60)
    b2 = make_batch(8, 60)
    eng.submit(b1)
    eng.submit(b2)
    eng.step()
    assert eng.n_active == 2
    inter = make_interactive(8, 4)
    eng.submit(inter)
    stats = eng.step()
    assert len(stats.preempted) == 1
    victim = stats.preempted[0]
    assert victim.state == RequestState.PREEMPTED
    assert victim.saved_kv is not None
    assert inter.state in (RequestState.RUNNING, RequestState.FINISHED)
    # resubmit the victim: must resume from saved KV (no re-prefill -> its
    # first_token_time is preserved and generation continues)
    tokens_before = victim.tokens_generated
    eng.submit(victim)
    _drain(eng, [victim])
    assert victim.state == RequestState.FINISHED
    assert victim.tokens_generated >= victim.output_len
    assert victim.tokens_generated >= tokens_before
    assert victim.saved_kv is None


def test_throughput_metric_positive(engine_cfg):
    eng = Engine(engine_cfg, max_slots=4, max_len=64, dtype=jnp.float32)
    for i in range(3):
        eng.submit(make_interactive(8, 20))
    for _ in range(10):
        eng.step()
    assert eng.throughput() > 0
