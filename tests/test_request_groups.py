"""Request-group clustering (1-D k-means on TTFT deadlines)."""
from hypothesis import given, settings, strategies as st

from repro.core.request_groups import kmeans_1d, make_request_groups
from repro.serving.request import make_batch


def test_kmeans_separates_two_clusters():
    vals = [1.0, 1.1, 0.9, 100.0, 101.0, 99.5]
    assign = kmeans_1d(vals, 2)
    assert assign[0] == assign[1] == assign[2]
    assert assign[3] == assign[4] == assign[5]
    assert assign[0] != assign[3]


def test_groups_split_by_deadline():
    fast = [make_batch(10, 10, arrival=0.0, ttft_slo=300.0) for _ in range(5)]
    slow = [make_batch(10, 10, arrival=0.0, ttft_slo=3600.0) for _ in range(5)]
    groups = make_request_groups(fast + slow)
    assert len(groups) >= 2
    # groups ordered by deadline; all fast requests in earlier groups
    first = set(id(r) for r in groups[0].requests)
    assert all(id(r) in first for r in fast) or groups[0].deadline < 3000


def test_fcfs_within_group():
    reqs = [make_batch(10, 10, arrival=float(10 - i), ttft_slo=600.0)
            for i in range(5)]
    [g] = make_request_groups(reqs, k=1)
    order = [r.arrival_time for r in g.sorted_fcfs()]
    assert order == sorted(order)


@given(st.lists(st.floats(0.0, 10000.0), min_size=1, max_size=60),
       st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_kmeans_total_assignment(vals, k):
    assign = kmeans_1d(vals, k)
    assert len(assign) == len(vals)
    assert all(0 <= a < min(k, len(vals)) for a in assign)


@given(st.integers(1, 100))
@settings(max_examples=20, deadline=None)
def test_every_request_in_exactly_one_group(n):
    reqs = [make_batch(10, 10, arrival=float(i % 7), ttft_slo=600.0 * (1 + i % 3))
            for i in range(n)]
    groups = make_request_groups(reqs)
    seen = [id(r) for g in groups for r in g.requests]
    assert sorted(seen) == sorted(id(r) for r in reqs)
