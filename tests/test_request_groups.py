"""Request-group clustering (1-D k-means on TTFT deadlines)."""
from _hypothesis_compat import given, settings, st

from repro.core.request_groups import kmeans_1d, make_request_groups
from repro.serving.request import make_batch


def test_kmeans_separates_two_clusters():
    vals = [1.0, 1.1, 0.9, 100.0, 101.0, 99.5]
    assign = kmeans_1d(vals, 2)
    assert assign[0] == assign[1] == assign[2]
    assert assign[3] == assign[4] == assign[5]
    assert assign[0] != assign[3]


def test_groups_split_by_deadline():
    fast = [make_batch(10, 10, arrival=0.0, ttft_slo=300.0) for _ in range(5)]
    slow = [make_batch(10, 10, arrival=0.0, ttft_slo=3600.0) for _ in range(5)]
    groups = make_request_groups(fast + slow)
    assert len(groups) >= 2
    # groups ordered by deadline; all fast requests in earlier groups
    first = set(id(r) for r in groups[0].requests)
    assert all(id(r) in first for r in fast) or groups[0].deadline < 3000


def test_fcfs_within_group():
    reqs = [make_batch(10, 10, arrival=float(10 - i), ttft_slo=600.0)
            for i in range(5)]
    [g] = make_request_groups(reqs, k=1)
    order = [r.arrival_time for r in g.sorted_fcfs()]
    assert order == sorted(order)


@given(st.lists(st.floats(0.0, 10000.0), min_size=1, max_size=60),
       st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_kmeans_total_assignment(vals, k):
    assign = kmeans_1d(vals, k)
    assert len(assign) == len(vals)
    assert all(0 <= a < min(k, len(vals)) for a in assign)


@given(st.integers(1, 100))
@settings(max_examples=20, deadline=None)
def test_every_request_in_exactly_one_group(n):
    reqs = [make_batch(10, 10, arrival=float(i % 7), ttft_slo=600.0 * (1 + i % 3))
            for i in range(n)]
    groups = make_request_groups(reqs)
    seen = [id(r) for g in groups for r in g.requests]
    assert sorted(seen) == sorted(id(r) for r in reqs)


# ------------------------------------------------- k-clamp fix (short queues)
def test_positive_k_does_not_degenerate_on_short_queue():
    """Regression: 0 < k >= n used to silently take the one-group-per-
    request ablation path, inflating BBP on short queues. Identical
    deadlines must collapse into ONE group for any positive k."""
    reqs = [make_batch(10, 10, arrival=0.0, ttft_slo=600.0)
            for _ in range(5)]
    for k in (1, 4, 5, 8, 100):
        groups = make_request_groups(reqs, k=k)
        assert len(groups) == 1, (k, len(groups))
        assert groups[0].n == 5


def test_minus_one_is_the_only_singleton_path():
    reqs = [make_batch(10, 10, arrival=0.0, ttft_slo=600.0)
            for _ in range(4)]
    groups = make_request_groups(reqs, k=-1)
    assert len(groups) == 4
    assert all(g.n == 1 for g in groups)


@given(n=st.integers(1, 8), k=st.integers(1, 10),
       spread=st.sampled_from([0.0, 1.0, 5000.0]))
@settings(max_examples=60, deadline=None)
def test_small_queue_grouping_property(n, k, spread):
    """Positive k is clamped to min(k, n) and near-identical deadlines
    merge: group count never exceeds the number of distinct deadlines."""
    reqs = [make_batch(10, 10, arrival=0.0,
                       ttft_slo=600.0 + spread * (i % 2))
            for i in range(n)]
    groups = make_request_groups(reqs, k=k)
    distinct = len({r.deadline for r in reqs})
    assert 1 <= len(groups) <= min(k, n)
    if spread == 0.0:
        assert len(groups) == 1
    assert sum(g.n for g in groups) == n
    assert len(groups) <= distinct


# ------------------------------------------------- incremental grouper
def test_incremental_grouper_tracks_queue():
    from repro.core.request_groups import IncrementalGrouper
    from repro.serving.global_queue import GlobalQueue

    q = GlobalQueue()
    g = IncrementalGrouper()
    q.attach_batch_listener(g)
    fast = [make_batch(10, 10, arrival=0.0, ttft_slo=300.0)
            for _ in range(10)]
    slow = [make_batch(10, 10, arrival=0.0, ttft_slo=3600.0)
            for _ in range(10)]
    for r in fast + slow:
        q.push(r)
    stats = g.group_stats()
    assert sum(s.n for s in stats) == 20
    assert len(stats) >= 2                      # distant cohorts split
    assert stats[0].deadline < stats[-1].deadline
    # serving drains groups (earliest deadline first)
    for _ in range(10):
        q.pop_batch_fcfs()
    stats = g.group_stats()
    assert sum(s.n for s in stats) == 10
    assert g.n_members == 10


def test_incremental_grouper_matches_oneshot_bbp_inputs():
    """The incremental stats must agree with a from-scratch clustering on
    what BBP reads: total membership and the earliest deadline."""
    from repro.core.request_groups import IncrementalGrouper
    from repro.serving.global_queue import GlobalQueue

    q = GlobalQueue()
    g = IncrementalGrouper()
    q.attach_batch_listener(g)
    reqs = [make_batch(10, 10, arrival=float(i),
                       ttft_slo=300.0 * (1 + i % 5)) for i in range(300)]
    for r in reqs:
        q.push(r)
    for _ in range(120):
        q.pop_batch_fcfs()
    stats = g.group_stats()
    remaining = list(q.iter_batch())
    oneshot = make_request_groups(remaining)
    assert sum(s.n for s in stats) == len(remaining)
    assert abs(stats[0].deadline - oneshot[0].deadline) < 1e-9
