"""Overload control plane: SLO-aware admission, deadline shedding vs.
batch deferral, deterministic client retries, brownout hysteresis, fleet
circuit breakers, goodput accounting, and the attempt column's trace-IO
round trip. The plane is opt-in — the last tests pin the disabled path
bit-identical to a run predating the module."""
import numpy as np
import pytest

from repro.serving.request import RequestType
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController
from repro.sim.fleet import ClusterSpec, Fleet, FleetTopology, Region, Router
from repro.sim.ledger import (EXPIRED, FINISHED, REJECTED, SHED,
                              RequestLedger)
from repro.sim.overload import (BRK_CLOSED, BRK_HALF_OPEN, BRK_OPEN,
                                AdmissionConfig, BreakerConfig,
                                BrownoutConfig, BrownoutState,
                                CircuitBreaker, OverloadConfig, RetryPolicy)
from repro.sim.scenarios import build_trace
from repro.sim.simulator import (default_perf_factory, simulate,
                                 simulate_events, simulate_fleet)
from repro.sim.trace_io import load_trace, save_trace
from repro.sim.workload import Trace, make_trace

MODEL = "llama-8b"


def _storm_trace(n=400, rate=80.0, seed=3, *, ttft_slo=3.0):
    """Sustained saturation: heavy near-constant tokens at an arrival
    rate far past what 4 chips can serve within a tight TTFT SLO."""
    rng = np.random.default_rng(seed)
    t = np.sort(rng.uniform(0.0, n / rate, n))
    ins = np.clip(rng.lognormal(np.log(1500.0), 0.25, n),
                  64, 8192).astype(np.int64)
    outs = np.clip(rng.lognormal(np.log(400.0), 0.25, n),
                   16, 2048).astype(np.int64)
    return make_trace(t, ins, outs, np.ones(n, dtype=bool),
                      ttft_slo=ttft_slo)


def _run_storm(overload, *, n=400, seed=3, telemetry=None,
               shadow_verify=None, max_chips=4):
    trace = _storm_trace(n=n, seed=seed)
    cluster = SimCluster(default_perf_factory(), max_chips=max_chips)
    return simulate_events(trace, ChironController(), cluster,
                           max_time=trace.duration + 600.0,
                           overload=overload, telemetry=telemetry,
                           shadow_verify=shadow_verify)


# ------------------------------------------------------------ retry policy
def test_retry_backoff_deterministic_and_bounded():
    pol = RetryPolicy(base_backoff=2.0, jitter=0.5)
    for row in (0, 7, 123456):
        for k in (1, 2, 3):
            base = 2.0 * 2.0 ** (k - 1)
            d = pol.backoff(row, k)
            assert d == pol.backoff(row, k)          # pure counter hash
            assert base <= d < base * 1.5
    # different rows decorrelate: not all first-attempt delays collide
    assert len({pol.backoff(r, 1) for r in range(16)}) > 8


def test_retry_backoff_no_jitter_is_pure_exponential():
    pol = RetryPolicy(base_backoff=1.0, jitter=0.0)
    assert [pol.backoff(5, k) for k in (1, 2, 3)] == [1.0, 2.0, 4.0]


# ------------------------------------------------------------ brownout FSM
def test_brownout_hysteresis_enter_and_exit():
    cfg = BrownoutConfig(enter_ticks=3, exit_ticks=2, queue_min=1)
    st = BrownoutState()
    assert st.update(True, cfg) is None
    assert st.update(True, cfg) is None
    assert st.update(True, cfg) is True           # 3rd hot tick enters
    assert st.engaged
    assert st.update(False, cfg) is None
    assert st.update(True, cfg) is None           # healthy streak resets
    assert st.update(False, cfg) is None
    assert st.update(False, cfg) is False         # 2nd cool tick exits
    assert not st.engaged


def test_brownout_hot_streak_resets_on_healthy_tick():
    cfg = BrownoutConfig(enter_ticks=3, exit_ticks=5)
    st = BrownoutState()
    for _ in range(10):                           # alternating never enters
        assert st.update(True, cfg) is None
        assert st.update(True, cfg) is None
        assert st.update(False, cfg) is None
    assert not st.engaged


# -------------------------------------------------------- circuit breaker
def test_breaker_opens_half_opens_and_closes():
    cfg = BreakerConfig(ewma_alpha=0.5, open_threshold=0.5, cooldown=30.0,
                        trial_successes=2, min_samples=3)
    brk = CircuitBreaker(cfg)
    t = 0.0
    assert brk.record(True, t) is None            # below min_samples
    assert brk.record(True, t) is None
    assert brk.record(True, t) == BRK_OPEN        # EWMA 1.0 > 0.5, trips
    assert not brk.allows(t + 10.0)               # still cooling down
    assert brk.allows(t + 30.0)                   # cooldown -> half-open
    assert brk.state == BRK_HALF_OPEN
    assert brk.record(False, t + 31.0) is None    # 1st trial accept
    assert brk.record(False, t + 32.0) == BRK_CLOSED
    assert brk.samples == 0                       # fresh slate after close


def test_breaker_half_open_rejection_reopens():
    cfg = BreakerConfig(open_threshold=0.5, cooldown=10.0, min_samples=1,
                        ewma_alpha=1.0)
    brk = CircuitBreaker(cfg)
    assert brk.record(True, 0.0) == BRK_OPEN
    assert brk.allows(10.0)                       # half-open trial
    assert brk.record(True, 10.5) == BRK_OPEN     # trial reject reopens
    assert not brk.allows(15.0)                   # new cooldown from 10.5
    assert brk.allows(20.5)


def test_router_breaker_deflects_to_healthy_cluster():
    specs = [ClusterSpec("us-a", "us", max_chips=40),
             ClusterSpec("us-b", "us", max_chips=40)]
    topo = FleetTopology([Region("us")])
    router = Router(breaker=BreakerConfig(open_threshold=0.5,
                                          min_samples=2, ewma_alpha=1.0,
                                          cooldown=30.0))
    fleet = Fleet(specs, topo, models=(MODEL,), router=router)
    a, b = fleet.by_name["us-a"], fleet.by_name["us-b"]
    assert router._pick_interactive(MODEL, "us", 0.0).name == "us-a"
    router.note_admission(a, True, 0.0)
    trans = router.note_admission(a, True, 0.0)
    assert trans is not None and trans[0] == BRK_OPEN
    # open breaker on us-a: interactive and batch both deflect to us-b
    assert router._pick_interactive(MODEL, "us", 1.0).name == "us-b"
    assert router._pick_batch(MODEL, 1.0).name == "us-b"
    # every breaker open -> route anyway rather than dropping on the floor
    router.note_admission(b, True, 1.0)
    router.note_admission(b, True, 1.0)
    assert router.breaker_for(b).state == BRK_OPEN
    assert router._pick_interactive(MODEL, "us", 2.0) is not None
    # after the cooldown us-a half-opens and takes trial traffic again
    assert router._pick_interactive(MODEL, "us", 31.0).name == "us-a"
    assert router.breaker_for(a).state == BRK_HALF_OPEN


# ------------------------------------------------------------- engine gates
def test_inert_config_and_engine_gates():
    assert not OverloadConfig().active
    assert OverloadConfig.full().active
    trace = _storm_trace(n=40)
    cluster = SimCluster(default_perf_factory(), max_chips=4)
    with pytest.raises(ValueError, match="columnar"):
        simulate_events(trace, ChironController(), cluster, max_time=60.0,
                        reference=True, overload=OverloadConfig.full())
    with pytest.raises(ValueError, match="engine='event'"):
        simulate(trace, ChironController(), cluster, engine="fixed",
                 max_time=60.0, overload=OverloadConfig.full())
    trace2, kw = build_trace("multi_region", n_requests=60, seed=7)
    with pytest.raises(ValueError, match="columnar"):
        simulate_fleet(trace2, kw["fleet"](), max_time=kw["max_time"],
                       reference=True, overload=OverloadConfig.full())


# --------------------------------------------------------- storm end-to-end
def test_storm_admission_rejects_and_accounting_identity():
    res = _run_storm(OverloadConfig.full(slack=0.3, max_retries=3,
                                         base_backoff=2.0, budget=30.0),
                     n=600, telemetry=True)
    led = res.ledger
    counts = led.state_counts()
    assert counts[REJECTED] > 0                   # admission refused work
    assert counts[SHED] + counts[EXPIRED] > 0     # sweeps fired too
    # the terminal accounting identity over a completed run
    assert (int(counts[FINISHED]) + int(counts[REJECTED])
            + int(counts[SHED]) + int(counts[EXPIRED])) == led.n
    s = res.summary()
    for key in ("goodput", "goodput_interactive", "reject_rate",
                "shed_rate", "expired_rate"):
        assert key in s
    assert s["reject_rate"] > 0.0
    assert 0.0 <= s["reject_rate"] + s["shed_rate"] + s["expired_rate"] <= 1.0
    # every refusal is stamped into the obs decision ledger: at least one
    # reject row per terminally-rejected request (retried attempts that
    # were refused again add more)
    rep = res.telemetry.replay()
    assert rep["rejections"] >= int(counts[REJECTED]) > 0


def test_storm_retries_reattempt_and_respect_budget():
    res = _run_storm(OverloadConfig.full(slack=0.3, max_retries=3,
                                         base_backoff=2.0, budget=30.0))
    led = res.ledger
    assert int(led.retries.sum()) > 0             # clients actually retried
    assert int(led.retries.max()) <= 3
    # a retried request that eventually ran counts toward throughput
    served_after_retry = np.flatnonzero((led.retries > 0)
                                        & (led.state == FINISHED))
    assert served_after_retry.size >= 0           # may be zero under storm


def test_batch_is_deferred_never_dropped():
    trace, kw = build_trace("graceful_brownout", n_requests=600, seed=0)
    cluster = SimCluster(default_perf_factory(),
                         max_chips=kw["max_chips"])
    res = simulate_events(trace, ChironController(), cluster,
                          max_time=kw["max_time"],
                          overload=kw["overload"])
    led = res.ledger
    dropped = np.isin(led.state, (REJECTED, SHED, EXPIRED))
    assert dropped.any()                          # the plane engaged
    batch = ~led.interactive.astype(bool)
    assert not np.any(dropped & batch)            # batch only ever defers
    assert np.all(led.state[batch] == FINISHED)


def test_storm_goodput_beats_uncontrolled():
    """The acceptance criterion: the overload plane holds interactive
    goodput ≥20% above the control-disabled run on the same storm."""
    trace, kw = build_trace("retry_storm", n_requests=600, seed=3)
    cluster = SimCluster(default_perf_factory(), max_chips=kw["max_chips"])
    on = simulate_events(trace, ChironController(), cluster,
                         max_time=kw["max_time"], overload=kw["overload"])
    trace2, kw2 = build_trace("retry_storm", n_requests=600, seed=3,
                              overload_enabled=False)
    cluster2 = SimCluster(default_perf_factory(),
                          max_chips=kw2["max_chips"])
    off = simulate_events(trace2, ChironController(), cluster2,
                          max_time=kw2["max_time"])
    gp_on = on.goodput(RequestType.INTERACTIVE)
    gp_off = off.goodput(RequestType.INTERACTIVE)
    assert gp_on >= gp_off * 1.2


def test_storm_deterministic_across_observer_arms():
    """Telemetry and shadow verification are observers: the per-request
    outcomes must be bit-identical with them on, off, or both (compare
    by ledger index — request ids are process-global)."""
    def fingerprint(res):
        led = res.ledger
        return (led.state.tobytes(), led.retries.tobytes(),
                led.finish_time.tobytes(),
                led.first_token_time.tobytes())

    cfg = OverloadConfig.full(slack=0.3, max_retries=3,
                              base_backoff=2.0, budget=30.0)
    plain = fingerprint(_run_storm(cfg, n=300))
    again = fingerprint(_run_storm(cfg, n=300))
    telem = fingerprint(_run_storm(cfg, n=300, telemetry=True))
    shadow = fingerprint(_run_storm(cfg, n=300, shadow_verify=True))
    both = fingerprint(_run_storm(cfg, n=300, telemetry=True,
                                  shadow_verify=True))
    assert plain == again == telem == shadow == both


def test_disabled_plane_is_bit_identical_to_baseline():
    """overload=None and an all-None OverloadConfig must both leave the
    engine exactly on its pre-plane trajectory."""
    trace, kw = build_trace("multi_region", n_requests=300, seed=7)
    base = simulate_fleet(trace, kw["fleet"](), max_time=kw["max_time"],
                          warm_start=1).summary()
    inert = simulate_fleet(trace, kw["fleet"](), max_time=kw["max_time"],
                           warm_start=1, overload=OverloadConfig()).summary()
    assert inert == base
    tr2 = _storm_trace(n=120, rate=10.0)
    c1 = SimCluster(default_perf_factory(), max_chips=40)
    c2 = SimCluster(default_perf_factory(), max_chips=40)
    r1 = simulate_events(tr2, ChironController(), c1, max_time=300.0)
    r2 = simulate_events(tr2, ChironController(), c2, max_time=300.0,
                         overload=OverloadConfig())
    assert r1.summary() == r2.summary()


def test_goodput_counts_only_slo_met_finishes():
    led = RequestLedger.from_trace(_storm_trace(n=4, rate=1.0))
    # hand-mark: row 0 fast finish, row 1 slow finish, rows 2-3 dropped
    led.state[:] = (FINISHED, FINISHED, REJECTED, EXPIRED)
    led.first_token_time[:] = (led.arrival[0] + 0.1,
                               led.arrival[1] + 99.0, np.nan, np.nan)
    led.finish_time[:] = led.first_token_time + 1.0
    assert int(led.goodput_mask().sum()) == 1
    assert led.goodput(10.0) == pytest.approx(0.1)


# --------------------------------------------------------- attempt column
def _attempt_trace(n=30, seed=0):
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(0.2, n))
    ins = np.full(n, 64, dtype=np.int64)
    outs = np.full(n, 32, dtype=np.int64)
    att = rng.integers(0, 3, n).astype(np.int32)
    tidx = (rng.random(n) < 0.4).astype(np.int32)
    return make_trace(times, ins, outs, np.ones(n, dtype=bool),
                      attempt=att, tenant_idx=tidx,
                      tenants=("acme", "globex"))


@pytest.mark.parametrize("ext", ["csv", "jsonl", "csv.gz"])
def test_attempt_and_tenant_columns_round_trip(tmp_path, ext):
    tr = _attempt_trace()
    path = str(tmp_path / f"t.{ext}")
    save_trace(tr, path)
    back = load_trace(path)
    assert back.attempt is not None
    np.testing.assert_array_equal(back.attempt, tr.attempt)
    assert [back.tenants[i] for i in back.tenant_idx] \
        == [tr.tenants[i] for i in tr.tenant_idx]


def test_attemptless_trace_io_omits_column(tmp_path):
    tr = make_trace(np.arange(10, dtype=np.float64),
                    np.full(10, 64, dtype=np.int64),
                    np.full(10, 32, dtype=np.int64),
                    np.ones(10, dtype=bool))
    path = str(tmp_path / "t.csv")
    save_trace(tr, path)
    with open(path) as f:
        assert "attempt" not in f.readline()
    assert load_trace(path).attempt is None


def test_attempt_column_seeds_ledger_and_materialize():
    tr = _attempt_trace()
    led = RequestLedger.from_trace(tr)
    np.testing.assert_array_equal(led.retries, tr.attempt)
    reqs = tr.materialize()
    assert [r.retries for r in reqs] == tr.attempt.tolist()
    merged = Trace.concat([tr, _attempt_trace(seed=1)])
    assert merged.attempt is not None and merged.n == 60
