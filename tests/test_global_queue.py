"""Heap-based global queue: ordering, preemption discipline, scaling."""
import time

from repro.serving.global_queue import GlobalQueue
from repro.serving.request import make_batch, make_interactive


def test_interactive_fcfs_order():
    q = GlobalQueue()
    reqs = [make_interactive(10, 10, arrival=float(i)) for i in range(5)]
    for r in reqs:
        q.push(r)
    assert [q.pop_interactive() for _ in range(5)] == reqs
    assert q.pop_interactive() is None


def test_preempted_interactive_requeues_at_front():
    """Zero-queuing discipline (§3 footnote 3): a preempted interactive
    request must not re-queue behind later arrivals (regression: requeue
    used to append to the tail)."""
    q = GlobalQueue()
    first = make_interactive(10, 10, arrival=0.0)
    later = make_interactive(10, 10, arrival=1.0)
    q.push(first)
    q.push(later)
    victim = q.pop_interactive()
    assert victim is first
    q.requeue(victim)                   # preempted: back to the FRONT
    assert q.pop_interactive() is first
    assert q.pop_interactive() is later


def test_batch_pops_by_deadline_then_arrival():
    q = GlobalQueue()
    a = make_batch(10, 10, arrival=5.0, ttft_slo=100.0)   # deadline 105
    b = make_batch(10, 10, arrival=0.0, ttft_slo=100.0)   # deadline 100
    c = make_batch(10, 10, arrival=0.0, ttft_slo=50.0)    # deadline 50
    d = make_batch(10, 10, arrival=1.0, ttft_slo=99.0)    # deadline 100, later
    for r in (a, b, c, d):
        q.push(r)
    order = [q.pop_batch_fcfs() for _ in range(4)]
    assert order == [c, b, d, a]
    assert q.pop_batch_fcfs() is None


def test_preempted_batch_resumes_first():
    """A preempted batch request with host-saved KV re-enters service ahead
    of fresh requests (the restart skips re-prefill)."""
    q = GlobalQueue()
    urgent = make_batch(10, 10, arrival=0.0, ttft_slo=10.0)
    preempted = make_batch(10, 10, arrival=3.0, ttft_slo=1000.0)
    preempted.saved_kv = ("sim", 64.0)
    q.push(urgent)
    q.requeue(preempted)
    assert q.pop_batch_fcfs() is preempted
    assert q.pop_batch_fcfs() is urgent


def test_requeue_without_saved_kv_keeps_deadline_position():
    q = GlobalQueue()
    early = make_batch(10, 10, arrival=0.0, ttft_slo=50.0)
    late = make_batch(10, 10, arrival=0.0, ttft_slo=500.0)
    q.push(late)
    q.requeue(early)                    # no saved KV: ordinary re-insert
    assert q.pop_batch_fcfs() is early


def test_batch_listener_sees_adds_and_removes():
    q = GlobalQueue()
    seen = {"add": 0, "rm": 0}

    class L:
        def on_add(self, r):
            seen["add"] += 1

        def on_remove(self, r):
            seen["rm"] += 1

    q.push(make_batch(10, 10, 0.0))
    q.attach_batch_listener(L())        # replays current contents
    assert seen["add"] == 1
    q.push(make_batch(10, 10, 1.0))
    assert seen["add"] == 2
    q.pop_batch_fcfs()
    q.pop_batch_fcfs()
    assert seen["rm"] == 2
    assert len(q) == 0


def _drain(n: int) -> float:
    reqs = [make_batch(10, 10, arrival=float(i % 97),
                       ttft_slo=100.0 + (i % 13) * 50.0) for i in range(n)]
    q = GlobalQueue()
    t0 = time.perf_counter()
    for r in reqs:
        q.push(r)
    while q.pop_batch_fcfs() is not None:
        pass
    return time.perf_counter() - t0


def test_heap_queue_drains_50k_without_quadratic_blowup():
    """O(n log n) drain: 10x the requests must cost far less than the
    ~100x a quadratic (sort-per-pop) queue pays; absolute bound as a
    backstop against environmental noise. Best-of-3 on both sides keeps
    allocator/GC jitter (worst after the JAX-heavy modules run first in
    the full suite) from flaking a structural guard."""
    _drain(5_000)                       # warm-up (allocator, caches)
    small = max(min(_drain(5_000) for _ in range(3)), 1e-3)
    big = min(_drain(50_000) for _ in range(3))
    assert big < 30.0 * small, (small, big)
    assert big < 2.0, f"50k drain took {big:.2f}s"
