"""Chunked prefill + prefix reuse: prefilling a prompt in pieces through
``past_cache`` must be equivalent to one-shot prefill (and to forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model

B, S = 2, 48


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2-moe-a2.7b"])
def test_chunked_prefill_matches_oneshot(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = model.example_batch(B, S, jax.random.PRNGKey(1),
                                dtype=jnp.float32)
    toks = batch["tokens"]

    one_logits, one_cache = model.prefill(params, batch, dtype=jnp.float32)

    # prefill in three chunks: 16 + 16 + 16
    cache = None
    for lo in range(0, S, 16):
        chunk = {"tokens": toks[:, lo:lo + 16]}
        logits, cache = model.prefill(params, chunk, dtype=jnp.float32,
                                      past_cache=cache)

    np.testing.assert_allclose(np.asarray(logits), np.asarray(one_logits),
                               atol=3e-3, rtol=3e-3)
    np.testing.assert_allclose(np.asarray(cache["k"]),
                               np.asarray(one_cache["k"]),
                               atol=3e-3, rtol=3e-3)
    assert int(cache["pos"][0]) == S


def test_prefix_reuse_then_decode():
    """Reuse a cached shared prefix, prefill only the suffix, then decode —
    results must match the from-scratch path (prefix caching semantics)."""
    cfg = get_smoke_config("granite-8b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    prefix = jax.random.randint(key, (1, 24), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    sufa = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                              cfg.vocab_size, dtype=jnp.int32)

    # cache the prefix once
    _, pcache = model.prefill(params, {"tokens": prefix},
                              dtype=jnp.float32)
    # continue with the suffix from the cached prefix
    la, ca = model.prefill(params, {"tokens": sufa}, dtype=jnp.float32,
                           past_cache=pcache)
    # from-scratch reference
    full = jnp.concatenate([prefix, sufa], axis=1)
    lr, cr = model.prefill(params, {"tokens": full}, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lr),
                               atol=3e-3, rtol=3e-3)

    # decode a few tokens from both caches: must agree
    # (grow room: pad both caches via cache_len on a fresh prefill)
    la2, ca = model.prefill(params, {"tokens": sufa}, dtype=jnp.float32,
                            past_cache=pcache, cache_len=40)
    lr2, cr = model.prefill(params, {"tokens": full}, dtype=jnp.float32,
                            cache_len=40)
    tok = jnp.argmax(la2, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        da, ca = model.decode_step(params, tok, ca)
        dr, cr = model.decode_step(params, tok, cr)
        np.testing.assert_allclose(np.asarray(da), np.asarray(dr),
                                   atol=5e-3, rtol=5e-3)
        tok = jnp.argmax(da, -1)[:, None].astype(jnp.int32)
