"""Per-architecture smoke tests: reduced config, one forward + one train
step + prefill/decode consistency on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.training.optimizer import adamw_init

B, S = 2, 64


@pytest.fixture(scope="module")
def setups():
    return {}


def _setup(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = model.example_batch(B, S, jax.random.PRNGKey(1),
                                dtype=jnp.float32)
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg, model, params, batch = _setup(arch)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch):
    cfg, model, params, batch = _setup(arch)
    step = jax.jit(make_train_step(cfg, remat=False, lr=1e-3))
    opt = adamw_init(params)
    p1, opt1, m1 = step(params, opt, batch)
    assert jnp.isfinite(m1["loss"]) and m1["loss"] > 0
    assert jnp.isfinite(m1["grad_norm"]) and m1["grad_norm"] > 0
    # params actually changed
    d = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l[0] - l[1]))),
        jax.tree.map(lambda a, b: (a, b), p1, params), 0.0)
    assert d > 0
    # a second step keeps the loss finite (and typically lower)
    _, _, m2 = step(p1, opt1, batch)
    assert jnp.isfinite(m2["loss"])
    assert float(m2["loss"]) < float(m1["loss"]) * 1.5


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_matches_forward(arch):
    cfg, model, params, batch = _setup(arch)
    logits, _ = model.forward(params, batch)
    last, cache = model.prefill(params, batch, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits[:, -1]),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    """Decode step t must reproduce forward logits at position t —
    validates cache correctness (and SSD duality for SSM/hybrid)."""
    cfg, model, params, batch = _setup(arch)
    toks = batch["tokens"]
    n_extra = 4
    prompt = {**batch, "tokens": toks[:, :S - n_extra]}
    # cache_len must cover prompt + vision prefix + decoded tokens (decode
    # writes at slot=pos; an exactly-sized cache would drop the write)
    clen = S + (cfg.n_vision_tokens if cfg.arch_type == "vlm" else 0)
    last, cache = model.prefill(params, prompt, dtype=jnp.float32,
                                cache_len=clen)
    full_logits, _ = model.forward(params, batch)
    for i in range(n_extra):
        pos = S - n_extra + i
        step_logits, cache = model.decode_step(params, toks[:, pos:pos + 1],
                                               cache)
        ref = full_logits[:, pos]
        np.testing.assert_allclose(np.asarray(step_logits), np.asarray(ref),
                                   atol=5e-3, rtol=5e-3)
        assert not bool(jnp.any(jnp.isnan(step_logits)))
