"""Integration: PagedKVManager block tables + paged_attention kernel.

Builds a paged KV pool through the allocator (multiple sequences, ragged
lengths, appends, a swap-out/in cycle), then checks paged attention over
the resulting block tables against dense attention — the serving data
path Chiron's instances run on TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.serving.kv_manager import PagedKVManager

PAGE = 16
N_KV, GROUP, D = 2, 2, 128


def _dense_attention(q, k, v):
    """q (n_kv,g,D); k/v (T,n_kv,D)."""
    import math
    s = jnp.einsum("kgd,tkd->kgt", q, k) / math.sqrt(D)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("kgt,tkd->kgd", w, v)


def test_allocator_kernel_end_to_end():
    rng = np.random.default_rng(0)
    mgr = PagedKVManager(num_pages=64, page_size=PAGE)
    pool_k = np.zeros((64, PAGE, N_KV, D), np.float32)
    pool_v = np.zeros((64, PAGE, N_KV, D), np.float32)
    seq_tokens = {}

    # three sequences with ragged prompt lengths
    for sid, n in ((0, 37), (1, 5), (2, 64)):
        pages = mgr.allocate(sid, n)
        toks_k = rng.normal(size=(n, N_KV, D)).astype(np.float32)
        toks_v = rng.normal(size=(n, N_KV, D)).astype(np.float32)
        seq_tokens[sid] = (toks_k, toks_v)
        for i in range(n):
            p = pages[i // PAGE]
            pool_k[p, i % PAGE] = toks_k[i]
            pool_v[p, i % PAGE] = toks_v[i]

    # append a few decode tokens to seq 0 (may allocate a new page)
    for _ in range(12):
        newp = mgr.append_token(0)
        tk = rng.normal(size=(N_KV, D)).astype(np.float32)
        tv = rng.normal(size=(N_KV, D)).astype(np.float32)
        k0, v0 = seq_tokens[0]
        seq_tokens[0] = (np.concatenate([k0, tk[None]]),
                         np.concatenate([v0, tv[None]]))
        n = mgr.seq_tokens(0)
        page_list = mgr.block_table(0)
        p = page_list[(n - 1) // PAGE]
        pool_k[p, (n - 1) % PAGE] = tk
        pool_v[p, (n - 1) % PAGE] = tv

    # swap a sequence out and back in (host offload round trip)
    saved = {pid: (pool_k[pid].copy(), pool_v[pid].copy())
             for pid in mgr.block_table(1)}
    old_pages = mgr.block_table(1)
    mgr.swap_out(1)
    new_pages = mgr.swap_in(1)
    for old, new in zip(old_pages, new_pages):
        pool_k[new], pool_v[new] = saved[old]
    mgr.check_invariants()

    # build batched block tables + lengths; run the kernel
    sids = [0, 1, 2]
    max_pages = max(len(mgr.block_table(s)) for s in sids)
    bt = np.zeros((3, max_pages), np.int32)
    lengths = np.zeros((3,), np.int32)
    for i, s in enumerate(sids):
        pages = mgr.block_table(s)
        bt[i, :len(pages)] = pages
        lengths[i] = mgr.seq_tokens(s)

    q = jnp.asarray(rng.normal(size=(3, N_KV, GROUP, D)), jnp.float32)
    out = ops.paged_attention(q, jnp.asarray(pool_k), jnp.asarray(pool_v),
                              jnp.asarray(bt), jnp.asarray(lengths),
                              page_size=PAGE, backend="interpret")

    # oracle: dense attention over each sequence's true tokens
    for i, s in enumerate(sids):
        tk, tv = seq_tokens[s]
        assert len(tk) == lengths[i]
        want = _dense_attention(q[i], jnp.asarray(tk), jnp.asarray(tv))
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
