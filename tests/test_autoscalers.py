"""Algorithm 1 (local) and Algorithm 2 / IBP (global) behaviour tests."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.backpressure import (LocalMetrics, interactive_backpressure,
                                     local_backpressure)
from repro.core.global_autoscaler import (BatchAutoscaler,
                                          InteractiveAutoscaler)
from repro.core.local_autoscaler import LocalAutoscaler
from repro.core.request_groups import make_request_groups
from repro.core.waiting_time import WaitingTimeEstimator
from repro.serving.request import make_batch
from repro.sim.perf_model import PerfModel


# ------------------------------------------------------------ backpressure
def test_backpressure_metrics():
    assert local_backpressure(0.4, 0.2, None, 10.0) == 2.0      # LBP wins
    assert local_backpressure(0.1, 0.2, 20.0, 10.0) == 2.0      # TBP wins
    assert local_backpressure(0.1, 0.2, 5.0, 10.0) == 0.5
    assert interactive_backpressure(2, 2, 4) == pytest.approx(1 / 3)


# ------------------------------------------------------------ Algorithm 1
def test_local_halves_on_violation():
    s = LocalAutoscaler(itl_slo=0.2, init_batch=64)
    s.update(LocalMetrics(observed_itl=0.4, throughput=100, itl_slo=0.2))
    assert s.max_batch_size == 32


def test_local_grows_when_under_slo():
    s = LocalAutoscaler(itl_slo=0.2, init_batch=8)
    s.update(LocalMetrics(observed_itl=0.1, throughput=100, itl_slo=0.2))
    assert s.max_batch_size > 8


def test_local_growth_slows_near_one():
    fast = LocalAutoscaler(itl_slo=0.2, init_batch=100)
    slow = LocalAutoscaler(itl_slo=0.2, init_batch=100)
    fast.update(LocalMetrics(0.05, 100, 0.2))
    slow.update(LocalMetrics(0.19, 100, 0.2))
    assert fast.max_batch_size > slow.max_batch_size > 100


@given(st.floats(0.01, 10.0), st.floats(0.01, 10.0))
@settings(max_examples=50, deadline=None)
def test_local_batch_stays_bounded(itl, thr):
    s = LocalAutoscaler(itl_slo=0.2, init_batch=16, min_batch=1,
                        max_batch=256)
    for _ in range(30):
        s.update(LocalMetrics(itl, thr, 0.2))
        assert 1 <= s.max_batch_size <= 256


def test_ewma_throughput_makes_ceiling_grain_robust():
    """ROADMAP robustness item (fig19_equiv regression): tick-grain noise
    on the throughput samples must not move Algorithm 1's batch-size
    ceiling — the EWMA input (plus the proportional mild step) keeps the
    noisy fixed point within a few percent of the clean one, where raw
    sampling used to collapse it (different ceilings per engine grain)."""
    import numpy as np
    pm = PerfModel("llama-8b")
    slo, ctx = 0.2, 1024.0

    def closed_loop(alpha, noise, seed=0, iters=300):
        rng = np.random.default_rng(seed)
        s = LocalAutoscaler(itl_slo=slo, init_batch=8, max_batch=4096,
                            thr_ewma_alpha=alpha)
        for _ in range(iters):
            b = s.max_batch_size
            eps = 1.0 + noise * rng.uniform(-1, 1)
            s.update(LocalMetrics(observed_itl=pm.itl(b, ctx),
                                  throughput=pm.throughput(b, ctx) * eps,
                                  itl_slo=slo))
        tail = s.history[-50:]
        return sum(tail) / len(tail)

    clean = closed_loop(0.5, 0.0)
    errs_smooth = [abs(closed_loop(0.5, 0.03, seed) - clean) / clean
                   for seed in range(3)]
    errs_raw = [abs(closed_loop(1.0, 0.03, seed) - clean) / clean
                for seed in range(3)]
    assert max(errs_smooth) < 0.10, errs_smooth
    assert max(errs_smooth) <= max(errs_raw) + 1e-9


def test_local_converges_against_perf_model():
    """Closed loop against the analytic data plane: Algorithm 1 must settle
    near the true optimum (paper Fig. 11/12 behaviour)."""
    pm = PerfModel("llama-8b")
    slo, ctx = 0.2, 1024.0
    opt = pm.optimal_batch(slo, ctx)
    s = LocalAutoscaler(itl_slo=slo, init_batch=8, max_batch=4096)
    for _ in range(60):
        b = s.max_batch_size
        s.update(LocalMetrics(observed_itl=pm.itl(b, ctx),
                              throughput=pm.throughput(b, ctx),
                              itl_slo=slo))
    assert s.converged(window=8, tol=0.35)
    tail = s.history[-8:]
    mean_b = sum(tail) / len(tail)
    assert 0.4 * opt <= mean_b <= 1.6 * opt, (mean_b, opt)


# ------------------------------------------------------------ IBP scaler
def test_interactive_scaler_adds_on_high_ibp():
    sc = InteractiveAutoscaler(theta=1 / 3, delta=0.05)
    d = sc.update(n_running_interactive=3, n_interactive=0, n_mixed=4)
    assert d.delta_instances > 0        # ibp=0.75 >> theta
    target = 3 + d.delta_instances + 1  # adding one more would exceed need
    assert 3 / (4 + d.delta_instances) <= 1 / 3 + 0.05


def test_interactive_scaler_removes_on_low_ibp():
    sc = InteractiveAutoscaler(theta=1 / 3, delta=0.05, min_instances=1)
    d = sc.update(n_running_interactive=1, n_interactive=0, n_mixed=12)
    assert d.delta_instances < 0


def test_interactive_scaler_stable_in_band():
    sc = InteractiveAutoscaler(theta=1 / 3, delta=0.1)
    d = sc.update(n_running_interactive=1, n_interactive=1, n_mixed=2)
    assert d.delta_instances == 0


# ------------------------------------------------------------ Algorithm 2
def _queue(n, ttft, now=0.0):
    return [make_batch(128, 256, arrival=now, ttft_slo=ttft)
            for _ in range(n)]


def _mk_scaler(throughput=1000.0):
    est = WaitingTimeEstimator()
    est.output_model.mu, est.output_model.sigma = 256.0, 64.0
    return BatchAutoscaler(est, instance_token_throughput=throughput)


def test_batch_scaler_zero_when_no_queue():
    sc = _mk_scaler()
    d = sc.update([], now=0.0, n_batch_instances=0)
    assert d.add_instances == 0 and not d.retire_all


def test_batch_scaler_retires_when_idle():
    sc = _mk_scaler()
    d = sc.update([], now=0.0, n_batch_instances=3,
                  n_active_batch_requests=0)
    assert d.retire_all


def test_batch_scaler_adds_min_instances():
    """Algorithm 2 must return the MINIMUM count driving BBP to zero."""
    sc = _mk_scaler(throughput=1000.0)
    q = _queue(2000, ttft=600.0)   # 2000 reqs * 256 tok / 1000 tok/s
    d = sc.update(q, now=100.0, n_batch_instances=0)
    add = d.add_instances
    assert add >= 1
    groups = d.groups
    # minimality: one fewer instance leaves BBP > 0
    if add > 1:
        assert sc.compute_bbp(groups, 100.0,
                              (add - 1) * 1000.0) > 0
    assert sc.compute_bbp(groups, 100.0, add * 1000.0) == 0


@given(st.integers(10, 3000), st.floats(60.0, 3600.0))
@settings(max_examples=20, deadline=None)
def test_batch_scaler_minimality_property(n, ttft):
    sc = _mk_scaler(throughput=2000.0)
    q = _queue(n, ttft=ttft)
    d = sc.update(q, now=0.0, n_batch_instances=0)
    if 0 < d.add_instances < sc.max_add_per_cycle:
        assert sc.compute_bbp(d.groups, 0.0, d.add_instances * 2000.0) == 0
        assert sc.compute_bbp(d.groups, 0.0,
                              (d.add_instances - 1) * 2000.0) > 0


def test_spare_mixed_capacity_reduces_instances():
    sc = _mk_scaler(throughput=1000.0)
    q = _queue(1000, ttft=300.0)
    d_no_spare = sc.update(q, now=0.0, n_batch_instances=0)
    d_spare = sc.update(q, now=0.0, n_batch_instances=0,
                        spare_mixed_throughput=2000.0)
    assert d_spare.add_instances <= d_no_spare.add_instances


def test_batch_scaler_scales_down_excess_while_bbp_zero():
    """Algorithm 2 minimality (stale-instance fix): with BBP already 0,
    instances that remain unnecessary even after derating the surviving
    capacity are surrendered instead of lingering while groups trickle in."""
    sc = _mk_scaler(throughput=1000.0)
    q = _queue(10, ttft=3600.0)          # tiny draining queue, far deadline
    d = sc.update(q, now=0.0, n_batch_instances=8)
    assert d.add_instances == 0 and d.bbp_before == 0
    assert d.remove_instances >= 1
    # never surrenders capacity needed to keep BBP at zero
    left = 8 - d.remove_instances
    assert sc.compute_bbp(
        d.groups, 0.0,
        max(sc.scale_down_derate * left * 1000.0, 1e-9)) == 0


def test_batch_scaler_never_removes_needed_capacity():
    sc = _mk_scaler(throughput=1000.0)
    # 2000 reqs * 256 tok = 512k tokens; 600 s deadline -> ~853 tok/s needed
    q = _queue(2000, ttft=600.0)
    d = sc.update(q, now=0.0, n_batch_instances=1)
    assert d.remove_instances == 0


def test_batch_scaler_retire_all_unchanged_when_queue_empty():
    sc = _mk_scaler()
    d = sc.update([], now=0.0, n_batch_instances=4,
                  n_active_batch_requests=0)
    assert d.retire_all and d.remove_instances == 0
