"""End-to-end behaviour tests for the paper's system (Chiron).

These mirror the paper's headline claims at reduced scale:
- hierarchical autoscaling keeps SLOs while using fewer GPU-hours than a
  utilization autoscaler on a mixed interactive+batch workload;
- the ablation ordering (full Chiron >= single-level arms) holds;
- the whole pipeline (workload -> queue -> routing -> scaling -> metrics)
  conserves requests.
"""
import pytest

from repro.serving.request import RequestState, RequestType
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController, LlumnixController
from repro.sim.simulator import default_perf_factory, simulate
from repro.sim.workload import WorkloadSpec, generate


def _spec(seed=11, **kw):
    base = dict(n_requests=150, arrival_rate=8.0, interactive_frac=1.0,
                batch_queue_size=350, batch_ttft_slo=900.0, seed=seed)
    base.update(kw)
    return WorkloadSpec(**base)


def _run(ctrl, spec, max_time=1500):
    cluster = SimCluster(default_perf_factory(), max_chips=200)
    return simulate(generate(spec), ctrl, cluster, max_time=max_time,
                    warm_start=2)


def test_full_pipeline_conserves_and_meets_slos():
    res = _run(ChironController(), _spec())
    assert res.completion_rate() == 1.0
    assert res.slo_attainment(RequestType.INTERACTIVE) > 0.7
    assert res.ttft_attainment(RequestType.BATCH) > 0.7


def test_chiron_more_efficient_than_llumnix():
    res_c = _run(ChironController(), _spec(seed=21))
    res_l = _run(LlumnixController(), _spec(seed=21))
    done_c = sum(r.state == RequestState.FINISHED for r in res_c.requests)
    done_l = sum(r.state == RequestState.FINISHED for r in res_l.requests)
    eff_c = res_c.gpu_hours() / max(done_c, 1)
    eff_l = res_l.gpu_hours() / max(done_l, 1)
    assert eff_c < eff_l


def test_ablation_ordering():
    """Fig. 18: both levels contribute.

    - vs global-only (static batch size): the local autoscaler lifts
      per-instance throughput;
    - vs local-only (no instance scaling): the global autoscaler adds the
      batch instances needed to meet TTFT deadlines under backlog.
    """
    spec = _spec(seed=31, n_requests=400, arrival_rate=20.0,
                 batch_queue_size=20000, batch_ttft_slo=120.0)
    full = _run(ChironController(), spec, max_time=1200)
    spec_l = _spec(seed=31, n_requests=400, arrival_rate=20.0,
                   batch_queue_size=20000, batch_ttft_slo=120.0)
    local_only = _run(ChironController(global_enabled=False), spec_l,
                      max_time=1200)
    spec_g = _spec(seed=31, n_requests=400, arrival_rate=20.0,
                   batch_queue_size=20000, batch_ttft_slo=120.0)
    global_only = _run(ChironController(local_enabled=False,
                                        static_batch=48), spec_g,
                       max_time=1200)
    # local contribution: higher per-instance throughput than static batch
    assert full.per_instance_throughput() > \
        global_only.per_instance_throughput()
    # global contribution: batch TTFT attainment under backlog
    assert full.ttft_attainment(RequestType.BATCH) > \
        local_only.ttft_attainment(RequestType.BATCH)
