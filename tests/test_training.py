"""Training substrate: AdamW, checkpointing, loss-goes-down."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step
from repro.launch.train import synthetic_lm_batch
from repro.models import Model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import adamw_init, adamw_update


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, info = adamw_update(g, opt, params, lr=0.05,
                                         weight_decay=0.0)
    np.testing.assert_allclose(params["w"], [1.0, 1.0], atol=0.05)


def test_grad_clip():
    params = {"w": jnp.asarray([0.0])}
    opt = adamw_init(params)
    g = {"w": jnp.asarray([1e9])}
    p2, opt2, info = adamw_update(g, opt, params, lr=0.1, grad_clip=1.0)
    assert float(info["grad_norm"]) == 1e9
    assert abs(float(p2["w"][0])) < 1.0   # clipped update


def test_lm_training_loss_decreases():
    cfg = get_smoke_config("olmo-1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, remat=False, lr=3e-3))
    rng = np.random.default_rng(0)
    losses = []
    for i in range(30):
        batch = synthetic_lm_batch(rng, model, 4, 32)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_remat_matches_no_remat():
    cfg = get_smoke_config("granite-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = model.example_batch(2, 32, jax.random.PRNGKey(1),
                                dtype=jnp.float32)
    l1 = model.loss(params, batch, remat=False)
    l2 = model.loss(params, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: model.loss(p, batch, remat=False))(params)
    g2 = jax.grad(lambda p: model.loss(p, batch, remat=True))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_checkpoint_roundtrip():
    cfg = get_smoke_config("olmo-1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, meta={"arch": cfg.name})
        zeros = jax.tree.map(jnp.zeros_like, params)
        restored = load_checkpoint(d, zeros)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
