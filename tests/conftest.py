import os
import sys

# Tests run on the single real CPU device (the dry-run's 512-device flag is
# process-local to repro.launch.dryrun and must NOT leak here).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
