"""QLM waiting-time estimator: online fitting + CLT sharpening property."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.waiting_time import OutputLengthModel, WaitingTimeEstimator


def test_output_model_fits():
    m = OutputLengthModel()
    rng = np.random.default_rng(0)
    xs = rng.lognormal(5.0, 0.8, 500)
    for x in xs:
        m.observe(int(x))
    assert abs(m.mu - xs.astype(int).mean()) < 1.0
    assert abs(m.sigma - xs.astype(int).std()) < 2.0


def test_waiting_time_eq1():
    est = WaitingTimeEstimator()
    est.output_model.mu = 100.0
    # Eq 1: W = sum O_i / Theta = 10*100/500
    assert est.waiting_time(10, 500.0) == 2.0
    assert est.waiting_time(10, 500.0, n_instances=2) == 1.0
    assert est.waiting_time(0, 500.0) == 0.0


@given(seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_clt_relative_error_shrinks(seed):
    """Paper Fig. 14: estimates sharpen as the queue grows — the relative
    error of total-token prediction at q=2000 must beat q=20 on average."""
    rng = np.random.default_rng(seed)
    m = OutputLengthModel()
    for x in rng.lognormal(5.0, 0.8, 300):
        m.observe(int(x))

    def rel_err(q, trials=30):
        errs = []
        for _ in range(trials):
            actual = rng.lognormal(5.0, 0.8, q).astype(int).sum()
            pred = q * m.mu
            errs.append(abs(pred - actual) / actual)
        return np.mean(errs)

    assert rel_err(2000) < rel_err(20)
