"""Flight recorder: decision-ledger equivalence, deterministic span
sampling, exporter round-trips, gating, and the telemetry overhead
guard.

The correctness bar mirrors tests/test_ledger.py's decision-equivalence
suite: on seeded scenarios the recorder must be a *passive* observer —
telemetry-on runs bit-identical to telemetry-off — while its decision
ledger alone reconstructs the run's scale-action totals and the
per-type instance timeline exactly (replay equivalence).
"""
import json
import math
import os

import numpy as np
import pytest

from repro.obs import FlightRecorder, resolve
from repro.obs.export import to_jsonl, to_perfetto, to_prometheus
from repro.obs.recorder import (HANDBACK, KIND_NAMES, MIGRATE, PROVISION,
                                RETIRE)
from repro.sim.cluster import SimCluster
from repro.sim.controllers import ChironController
from repro.sim.metrics import Timeline, TimelinePoint
from repro.sim.scenarios import build_trace
from repro.sim.simulator import (default_perf_factory, simulate,
                                 simulate_events, simulate_fleet)


def _run(name, seed=7, *, n=0, telemetry=None):
    trace, kw = build_trace(name, n_requests=n, seed=seed)
    cluster = SimCluster(default_perf_factory(), max_chips=400)
    ctrl = ChironController(models=kw["models"]) if "models" in kw \
        else ChironController()
    return simulate_events(trace, ctrl, cluster, max_time=kw["max_time"],
                           warm_start=2, failures=kw.get("failures"),
                           degradations=kw.get("degradations"),
                           telemetry=telemetry)


def _run_fleet(name, seed=7, *, n=600, telemetry=None):
    trace, kw = build_trace(name, n_requests=n, seed=seed)
    return simulate_fleet(trace, kw["fleet"](), max_time=kw["max_time"],
                          warm_start=1, telemetry=telemetry)


def _fingerprint(res):
    return (res.scale_ups, res.scale_downs, res.peak_chips, res.n_events,
            res.failures, res.degradations, res.duration,
            res.chip_seconds,
            tuple((p.t, p.n_interactive, p.n_mixed, p.n_batch, p.chips,
                   p.q_interactive, p.q_batch) for p in res.timeline))


# --------------------------------------------------------- passive observer
@pytest.mark.parametrize("scenario", ["diurnal", "multi_model_fleet"])
def test_telemetry_off_bit_identical(scenario):
    off = _run(scenario, telemetry=False)
    on = _run(scenario, telemetry=True)
    assert off.telemetry is None
    assert on.telemetry is not None
    assert _fingerprint(off) == _fingerprint(on)


# -------------------------------------------------------- replay equivalence
@pytest.mark.parametrize("scenario",
                         ["diurnal", "multi_model_fleet",
                          "instance_failures"])
def test_replay_reconstructs_scale_actions(scenario):
    res = _run(scenario, telemetry=True)
    rep = res.telemetry.replay()
    assert rep["scale_ups"] == res.scale_ups
    assert rep["scale_downs"] == res.scale_downs
    assert rep["failures"] == res.failures
    assert rep["degradations"] == res.degradations


@pytest.mark.parametrize("scenario", ["diurnal", "multi_model_fleet",
                                      "instance_failures"])
def test_replay_rebuilds_instance_timeline(scenario):
    res = _run(scenario, telemetry=True)
    tl = res.timeline
    counts = res.telemetry.replay_instance_counts(tl.col("t"))
    assert (counts[:, 0] == tl.col("n_interactive")).all()
    assert (counts[:, 1] == tl.col("n_mixed")).all()
    assert (counts[:, 2] == tl.col("n_batch")).all()


def test_fleet_replay_multi_region():
    res = _run_fleet("multi_region", telemetry=True)
    rec = res.telemetry
    rep = rec.replay()
    assert rep["scale_ups"] == res.scale_ups
    assert rep["scale_downs"] == res.scale_downs
    assert rep["migrations"] == res.migrations
    assert rep["handbacks"] == res.handbacks
    # all three regional clusters registered under their spec names and
    # produced per-tick rows
    assert set(rec.cluster_names) == {"us-central", "eu-west", "ap-south"}
    assert set(np.unique(rec.cticks.col("cluster"))) == {0, 1, 2}
    # decision rows carry the cluster they fired on
    kinds = rec.decisions.col("kind")
    assert (kinds == PROVISION).sum() == res.scale_ups
    assert (kinds == RETIRE).sum() == res.scale_downs
    if res.migrations:
        assert (kinds == MIGRATE).sum() == res.migrations
    if res.handbacks:
        sel = kinds == HANDBACK
        assert int(rec.decisions.col("count")[sel].sum()) == res.handbacks
        # hand-backs name a destination peer
        assert (rec.decisions.col("peer")[sel] >= 0).all()


def test_decision_timeline_is_ordered_and_labelled():
    res = _run("burst_spikes", telemetry=True)
    rec = res.telemetry
    t = rec.decisions.col("t")
    assert (np.diff(t) >= 0).all()
    kinds = rec.decisions.col("kind")
    assert set(np.unique(kinds)).issubset(set(range(len(KIND_NAMES))))
    # provisions report the chip delta they caused
    sel = kinds == PROVISION
    assert (rec.decisions.col("chips_after")[sel]
            > rec.decisions.col("chips_before")[sel]).all()


# ------------------------------------------------------------ span sampling
def test_span_sampling_deterministic():
    a = _run("diurnal", telemetry=FlightRecorder(span_sample=0.5,
                                                 span_seed=3)).telemetry
    b = _run("diurnal", telemetry=FlightRecorder(span_sample=0.5,
                                                 span_seed=3)).telemetry
    for name in ("t", "row", "event"):
        assert (a.spans.col(name) == b.spans.col(name)).all()
    # instance ids draw from a process-global counter, so they shift
    # between runs — but the assignment *pattern* must be identical
    _, ia = np.unique(a.spans.col("instance"), return_inverse=True)
    _, ib = np.unique(b.spans.col("instance"), return_inverse=True)
    assert (ia == ib).all()
    # a different seed samples a different subset of rows
    c = _run("diurnal", telemetry=FlightRecorder(span_sample=0.5,
                                                 span_seed=4)).telemetry
    assert set(np.unique(a.spans.col("row"))) \
        != set(np.unique(c.spans.col("row")))
    # sampled() is the verdict the hot path applied
    rows_a = set(np.unique(a.spans.col("row")).tolist())
    assert all(a.sampled(r) for r in rows_a)
    assert 0 < a.spans.n < c.spans.n + a.spans.n  # both non-empty


def test_span_sample_full_coverage():
    res = _run("diurnal", telemetry=FlightRecorder(span_sample=1.0))
    rec = res.telemetry
    led = res.ledger
    # every request that ever ran produced at least one admit span
    ran = set(np.flatnonzero(~np.isnan(led.first_token_time)).tolist())
    spanned = set(np.unique(rec.spans.col("row")).tolist())
    assert ran <= spanned
    # half-rate sampling keeps roughly half (deterministic hash, not RNG)
    half = _run("diurnal",
                telemetry=FlightRecorder(span_sample=0.5)).telemetry
    frac = len(np.unique(half.spans.col("row"))) / max(len(spanned), 1)
    assert 0.3 < frac < 0.7


# ---------------------------------------------------------------- exporters
def test_jsonl_roundtrip_and_cli(tmp_path, capsys):
    res = _run("multi_model_fleet", telemetry=True)
    rec = res.telemetry
    path = tmp_path / "run.jsonl"
    n_lines = to_jsonl(res, path)
    lines = [json.loads(s) for s in path.read_text().splitlines()]
    assert len(lines) == n_lines
    assert lines[0]["kind"] == "meta"
    assert lines[0]["scale_ups"] == res.scale_ups
    by_kind = {}
    for row in lines:
        by_kind.setdefault(row["kind"], []).append(row)
    assert len(by_kind["signal"]) == rec.signals.n
    assert len(by_kind["cluster"]) == rec.cticks.n
    assert len(by_kind["decision"]) == rec.decisions.n
    assert len(by_kind["timeline"]) == len(res.timeline)
    # decisions decode their vocabularies
    acts = {r["action"] for r in by_kind["decision"]}
    assert acts <= set(KIND_NAMES)
    # timeline rows carry the per-model queue-depth split
    models = sorted({r["model"] for r in by_kind["signal"]})
    assert all(sorted(r["q_by_model"]) == models
               for r in by_kind["timeline"])
    # the dashboard CLI consumes the export end-to-end
    from repro.obs.__main__ import main
    assert main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "control plane" in out
    assert "decision ledger" in out
    assert "request waterfalls" in out
    for m in models:
        assert f"model {m}" in out
    # --model filters to one dashboard
    assert main([str(path), "--model", models[0],
                 "--waterfalls", "3"]) == 0
    out = capsys.readouterr().out
    assert f"model {models[0]}" in out
    assert f"model {models[1]}" not in out


def test_perfetto_schema(tmp_path):
    res = _run("diurnal", telemetry=True)
    path = tmp_path / "trace.json"
    doc = to_perfetto(res, path)
    assert json.loads(path.read_text()) == doc
    events = doc["traceEvents"]
    assert events, "empty trace"
    phases = {e["ph"] for e in events}
    assert phases <= {"M", "C", "X"}
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
            assert e["ts"] >= 0.0
            assert e["name"] in ("queued", "prefill", "decode", "exec")
        elif e["ph"] == "C":
            assert e["name"] in ("queue_depth", "chips")
    # one queued span per sampled request
    n_queued = sum(e["ph"] == "X" and e["name"] == "queued"
                   for e in events)
    assert n_queued == len(np.unique(res.telemetry.spans.col("row")))


def test_prometheus_text(tmp_path):
    res = _run("diurnal", telemetry=True)
    text = to_prometheus(res)
    assert "# TYPE chiron_scale_actions_total counter" in text
    assert f'chiron_scale_actions_total{{action="scale_ups"}} ' \
        f"{res.scale_ups}" in text
    assert "chiron_slo_attainment" in text
    assert "chiron_completion_rate" in text
    assert "chiron_queue_depth" in text
    assert "chiron_chips_in_use" in text
    path = tmp_path / "metrics.prom"
    to_prometheus(res, path)
    assert path.read_text() == text


def test_export_requires_telemetry():
    res = _run("diurnal", n=50, telemetry=False)
    with pytest.raises(ValueError, match="telemetry"):
        to_prometheus(res)


# ------------------------------------------------------------------- gating
def test_resolve_env_gating(monkeypatch):
    monkeypatch.delenv("CHIRON_TELEMETRY", raising=False)
    assert resolve(None) is None
    assert resolve(False) is None
    assert isinstance(resolve(True), FlightRecorder)
    rec = FlightRecorder(span_sample=0.25)
    assert resolve(rec) is rec
    monkeypatch.setenv("CHIRON_TELEMETRY", "1")
    assert isinstance(resolve(None), FlightRecorder)
    assert resolve(False) is None
    for off in ("", "0", "false", "no"):
        monkeypatch.setenv("CHIRON_TELEMETRY", off)
        assert resolve(None) is None


def test_fixed_tick_rejects_telemetry():
    trace, kw = build_trace("trace_replay", n_requests=40, seed=1)
    cluster = SimCluster(default_perf_factory(), max_chips=64)
    with pytest.raises(ValueError, match="event"):
        simulate(trace, ChironController(), cluster, engine="fixed",
                 max_time=kw["max_time"], telemetry=True)


# ------------------------------------------------------- columnar timeline
def test_timeline_columnar_backcompat():
    res = _run("multi_model_fleet", telemetry=False)
    tl = res.timeline
    assert isinstance(tl, Timeline)
    assert len(tl) == tl.n > 0
    p = tl[-1]
    assert isinstance(p, TimelinePoint)
    assert p.t == tl.col("t")[-1]
    assert [q.t for q in tl[1:3]] == list(tl.col("t")[1:3])
    assert len(list(iter(tl))) == len(tl)
    with pytest.raises(IndexError):
        tl[len(tl)]
    # per-model depth columns tile the aggregate columns
    models = tl.queue_models()
    assert models
    qi = sum(tl.q_interactive_for(m).astype(np.int64) for m in models)
    qb = sum(tl.q_batch_for(m).astype(np.int64) for m in models)
    assert (qi == tl.col("q_interactive")).all()
    assert (qb == tl.col("q_batch")).all()
    # unknown models read as empty lanes, not errors
    assert (tl.q_interactive_for("no-such-model") == 0).all()


def test_instance_counts_at_matches_object_view():
    res = _run("diurnal", n=300, telemetry=False)
    tl = res.timeline
    for p in (tl[0], tl[len(tl) // 2], tl[-1]):
        assert res.instance_counts_at(p.t) \
            == (p.n_interactive, p.n_mixed, p.n_batch)


# ----------------------------------------------------------- overhead guard
def test_telemetry_overhead_guard():
    """Telemetry-on must stay within a few percent of telemetry-off on
    the diurnal scenario. The committed benchmark
    (BENCH_scenarios.json: ``diurnal_telemetry``) pins the <5% events/s
    acceptance number under best-of-repeats; this in-test guard uses
    CPU time with a wider margin so CI noise cannot flake it while an
    order-of-magnitude regression (e.g. un-staged per-row numpy writes)
    still fails fast."""
    import time

    def timed(telemetry):
        trace, kw = build_trace("diurnal", n_requests=3000, seed=7)
        cluster = SimCluster(default_perf_factory(), max_chips=400)
        t0 = time.process_time()
        simulate_events(trace, ChironController(), cluster,
                        max_time=kw["max_time"], warm_start=2,
                        telemetry=telemetry)
        return time.process_time() - t0

    best_on = best_off = math.inf
    for i in range(6):
        if i % 2:
            best_off = min(best_off, timed(False))
            best_on = min(best_on, timed(True))
        else:
            best_on = min(best_on, timed(True))
            best_off = min(best_off, timed(False))
    assert best_on <= best_off * 1.25, \
        f"telemetry overhead {best_on / best_off - 1:.1%} (limit 25%)"
