"""Additional hypothesis properties on substrate invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models import layers as L
from repro.sim.perf_model import PerfModel
from repro.sim.workload import WorkloadSpec, generate


# ------------------------------------------------------------- fit_cache
@given(total=st.integers(1, 40), clen=st.integers(1, 48),
       window=st.sampled_from([0, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_fit_cache_shapes_and_slots(total, clen, window):
    Lyr, B, Hkv, D = 2, 1, 2, 4
    if window:
        clen = min(clen, window)
    ks = jnp.arange(Lyr * B * total * Hkv * D, dtype=jnp.float32) \
        .reshape(Lyr, B, total, Hkv, D)
    vs = ks + 1
    ko, vo, sp = L.fit_cache(ks, vs, total, clen, window, B)
    assert ko.shape == (Lyr, B, clen, Hkv, D)
    assert sp.shape == (B, clen)
    spn = np.asarray(sp[0])
    # every retained absolute position appears exactly once, and the
    # retained set is exactly the last min(total, clen) positions
    kept = sorted(p for p in spn if p >= 0)
    expect = list(range(max(total - clen, 0), total))
    assert kept == expect
    # slot contents match: cache[slot] holds position sp[slot]
    for slot, pos in enumerate(spn):
        if pos < 0:
            continue
        np.testing.assert_array_equal(np.asarray(ko[:, 0, slot]),
                                      np.asarray(ks[:, 0, pos]))


# ------------------------------------------------------------- RoPE
@given(pos=st.integers(0, 16384), shift=st.integers(1, 1000))
@settings(max_examples=30, deadline=None)
def test_rope_relative_property(pos, shift):
    """<rope(q,i), rope(k,j)> depends only on i-j (relative encoding).

    Bounded to pos <= 16k: f32 angle computation loses the property's
    precision beyond ~1e5 absolute positions (production long-context
    decode sidesteps this via the 4096-token sliding window, where
    relative offsets stay small; exact 500k absolute RoPE would need f64
    angles)."""
    D = 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))

    def score(i, j):
        ci, si = L.rope_angles(jnp.array([[i]], jnp.float32), D, 1e4)
        cj, sj = L.rope_angles(jnp.array([[j]], jnp.float32), D, 1e4)
        qi = L.apply_rope(q, ci, si)
        kj = L.apply_rope(k, cj, sj)
        return float(jnp.sum(qi * kj))

    a = score(pos, pos + shift)
    b = score(pos + 7, pos + shift + 7)
    # f32 trig at positions up to 1e5 carries ~1e-3 relative error
    np.testing.assert_allclose(a, b, rtol=5e-3, atol=1e-3)


# ------------------------------------------------------------- perf model
@given(b1=st.integers(1, 2000), b2=st.integers(1, 2000),
       ctx=st.sampled_from([128.0, 1024.0, 4096.0]))
@settings(max_examples=50, deadline=None)
def test_perf_model_itl_monotone_in_batch(b1, b2, ctx):
    pm = PerfModel("llama-8b")
    lo, hi = min(b1, b2), max(b1, b2)
    assert pm.itl(lo, ctx) <= pm.itl(hi, ctx) * 1.0001


@given(rate=st.floats(0.5, 200.0), n=st.integers(10, 300),
       seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_workload_generator_sane(rate, n, seed):
    reqs = generate(WorkloadSpec(n_requests=n, arrival_rate=rate, seed=seed))
    assert len(reqs) == n
    ts = [r.arrival_time for r in reqs]
    assert ts == sorted(ts)
    assert all(r.prompt_len >= 4 and r.output_len >= 4 for r in reqs)
    assert all(r.prompt_len <= 2048 and r.output_len <= 2048 for r in reqs)
    # empirical rate within a loose factor of the target
    dur = ts[-1] - ts[0]
    if dur > 1:
        emp = (n - 1) / dur
        assert 0.3 * rate < emp < 3.0 * rate
