"""The assigned architecture table, verified exactly."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config

# (arch, type, L, d_model, H, kv, d_ff, vocab)
ASSIGNED = [
    ("olmo-1b", "dense", 16, 2048, 16, 16, 8192, 50304),
    ("granite-8b", "dense", 36, 4096, 32, 8, 14336, 49152),
    ("zamba2-2.7b", "hybrid", 54, 2560, 32, 32, 10240, 32000),
    ("phi3-mini-3.8b", "dense", 32, 3072, 32, 32, 8192, 32064),
    ("yi-34b", "dense", 60, 7168, 56, 8, 20480, 64000),
    ("mamba2-1.3b", "ssm", 48, 2048, 0, 0, 0, 50280),
    ("qwen2-moe-a2.7b", "moe", 24, 2048, 16, 16, 1408, 151936),
    ("deepseek-moe-16b", "moe", 28, 2048, 16, 16, 1408, 102400),
    ("whisper-base", "audio", 6, 512, 8, 8, 2048, 51865),
    ("internvl2-2b", "vlm", 24, 2048, 16, 8, 8192, 92553),
]


@pytest.mark.parametrize("arch,atype,L,d,H,kv,ff,V", ASSIGNED)
def test_assigned_config_exact(arch, atype, L, d, H, kv, ff, V):
    cfg = get_config(arch)
    assert cfg.arch_type == atype
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    assert cfg.source, "every config must cite its source"


def test_moe_details():
    q = get_config("qwen2-moe-a2.7b")
    assert (q.moe.n_experts, q.moe.n_shared_experts,
            q.moe.experts_per_token) == (60, 4, 4)
    d = get_config("deepseek-moe-16b")
    assert (d.moe.n_experts, d.moe.n_shared_experts,
            d.moe.experts_per_token) == (64, 2, 6)


def test_ssm_details():
    m = get_config("mamba2-1.3b")
    assert m.ssm.state_dim == 128
    z = get_config("zamba2-2.7b")
    assert z.ssm.state_dim == 64
    assert z.attn_every > 0 and z.n_layers % z.attn_every == 0


def test_smoke_configs_reduced():
    for arch in ASSIGNED_ARCHS:
        s = get_smoke_config(arch)
        assert s.n_layers <= 2
        assert s.d_model <= 512
        if s.is_moe:
            assert s.moe.n_experts <= 4
        assert s.arch_type == get_config(arch).arch_type


def test_param_counts_plausible():
    # sanity: headline sizes within ~45% of the advertised parameter count
    expect = {"olmo-1b": 1.2e9, "granite-8b": 8e9, "yi-34b": 34e9,
              "mamba2-1.3b": 1.3e9, "phi3-mini-3.8b": 3.8e9,
              "deepseek-moe-16b": 16e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.55 * n < got < 1.45 * n, (arch, got)
