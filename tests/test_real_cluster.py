"""Real-plane cluster: the identical ChironController over real JAX
engines — provision, route, preempt, migrate, retire."""
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.serving.real_cluster import RealCluster, RealInstance, serve_forever
from repro.serving.request import (Request, RequestState, RequestType,
                                   make_batch, make_interactive)
from repro.sim.cluster import InstanceType
from repro.sim.controllers import ChironController


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("olmo-1b")


def test_chiron_controller_drives_real_engines(cfg):
    cluster = RealCluster(cfg, max_chips=4, max_slots=3, max_len=64)
    ctrl = ChironController(model="llama-8b", init_batch=2, max_batch=3)
    reqs = ([make_interactive(8, 6, arrival=0.0) for _ in range(4)] +
            [make_batch(8, 10, arrival=0.0, ttft_slo=30.0)
             for _ in range(4)])
    # deterministic fake clock: one "second" per call
    t = iter(range(100000))
    out = serve_forever(reqs, ctrl, cluster,
                        clock=lambda: float(next(t)) * 0.05,
                        max_steps=800)
    assert out["finished"] == out["total"] == 8, out
    assert cluster.scale_ups >= 1
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert r.tokens_generated >= r.output_len


def test_migration_preserves_generation(cfg):
    a = RealInstance(cfg, InstanceType.MIXED, 0.0, max_slots=2, max_len=64)
    b = RealInstance(cfg, InstanceType.MIXED, 0.0, max_slots=2, max_len=64)
    a.activate_if_ready(0.0)
    b.activate_if_ready(0.0)
    req = make_batch(8, 16)
    a.admit(req, 0.0)
    for _ in range(5):
        a.step(0.0)
    toks_before = req.tokens_generated
    assert toks_before > 0

    cluster = RealCluster.__new__(RealCluster)  # migrate() only needs ducks
    assert RealCluster.migrate(cluster, req.req_id, a, b)
    assert a.n_running == 0
    while req.state != RequestState.FINISHED:
        st = b.step(0.0)
        if not st.n_active and not b.engine.waiting:
            break
    assert req.state == RequestState.FINISHED
    assert req.tokens_generated >= req.output_len
    assert req.tokens_generated >= toks_before  # no progress lost


def test_rebalance_moves_batch_off_crowded(cfg):
    cluster = RealCluster(cfg, max_chips=2, max_slots=2, max_len=64)
    a = cluster.provision("x", InstanceType.MIXED, 0.0, static_batch=2)
    b = cluster.provision("x", InstanceType.MIXED, 0.0, static_batch=2)
    a.activate_if_ready(0.0)
    b.activate_if_ready(0.0)
    for r in (make_batch(8, 30), make_batch(8, 30)):
        a.admit(r, 0.0)
    a.step(0.0)
    assert a.n_running == 2 and b.n_running == 0
    moved = cluster.rebalance(0.0)
    b.step(0.0)
    assert moved == 1
    assert a.n_running == 1 and b.n_running == 1
