"""Fleet plane: router SLO-headroom decisions, migration warm-up delay
semantics, per-cluster budget isolation, placement drain, slow-node
degradation (detection + route-around), per-model controller clocks, and
the deterministic ``multi_region`` end-to-end acceptance run."""
import numpy as np
import pytest

from repro.serving.global_queue import GlobalQueue
from repro.serving.request import make_batch, make_interactive
from repro.sim.cluster import InstanceType, SimCluster
from repro.sim.controllers import ChironController, _best_fit
from repro.sim.fleet import (ACCELERATORS, ClusterSpec, Fleet, FleetTopology,
                             GlobalPlacer, Region)
from repro.sim.scenarios import build_trace
from repro.sim.simulator import (DegradationPlan, default_perf_factory,
                                 simulate_events, simulate_fleet)

MODEL = "llama-8b"


def _fleet(specs, regions=None, **kw):
    regions = regions or sorted({s.region for s in specs})
    topo = FleetTopology([Region(r) for r in regions])
    return Fleet(specs, topo, models=(MODEL,), **kw)


def _fill_instance(fc, n=4, now=0.0):
    """One active instance with ``n`` slots, all occupied."""
    inst = fc.cluster.provision(MODEL, InstanceType.MIXED, now,
                                static_batch=n)
    inst.ready_time = now
    inst.activate_if_ready(now)
    for k in range(n):
        inst.admit(make_interactive(64, 32, now), now)
    return inst


# ------------------------------------------------------------------ router
def test_router_interactive_prefers_origin_region():
    fleet = _fleet([ClusterSpec("us-a", "us", max_chips=40),
                    ClusterSpec("eu-a", "eu", max_chips=40)])
    fc, delay = fleet.route(make_interactive(100, 50, 0.0), 0.0)
    # no origin -> first topology region ("eu" sorts first here)
    assert fc.region == fleet.topology.regions[0]
    req = make_interactive(100, 50, 0.0)
    req.origin = "us"
    fc, delay = fleet.route(req, 0.0)
    assert fc.name == "us-a"
    assert delay == fleet.topology.intra_latency
    assert fc.stats.remote_served == 0


def test_router_interactive_spills_over_on_saturation():
    fleet = _fleet([ClusterSpec("us-a", "us", max_chips=4),
                    ClusterSpec("eu-a", "eu", max_chips=40)])
    us = fleet.by_name["us-a"]
    _fill_instance(us, n=4)      # all slots busy, budget exhausted
    assert us.interactive_headroom(MODEL) == 0
    req = make_interactive(100, 50, 0.0)
    req.origin = "us"
    fc, delay = fleet.route(req, 0.0)
    assert fc.name == "eu-a"                  # spillover
    assert delay == fleet.topology.latency("us", "eu")
    assert fc.stats.remote_served == 1
    assert fleet.egress_bytes > 0             # prompt crossed a region


def test_router_batch_picks_cheapest_then_backpressure_positive():
    fleet = _fleet([ClusterSpec("us-base", "us", accelerator="v5e",
                                max_chips=40),
                    ClusterSpec("us-econ", "us", accelerator="v4e",
                                max_chips=8)])
    econ, base = fleet.by_name["us-econ"], fleet.by_name["us-base"]
    assert econ.batch_cost_per_mtoken(MODEL) < \
        base.batch_cost_per_mtoken(MODEL)
    fc, _ = fleet.route(make_batch(100, 50, 0.0), 0.0)
    assert fc.name == "us-econ"               # cheapest per token
    # saturate the economy cluster's queue far past its headroom: the
    # router must route batch to the next-cheapest positive cluster
    for k in range(int(econ.batch_headroom(MODEL)) + 500):
        econ.queue.push(make_batch(100, 50, 0.0))
    assert econ.batch_headroom(MODEL) < 0
    fc, _ = fleet.route(make_batch(100, 50, 0.0), 0.0)
    assert fc.name == "us-base"


def test_best_fit_routes_around_suspected_slow_instances():
    cluster = SimCluster(default_perf_factory(), max_chips=40)
    a = cluster.provision(MODEL, InstanceType.MIXED, 0.0, static_batch=8)
    b = cluster.provision(MODEL, InstanceType.MIXED, 0.0, static_batch=8)
    for i in (a, b):
        i.ready_time = 0.0
        i.activate_if_ready(0.0)
    # b is busier (packing would pick it) but suspected slow
    b.admit(make_interactive(64, 32, 0.0), 0.0)
    b.health_ewma = 3.0
    assert _best_fit([a, b]) is a
    # with no healthy candidate the degraded pool still serves
    a.health_ewma = 3.0
    assert _best_fit([a, b]) is b


# --------------------------------------------------------------- migration
def test_migration_warm_up_delay_semantics():
    fleet = _fleet([ClusterSpec("us-a", "us", max_chips=40),
                    ClusterSpec("eu-a", "eu", max_chips=40)],
                   placement={MODEL: ["us-a"]})
    eu = fleet.by_name["eu-a"]
    assert eu.resident == {}
    req = make_interactive(100, 50, 0.0)
    req.origin = "eu"
    fc, _ = fleet.route(req, 0.0)
    assert fc.name == "us-a"                  # only resident copy

    warms = []
    egress_before = fleet.egress_bytes
    fleet.placer.ensure_resident(MODEL, eu, 0.0,
                                 lambda d, p: warms.append((d, p)))
    assert eu.resident[MODEL] == "warming"
    assert fleet.migrations == 1
    perf = eu.perf_factory(MODEL)
    (delay, payload), = warms
    # warm-up = cross-region weight transfer + model load, with the
    # weights' egress charged to the source cluster
    assert delay == pytest.approx(perf.model_load_time()
                                  + perf.weight_bytes
                                  / fleet.placer.wan_bw)
    assert fleet.egress_bytes - egress_before == perf.weight_bytes
    assert fleet.by_name["us-a"].stats.egress_bytes == perf.weight_bytes

    # while warming the router still avoids the cluster...
    fc, _ = fleet.route(req, 1.0)
    assert fc.name == "us-a"
    # re-ensuring is a no-op (no double migration)
    fleet.placer.ensure_resident(MODEL, eu, 1.0,
                                 lambda d, p: warms.append((d, p)))
    assert fleet.migrations == 1 and len(warms) == 1
    # ...and serves only after the warm-up event fires
    fleet.on_warm(payload, delay)
    assert eu.resident[MODEL] == "active"
    assert MODEL in eu.controller._configured
    fc, _ = fleet.route(req, delay)
    assert fc.name == "eu-a"


def test_placer_drains_idle_placement_and_hands_back_queue():
    fleet = _fleet([ClusterSpec("us-a", "us", max_chips=40),
                    ClusterSpec("eu-a", "eu", max_chips=40)])
    placer = fleet.placer
    eu = fleet.by_name["eu-a"]
    # demand exists only in us; eu sits idle through drain_strikes reviews
    now = 0.0
    for round_ in range(placer.drain_strikes + 1):
        for k in range(60):
            req = make_interactive(100, 50, now)
            req.origin = "us"
            placer.observe_arrival(req, now)
        now += placer.interval
        placer.review(now, lambda d, p: None)
    assert MODEL not in eu.resident           # drained
    assert MODEL not in eu.controller._configured
    assert eu.stats.migrations_out == 1
    # the us placement survives (never the last active copy, and needed)
    assert fleet.by_name["us-a"].resident[MODEL] == "active"


def test_drain_redispatch_accounts_from_source_and_drops_saved_kv():
    """Work leaving a drained cluster pays the hop from *that* cluster
    (not the request's origin) and loses its host-saved KV — another
    cluster's hosts never held it, so the restart must re-prefill."""
    from repro.sim.fleet import TOKEN_BYTES
    fleet = _fleet([ClusterSpec("us-a", "us", max_chips=40),
                    ClusterSpec("eu-a", "eu", max_chips=40)])
    eu = fleet.by_name["eu-a"]
    req = make_batch(200, 50, 0.0)
    req.origin = "us"                 # origin-side latency would be 0
    req.saved_kv = ("sim", 123.0)     # preempted here, KV on eu hosts
    eu.queue.requeue(req)
    (r, dest, delay), = fleet.drain(MODEL, eu, 0.0)
    assert r is req and r.saved_kv is None
    assert dest.name == "us-a"
    assert delay == fleet.topology.latency("eu", "us")   # hop from eu
    assert eu.stats.egress_bytes == 200 * TOKEN_BYTES
    assert MODEL not in eu.resident


def test_queue_drain_model_empties_every_lane():
    q = GlobalQueue()
    i1 = make_interactive(10, 5, 0.0, model="a")
    b1 = make_batch(10, 5, 0.0, model="a")
    b2 = make_batch(10, 5, 1.0, model="a")
    other = make_batch(10, 5, 0.0, model="b")
    for r in (i1, b1, b2, other):
        q.push(r)
    out = q.drain_model("a")
    assert [r.req_id for r in out] == [i1.req_id, b1.req_id, b2.req_id]
    assert q.n_interactive == 0 and q.n_batch == 1
    assert q.pop_batch_fcfs("b") is other


# ----------------------------------------------------------- degradation
def test_degradation_inflates_itl_and_is_detected():
    cluster = SimCluster(default_perf_factory(), max_chips=40)
    inst = cluster.provision(MODEL, InstanceType.MIXED, 0.0, static_batch=8)
    inst.ready_time = 0.0
    inst.activate_if_ready(0.0)
    inst.admit(make_interactive(64, 128, 0.0), 0.0)
    healthy_itl = inst.current_itl()
    cluster.degrade_instance(inst, 4.0, 0.0)
    assert inst.current_itl() == pytest.approx(4.0 * healthy_itl)
    assert cluster.degradations == 1
    assert not inst.suspected_slow
    for _ in range(4):                        # control ticks accumulate EWMA
        inst.update_health()
    assert inst.suspected_slow
    cluster.recover_instance(inst, 1.0)
    assert inst.current_itl() == pytest.approx(healthy_itl)
    for _ in range(6):
        inst.update_health()
    assert not inst.suspected_slow            # detection clears


def test_recovered_idle_instance_clears_suspicion():
    """Routing refuses suspected instances, so a victim that drained its
    work must still decay its health flag after recovery — otherwise the
    healthy capacity would be stranded forever."""
    cluster = SimCluster(default_perf_factory(), max_chips=40)
    inst = cluster.provision(MODEL, InstanceType.MIXED, 0.0, static_batch=8)
    inst.ready_time = 0.0
    inst.activate_if_ready(0.0)
    inst.health_ewma = 4.0                    # quarantined, then drained
    cluster.recover_instance(inst, 1.0)       # no running work
    assert inst.n_running == 0
    for _ in range(4):                        # idle control ticks probe it
        inst.update_health()
    assert not inst.suspected_slow


def test_slow_nodes_scenario_deterministic_and_survives():
    trace, kw = build_trace("slow_nodes", n_requests=500, seed=4)
    assert isinstance(kw["degradations"], DegradationPlan)

    def run():
        t, k = build_trace("slow_nodes", n_requests=500, seed=4)
        return simulate_events(
            t, ChironController(),
            SimCluster(default_perf_factory(), max_chips=200),
            max_time=k["max_time"], warm_start=2,
            degradations=k["degradations"])

    res_a, res_b = run(), run()
    assert res_a.degradations >= 1
    assert res_a.completion_rate() == 1.0
    assert res_a.summary() == res_b.summary()
    assert "degradations" in res_a.summary()


# ------------------------------------------------- per-model controller
def test_per_model_estimators_do_not_share_output_fits():
    ctrl = ChironController(models=["llama-8b", "llama-70b"])
    for _ in range(30):
        ctrl.observe_completion(make_batch(10, 100, 0.0, model="llama-8b"))
        ctrl.observe_completion(make_batch(10, 1000, 0.0,
                                           model="llama-70b"))
    mu8 = ctrl._estimator_for("llama-8b").output_model.mu
    mu70 = ctrl._estimator_for("llama-70b").output_model.mu
    assert mu8 == pytest.approx(100.0)
    assert mu70 == pytest.approx(1000.0)
    # the primary model keeps the legacy `estimator` field itself
    assert ctrl._estimator_for("llama-8b") is ctrl.estimator


def test_per_model_theta_refresh_cadence():
    ctrl = ChironController(models=["llama-8b", "llama-70b"],
                            auto_theta=True, theta_refresh=100.0,
                            theta_refresh_per_model={"llama-70b": 10.0})
    assert ctrl._next_theta_update == {"llama-8b": 100.0,
                                       "llama-70b": 10.0}
    ctrl._refresh_theta(10.0)
    # only the fast-cadence model's clock advanced
    assert ctrl._next_theta_update == {"llama-8b": 100.0,
                                       "llama-70b": 20.0}
    ctrl._refresh_theta(100.0)
    assert ctrl._next_theta_update == {"llama-8b": 200.0,
                                       "llama-70b": 110.0}


# --------------------------------------------------------- fleet end-to-end
def test_per_cluster_budget_isolation():
    trace, kw = build_trace("regional_spillover", n_requests=800, seed=3)
    fleet = kw["fleet"]()
    res = simulate_fleet(trace, fleet, max_time=kw["max_time"],
                         warm_start=1)
    assert res.completion_rate() == 1.0
    for fc in fleet.clusters:
        assert fc.stats.peak_chips <= fc.spec.max_chips
    # the spike exceeded the small cluster: its budget pinned at its own
    # cap while the big cluster absorbed the spill
    us = fleet.by_name["us-edge"]
    assert us.stats.peak_chips <= us.spec.max_chips == 4


def test_multi_region_deterministic():
    def run():
        trace, kw = build_trace("multi_region", n_requests=600, seed=7)
        return simulate_fleet(trace, kw["fleet"](),
                              max_time=kw["max_time"], warm_start=1)
    assert run().summary() == run().summary()


def test_multi_region_consolidates_batch_and_keeps_interactive_slo():
    """The acceptance run: batch work lands on the cheapest cluster while
    interactive SLO attainment matches the single-cluster baseline on the
    same trace, with migration/egress counters in the summary."""
    trace, kw = build_trace("multi_region", n_requests=2000, seed=11)
    fleet = kw["fleet"]()
    res = simulate_fleet(trace, fleet, max_time=kw["max_time"],
                         warm_start=1)
    assert res.completion_rate() == 1.0
    s = res.summary()
    for key in ("migrations", "egress_gb", "fleet_cost_usd"):
        assert key in s
    cheapest = min(fleet.clusters,
                   key=lambda fc: fc.batch_cost_per_mtoken(MODEL))
    assert cheapest.name == "us-central"
    assert s[f"cluster:{cheapest.name}:batch_share"] >= 0.6

    # single-cluster baseline: same trace, one cluster holding the whole
    # fleet's chip budget and no network hops
    total_chips = sum(fc.spec.max_chips for fc in fleet.clusters)
    base = simulate_events(
        trace, ChironController(),
        SimCluster(default_perf_factory(), max_chips=total_chips),
        max_time=kw["max_time"], warm_start=3)
    assert s["slo_interactive"] >= base.summary()["slo_interactive"]
