"""CLI launcher smoke tests (subprocess): train.py and serve.py run
end-to-end on reduced configs."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m", *args], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli():
    out = _run(["repro.launch.train", "--arch", "olmo-1b", "--steps", "6",
                "--batch", "2", "--seq", "32", "--log-every", "5"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout
    # first-vs-last line present
    assert "->" in out.stdout


def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "olmo-1b", "--requests",
                "6", "--max-slots", "4", "--max-len", "96"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 6/6 requests" in out.stdout


def test_dryrun_cli_smoke():
    """One small dry-run pair through the CLI (512 fake devices)."""
    out = _run(["repro.launch.dryrun", "--arch", "olmo-1b", "--shape",
                "decode_32k", "--no-unroll"], timeout=580)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "1/1 pairs lowered+compiled" in out.stdout
