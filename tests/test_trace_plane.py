"""Columnar trace plane: Trace round-trips, trace I/O, multi-model
routing/queueing, failure injection, and vectorized-generation scaling."""
import time

import numpy as np
import pytest

from repro.serving.global_queue import GlobalQueue
from repro.serving.request import RequestState, make_batch, make_interactive
from repro.sim.cluster import InstanceType, SimCluster, SimInstance
from repro.sim.controllers import ChironController
from repro.sim.perf_model import PerfModel
from repro.sim.simulator import (FailurePlan, default_perf_factory,
                                 simulate_events)
from repro.sim.trace_io import load_trace, save_trace
from repro.sim.workload import (Trace, WorkloadSpec, arrival_spikes,
                                generate, generate_trace, make_trace,
                                theta_from_history)


def _mixed_spec(n=400, seed=3):
    return WorkloadSpec(n_requests=n, arrival_rate=20.0,
                        interactive_frac=0.7, batch_queue_size=50,
                        batch_ttft_slo=600.0, seed=seed)


# ------------------------------------------------------------ Trace basics
def test_trace_matches_legacy_generate():
    """generate() and generate_trace() must describe the same workload
    (same RNG draw order), request by request."""
    spec = _mixed_spec()
    reqs = generate(spec)
    tr = generate_trace(spec)
    assert tr.n == len(reqs)
    assert np.all(np.diff(tr.arrival) >= 0)
    for i, r in enumerate(reqs):
        assert r.arrival_time == tr.arrival[i]
        assert r.prompt_len == tr.prompt_len[i]
        assert r.output_len == tr.output_len[i]
        assert r.is_interactive == bool(tr.interactive[i])
        assert r.slo.ttft == tr.ttft_slo[i]
        assert r.model == tr.models[tr.model_idx[i]]


def test_trace_from_requests_roundtrip():
    reqs = generate(_mixed_spec(100, seed=5))
    tr = Trace.from_requests(reqs)
    back = tr.materialize()
    assert len(back) == len(reqs)
    for a, b in zip(reqs, back):
        assert (a.arrival_time, a.prompt_len, a.output_len, a.request_type,
                a.slo.ttft, a.slo.itl, a.model) == \
               (b.arrival_time, b.prompt_len, b.output_len, b.request_type,
                b.slo.ttft, b.slo.itl, b.model)


def test_trace_concat_merges_model_vocabularies():
    a = make_trace(np.array([0.0, 1.0]), np.array([8, 8]), np.array([4, 4]),
                   np.array([True, True]), models=("m1",))
    b = make_trace(np.array([0.5]), np.array([8]), np.array([4]),
                   np.array([True]), models=("m2",))
    c = Trace.concat([a, b]).sorted_by_arrival()
    assert c.models == ("m1", "m2")
    assert [c.models[i] for i in c.model_idx] == ["m1", "m2", "m1"]


def test_trace_column_validation():
    with pytest.raises(ValueError):
        Trace(np.zeros(3), np.zeros(2), np.zeros(3), np.zeros(3, bool),
              np.zeros(3), np.zeros(3), np.zeros(3, np.int32))
    with pytest.raises(ValueError):
        make_trace(np.zeros(2), np.zeros(2), np.zeros(2),
                   np.zeros(2, bool), model_idx=np.array([0, 5]))


# ------------------------------------------------------------ trace I/O
@pytest.mark.parametrize("ext", ["csv", "jsonl"])
def test_trace_file_roundtrip_identical_requests(tmp_path, ext):
    """Synthetic scenario -> file -> Trace -> identical requests."""
    spec = _mixed_spec(200, seed=7)
    tr = generate_trace(spec)
    path = str(tmp_path / f"trace.{ext}")
    save_trace(tr, path)
    tr2 = load_trace(path)
    assert tr2.n == tr.n
    assert np.array_equal(tr.arrival, tr2.arrival)
    assert np.array_equal(tr.prompt_len, tr2.prompt_len)
    assert np.array_equal(tr.output_len, tr2.output_len)
    assert np.array_equal(tr.interactive, tr2.interactive)
    assert np.array_equal(tr.ttft_slo, tr2.ttft_slo)
    assert np.array_equal(tr.itl_slo, tr2.itl_slo)
    assert [tr.models[i] for i in tr.model_idx] == \
           [tr2.models[i] for i in tr2.model_idx]
    for a, b in zip(tr.materialize(), tr2.materialize()):
        assert (a.arrival_time, a.prompt_len, a.output_len, a.request_type,
                a.slo.ttft, a.slo.itl, a.model) == \
               (b.arrival_time, b.prompt_len, b.output_len, b.request_type,
                b.slo.ttft, b.slo.itl, b.model)


def test_load_azure_style_csv(tmp_path):
    """Azure-LLM-inference columns + ISO timestamps normalize to t0=0."""
    p = tmp_path / "azure.csv"
    p.write_text("TIMESTAMP,ContextTokens,GeneratedTokens\n"
                 "2023-11-16 18:17:04.250,100,200\n"
                 "2023-11-16 18:17:03.000,50,30\n")
    tr = load_trace(str(p))
    assert tr.n == 2
    assert tr.arrival.tolist() == [0.0, 1.25]       # sorted + normalized
    assert tr.prompt_len.tolist() == [50, 100]
    assert tr.interactive.all()                     # class defaults


def test_load_trace_max_requests_and_missing_columns(tmp_path):
    p = tmp_path / "t.csv"
    p.write_text("arrival,prompt_len\n0.0,10\n")
    with pytest.raises(ValueError):
        load_trace(str(p))
    tr = generate_trace(_mixed_spec(50, seed=11))
    path = str(tmp_path / "t2.csv")
    save_trace(tr, path)
    assert load_trace(path, max_requests=10).n == 10


# ----------------------------------------------------- vectorized analysis
def test_arrival_spikes_bincount_matches_loop():
    tr = generate_trace(WorkloadSpec(n_requests=2000, arrival_rate=30.0,
                                     process="gamma", cv=3.0, seed=9))
    spikes = arrival_spikes(tr, 30.0)
    # reference: the seed's per-request loop
    end = tr.arrival.max()
    counts = [0] * (int(end / 30.0) + 1)
    for t in tr.arrival:
        counts[int(t / 30.0)] += 1
    ref = [b / a for a, b in zip(counts, counts[1:]) if a > 0]
    assert np.allclose(np.asarray(ref), spikes)
    # same answer through every input form
    reqs = tr.materialize()
    assert np.allclose(arrival_spikes(reqs, 30.0), spikes)
    assert np.allclose(arrival_spikes(tr.arrival, 30.0), spikes)
    th = theta_from_history(tr)
    assert 0.0 < th <= 1.0 and th == theta_from_history(reqs)


def test_columnar_generation_200k_smoke():
    """>=200k-request columnar generation must stay vectorized: a
    per-request Python loop costs seconds; the array path, milliseconds.
    Generous wall bound so CI noise can't flake it."""
    t0 = time.perf_counter()
    tr = generate_trace(WorkloadSpec(n_requests=200_000, arrival_rate=50.0,
                                     interactive_frac=0.8, seed=13))
    wall = time.perf_counter() - t0
    assert tr.n == 200_000
    assert wall < 2.0, f"200k columnar generation took {wall:.2f}s"
    t0 = time.perf_counter()
    arrival_spikes(tr, 30.0)
    assert time.perf_counter() - t0 < 0.5


# ------------------------------------------------------------ multi-model
def _two_model_trace(n=600, seed=1, frac=0.3):
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1 / 15.0, n))
    ins = np.clip(rng.lognormal(4.6, 1.0, n), 4, 2048).astype(np.int64)
    outs = np.clip(rng.lognormal(5.0, 0.9, n), 4, 2048).astype(np.int64)
    midx = (rng.random(n) < frac).astype(np.int32)
    return make_trace(times, ins, outs, np.ones(n, dtype=bool),
                      model_idx=midx, models=("llama-8b", "llama-70b"))


def test_wrong_model_admission_rejected():
    inst = SimInstance(PerfModel("llama-8b"), InstanceType.MIXED, 0.0,
                       static_batch=8)
    inst.ready_time = 0.0
    inst.activate_if_ready(0.0)
    assert inst.can_admit(make_interactive(10, 10, model="llama-8b"))
    assert not inst.can_admit(make_interactive(10, 10, model="llama-70b"))


def test_multi_model_routing_never_crosses_models(monkeypatch):
    """End to end: every admit pairs a request with an instance of the
    same model, and both models' requests all finish."""
    pairs = []
    orig_admit = SimInstance.admit

    def spy(self, req, now):
        pairs.append((self.model, req.model))
        return orig_admit(self, req, now)
    monkeypatch.setattr(SimInstance, "admit", spy)

    tr = _two_model_trace()
    ctrl = ChironController(models=["llama-8b", "llama-70b"])
    res = simulate_events(tr, ctrl, SimCluster(default_perf_factory(),
                                               max_chips=400),
                          max_time=1500, warm_start=2)
    assert res.completion_rate() == 1.0
    assert pairs and all(im == rm for im, rm in pairs)
    by_model = res.slo_by_model()
    assert set(by_model) == {"llama-8b", "llama-70b"}
    s = res.summary()
    assert "slo_model:llama-70b" in s and "slo_model:llama-8b" in s


def test_multi_model_discovered_from_arrivals():
    """Models not configured up front are registered on the fly."""
    tr = _two_model_trace(n=300, seed=4)
    ctrl = ChironController()            # single-model default config
    res = simulate_events(tr, ctrl, SimCluster(default_perf_factory(),
                                               max_chips=400),
                          max_time=1500, warm_start=2)
    assert res.completion_rate() == 1.0
    assert set(ctrl.model_list) == {"llama-8b", "llama-70b"}


def test_global_queue_model_lanes():
    q = GlobalQueue()
    a = make_interactive(10, 10, arrival=0.0, model="m1")
    b = make_interactive(10, 10, arrival=1.0, model="m2")
    c = make_batch(10, 10, arrival=0.0, model="m2", ttft_slo=50.0)
    d = make_batch(10, 10, arrival=0.0, model="m1", ttft_slo=500.0)
    for r in (a, b, c, d):
        q.push(r)
    assert q.n_interactive_for("m1") == 1 and q.n_batch_for("m2") == 1
    assert set(q.interactive_models()) == {"m1", "m2"}
    assert q.peek_interactive("m2") is b
    assert q.pop_interactive() is a          # global FIFO across lanes
    assert q.pop_interactive("m2") is b
    # batch: per-model pop respects the lane, global pop takes min deadline
    assert q.peek_batch("m1") is d
    assert q.pop_batch_fcfs() is c           # earlier deadline, other lane
    assert q.pop_batch_fcfs("m1") is d
    assert len(q) == 0


def test_global_queue_listener_model_filter():
    q = GlobalQueue()
    seen = []

    class L:
        def on_add(self, r):
            seen.append(("add", r.model))

        def on_remove(self, r):
            seen.append(("rm", r.model))

    q.push(make_batch(10, 10, 0.0, model="m1"))
    q.attach_batch_listener(L(), model="m1")     # replays current m1 work
    q.push(make_batch(10, 10, 1.0, model="m2"))  # filtered out
    q.push(make_batch(10, 10, 2.0, model="m1"))
    while q.pop_batch_fcfs() is not None:
        pass
    assert seen == [("add", "m1"), ("add", "m1"), ("rm", "m1"), ("rm", "m1")]


# ------------------------------------------------------ failure injection
def _failure_run(plan_seed, trace_seed=9):
    tr = generate_trace(WorkloadSpec(n_requests=800, arrival_rate=15.0,
                                     seed=trace_seed))
    plan = FailurePlan([20.0, 35.0, 50.0], seed=plan_seed)
    return simulate_events(tr, ChironController(),
                           SimCluster(default_perf_factory(),
                                      max_chips=400),
                           max_time=2000, warm_start=2, failures=plan)


def test_failure_injection_recovers_and_counts():
    res = _failure_run(1)
    assert res.failures >= 1
    assert res.completion_rate() == 1.0      # fleet heals, work re-queues
    assert all(r.state == RequestState.FINISHED for r in res.requests)
    assert res.summary()["failures"] == res.failures


def test_failure_injection_seed_deterministic():
    a, b, c = _failure_run(1), _failure_run(1), _failure_run(2)
    assert a.summary() == b.summary()
    assert a.failures == b.failures
    # a different victim draw must still finish all work (and normally
    # perturbs the run) — determinism is per seed, not per plan
    assert c.completion_rate() == 1.0


def test_failures_not_counted_as_scaling_actions():
    cluster = SimCluster(default_perf_factory(), max_chips=400)
    inst = cluster.provision("llama-8b", InstanceType.MIXED, 0.0,
                             static_batch=8)
    inst.ready_time = 0.0
    inst.activate_if_ready(0.0)
    ups, downs = cluster.scale_ups, cluster.scale_downs
    cluster.fail_instance(inst)
    assert cluster.failures == 1
    assert (cluster.scale_ups, cluster.scale_downs) == (ups, downs)
    assert not cluster.instances


# ----------------------------------------------- streaming / gzip / origin
def test_gzip_round_trip_csv_and_jsonl(tmp_path):
    tr = generate_trace(_mixed_spec(120, seed=9))
    for name in ("t.csv.gz", "t.jsonl.gz"):
        p = str(tmp_path / name)
        save_trace(tr, p)
        back = load_trace(p)
        assert np.array_equal(back.arrival, tr.arrival)
        assert np.array_equal(back.prompt_len, tr.prompt_len)
        assert np.array_equal(back.itl_slo, tr.itl_slo)


def test_origin_column_round_trip(tmp_path):
    n = 60
    rng = np.random.default_rng(0)
    tr = make_trace(np.sort(rng.uniform(0, 10, n)), np.full(n, 100),
                    np.full(n, 50), np.ones(n, dtype=bool),
                    origin_idx=rng.integers(0, 3, n).astype(np.int32),
                    origins=("us", "eu", "ap"))
    p = str(tmp_path / "t.csv")
    save_trace(tr, p)
    back = load_trace(p)
    # vocabulary order may differ (np.unique sorts); the per-request
    # origin names must survive exactly
    want = [tr.origins[i] for i in tr.origin_idx]
    got = [back.origins[i] for i in back.origin_idx]
    assert got == want
    reqs = back.materialize()
    assert [r.origin for r in reqs] == want


def test_stream_trace_chunks_match_bulk_load(tmp_path):
    from repro.sim.trace_io import stream_trace
    tr = generate_trace(_mixed_spec(200, seed=13))
    p = str(tmp_path / "t.csv.gz")
    save_trace(tr, p)
    chunks = list(stream_trace(p, chunk_requests=32))
    assert len(chunks) == -(-tr.n // 32)
    assert sum(c.n for c in chunks) == tr.n
    merged = Trace.concat(chunks)
    bulk = load_trace(p)
    assert np.array_equal(merged.arrival, bulk.arrival)
    assert np.array_equal(merged.prompt_len, bulk.prompt_len)
    assert np.array_equal(merged.interactive, bulk.interactive)
    # max_requests truncates the stream
    assert sum(c.n for c in stream_trace(p, chunk_requests=32,
                                         max_requests=50)) == 50


def test_stream_trace_time_windowed_chunks(tmp_path):
    """window_s > 0: chunk boundaries fall on wall-clock windows (with
    chunk_requests as the per-window memory cap), and the merged stream
    equals the bulk load."""
    from repro.sim.trace_io import stream_trace
    n = 300
    rng = np.random.default_rng(7)
    tr = make_trace(np.sort(rng.uniform(0.0, 120.0, n)),
                    np.full(n, 100), np.full(n, 50),
                    np.ones(n, dtype=bool))
    p = str(tmp_path / "t.csv")
    save_trace(tr, p)
    chunks = list(stream_trace(p, window_s=10.0))
    # every chunk lives inside one 10 s window
    for c in chunks:
        assert np.floor(c.arrival[0] / 10.0) == np.floor(
            c.arrival[-1] / 10.0)
    merged = Trace.concat(chunks)
    assert np.array_equal(merged.arrival, tr.arrival)
    # a dense window is still capped by chunk_requests
    capped = list(stream_trace(p, window_s=1000.0, chunk_requests=64))
    assert all(c.n <= 64 for c in capped)
    assert sum(c.n for c in capped) == n


def test_stream_trace_windowed_epoch_timestamps(tmp_path):
    """Large absolute arrivals (un-normalized unix-epoch seconds) must
    not spin the window cursor from zero — the boundary jumps straight
    to the first arrival's window."""
    from repro.sim.trace_io import stream_trace
    n = 10
    rng = np.random.default_rng(0)
    tr = make_trace(1.75e9 + np.sort(rng.uniform(0.0, 5.0, n)),
                    np.full(n, 100), np.full(n, 50),
                    np.ones(n, dtype=bool))
    p = str(tmp_path / "epoch.csv")
    save_trace(tr, p)
    chunks = list(stream_trace(p, window_s=0.05))   # hangs pre-fix
    assert sum(c.n for c in chunks) == n


def test_stream_trace_multi_file_concatenation(tmp_path):
    """A list of day-per-file traces streams back to back; an
    out-of-order file boundary raises."""
    from repro.sim.trace_io import stream_trace
    n = 80
    rng = np.random.default_rng(3)
    day1 = make_trace(np.sort(rng.uniform(0.0, 50.0, n)),
                      np.full(n, 100), np.full(n, 50),
                      np.ones(n, dtype=bool))
    day2 = make_trace(np.sort(rng.uniform(50.0, 100.0, n)),
                      np.full(n, 100), np.full(n, 50),
                      np.zeros(n, dtype=bool))
    p1, p2 = str(tmp_path / "d1.csv"), str(tmp_path / "d2.csv.gz")
    save_trace(day1, p1)
    save_trace(day2, p2)
    chunks = list(stream_trace([p1, p2], chunk_requests=37))
    merged = Trace.concat(chunks)
    assert merged.n == 2 * n
    assert np.array_equal(merged.arrival,
                          np.concatenate([day1.arrival, day2.arrival]))
    assert bool(merged.interactive[0]) and not bool(merged.interactive[-1])
    # wrong order -> the cross-file boundary check fires
    with pytest.raises(ValueError, match="arrival-sorted"):
        list(stream_trace([p2, p1], chunk_requests=37))
    # windowed replay drives the event core end to end
    from repro.sim.simulator import simulate_events
    res = simulate_events(
        stream_trace([p1, p2], window_s=25.0), ChironController(),
        SimCluster(default_perf_factory(), max_chips=400),
        max_time=600.0, warm_start=2)
    assert res.completion_rate() == 1.0
    assert res.ledger is not None and res.ledger.n == 2 * n


def test_trace_stream_rejects_unsorted_chunk_interior():
    """The boundary check must see the *sorted* chunk: a chunk whose
    first raw row is in order but whose minimum is not must still fail."""
    from repro.sim.workload import TraceStream

    def chunk(times):
        n = len(times)
        return make_trace(np.array(times, dtype=np.float64),
                          np.full(n, 100), np.full(n, 50),
                          np.ones(n, dtype=bool), sort=False)

    stream = TraceStream([chunk([0.0, 100.0]), chunk([150.0, 50.0])])
    next(stream)
    with pytest.raises(ValueError, match="arrival-sorted"):
        next(stream)


def test_stream_trace_rejects_unsorted_file(tmp_path):
    p = str(tmp_path / "bad.csv")
    with open(p, "w") as f:
        f.write("arrival,prompt_len,output_len\n")
        for t in (0.0, 1.0, 2.0, 0.5, 3.0):    # out of order across chunks
            f.write(f"{t},100,50\n")
    from repro.sim.trace_io import stream_trace
    with pytest.raises(ValueError, match="arrival-sorted"):
        list(stream_trace(p, chunk_requests=2))


def test_event_core_replays_stream_identically(tmp_path):
    """A streamed replay must behave exactly like the bulk-loaded one."""
    from repro.sim.trace_io import stream_trace
    tr = generate_trace(_mixed_spec(300, seed=17))
    p = str(tmp_path / "t.jsonl.gz")
    save_trace(tr, p)

    def run(source):
        return simulate_events(
            source, ChironController(),
            SimCluster(default_perf_factory(), max_chips=200),
            max_time=3000.0, warm_start=2)

    res_stream = run(stream_trace(p, chunk_requests=64))
    res_bulk = run(load_trace(p))
    assert res_stream.completion_rate() == 1.0
    assert res_stream.summary() == res_bulk.summary()
