"""Runtime shadow-verify plane: seeded scenario runs must pass the
object→column rebuild exactly, and deliberate desyncs must be caught.

The verifier rebuilds ledger and instance-plane columns from the Python
objects at control ticks and completion sweeps; these tests drive it
through the same scenario library the equivalence suite uses (including
``vec_min=1`` so the vectorized plane is live from the first instance,
and the failure/degradation variants that exercise plane free/repack).
"""
import pytest

from repro.analysis.shadow import ShadowVerifier, ShadowVerifyError
from repro.sim.cluster import SimCluster, SimInstance
from repro.sim.controllers import ChironController
from repro.sim.ledger import QUEUED
from repro.sim.scenarios import build_trace
from repro.sim.simulator import (default_perf_factory, simulate_events,
                                 simulate_fleet)


def _run_events(name, seed, *, vec_min=None, shadow=None, n=0):
    trace, kw = build_trace(name, n_requests=n, seed=seed)
    cluster = SimCluster(default_perf_factory(), max_chips=400)
    if vec_min is not None:
        cluster.vec_min = vec_min
    ctrl = ChironController(models=kw["models"]) if "models" in kw \
        else ChironController()
    return simulate_events(trace, ctrl, cluster, max_time=kw["max_time"],
                           warm_start=2, failures=kw.get("failures"),
                           degradations=kw.get("degradations"),
                           shadow_verify=shadow)


# ----------------------------------------------------- scenario sweeps
@pytest.mark.parametrize("name,seed", [("diurnal", 7),
                                       ("multi_model_fleet", 11)])
def test_scenarios_pass_shadow_verify(name, seed):
    shadow = ShadowVerifier()
    res = _run_events(name, seed, shadow=shadow)
    assert res.completion_rate() > 0
    assert shadow.ledger_checks > 0
    assert shadow.queue_checks > 0


@pytest.mark.parametrize("name,seed", [("diurnal", 3),
                                       ("multi_model_fleet", 5)])
def test_scenarios_pass_with_plane_always_live(name, seed):
    # vec_min=1 arms the vectorized instance plane from the first
    # instance, so every control tick audits the columns
    shadow = ShadowVerifier()
    _run_events(name, seed, shadow=shadow, vec_min=1)
    assert shadow.plane_checks > 0
    assert shadow.ledger_checks > 0


@pytest.mark.parametrize("name", ["instance_failures", "slow_nodes"])
def test_failure_and_degradation_variants_pass(name):
    # failure frees plane slots, degradation rewrites slow factors —
    # both must keep the columns bit-identical to the objects
    shadow = ShadowVerifier()
    _run_events(name, 13, shadow=shadow, vec_min=1)
    assert shadow.plane_checks > 0


def test_multi_region_fleet_passes_shadow_verify():
    trace, kw = build_trace("multi_region", 0, seed=3)
    fleet = kw["fleet"]()
    for fc in fleet.clusters:
        fc.cluster.vec_min = 1
    shadow = ShadowVerifier()
    res = simulate_fleet(trace, fleet, max_time=kw["max_time"],
                         shadow_verify=shadow)
    assert res.completion_rate() > 0
    assert shadow.plane_checks > 0
    assert shadow.ledger_checks > 0


def test_env_var_resolves_to_verifier(monkeypatch):
    from repro.analysis.shadow import resolve
    monkeypatch.delenv("CHIRON_SHADOW_VERIFY", raising=False)
    assert resolve(None) is None
    monkeypatch.setenv("CHIRON_SHADOW_VERIFY", "0")
    assert resolve(None) is None
    monkeypatch.setenv("CHIRON_SHADOW_VERIFY", "1")
    assert isinstance(resolve(None), ShadowVerifier)
    sv = ShadowVerifier()
    assert resolve(sv) is sv


# --------------------------------------------------- deliberate desyncs
def test_skipping_sync_plane_is_caught(monkeypatch):
    # mutation: _sync_plane only refreshes the ETA stamp and never
    # writes the columns — the first live control tick must trip
    def broken(self):
        self._eta_stamp = -1
    monkeypatch.setattr(SimInstance, "_sync_plane", broken)
    with pytest.raises(ShadowVerifyError, match="plane column"):
        _run_events("diurnal", 7, shadow=ShadowVerifier(), vec_min=1)


def test_ledger_desync_is_caught(monkeypatch):
    # mutation: admit() runs normally, then the ledger row is knocked
    # back to QUEUED; ledger_interval=0 audits every control tick so
    # the corruption is seen while the request is still in flight
    orig_admit = SimInstance.admit

    def corrupt(self, req, *args, **kwargs):
        out = orig_admit(self, req, *args, **kwargs)
        led = getattr(self._cluster, "ledger", None) if self._cluster \
            else None
        if led is not None and req.row >= 0:
            led.state[req.row] = QUEUED
        return out

    monkeypatch.setattr(SimInstance, "admit", corrupt)
    with pytest.raises(ShadowVerifyError, match="ledger `state`"):
        _run_events("diurnal", 7,
                    shadow=ShadowVerifier(ledger_interval=0.0))


def test_queue_column_desync_is_caught(monkeypatch):
    # mutation: every lane push skews the arrival key column by one
    # second — the cell no longer rebuilds from the payload Request, so
    # the first control-tick audit that sees a queued request must trip
    from repro.serving.global_queue import _Lane
    orig_push = _Lane.push

    def skewed(self, s, req):
        orig_push(self, s, req)
        self.arrival[self.tail - 1] = req.arrival_time + 1.0

    monkeypatch.setattr(_Lane, "push", skewed)
    with pytest.raises(ShadowVerifyError, match="queue column"):
        _run_events("burst_spikes", 7, shadow=ShadowVerifier())


def test_queue_counter_desync_is_caught(monkeypatch):
    # mutation: push double-counts interactive arrivals — the maintained
    # O(1) counters drift from a recount of the live lane windows
    from repro.serving.global_queue import GlobalQueue
    orig_push = GlobalQueue.push

    def double(self, req):
        orig_push(self, req)
        self._icount += 1

    monkeypatch.setattr(GlobalQueue, "push", double)
    with pytest.raises(ShadowVerifyError, match="queue counters"):
        _run_events("burst_spikes", 7, shadow=ShadowVerifier())
