"""Scenario library: named workload generators beyond the paper's traces.

Each scenario builds a request list exercising a distinct control-plane
regime — diurnal capacity tracking, spike absorption (Theta), multi-tenant
SLO mixes, heavy-tail output lengths, and batch-backlog drains — in the
trace-driven multi-SLO evaluation style of SLOs-Serve (arXiv:2504.08784)
and the forecast/diurnal workloads of SageServe (arXiv:2502.14617).

Scenarios register into ``SCENARIOS`` and are consumed by
``benchmarks/scenario_sweep.py`` (and ``benchmarks/run.py``)::

    from repro.sim.scenarios import SCENARIOS, build
    reqs, sim_kw = build("diurnal", n_requests=5000, seed=0)

Every builder takes ``(n_requests, seed, **overrides)`` and returns
``(requests, sim_kwargs)`` where ``sim_kwargs`` carries a suggested
``max_time`` for the run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.serving.request import (BATCH_ITL_SLO, Request, RequestType, SLO,
                                   make_batch, make_interactive)
from repro.sim.workload import MAX_TOKENS, _token_lengths

SimKwargs = Dict[str, float]
Builder = Callable[..., Tuple[List[Request], SimKwargs]]


@dataclass
class Scenario:
    name: str
    description: str
    build: Builder
    default_n: int = 3000


SCENARIOS: Dict[str, Scenario] = {}


def register(name: str, description: str, default_n: int = 3000):
    def deco(fn: Builder) -> Builder:
        SCENARIOS[name] = Scenario(name, description, fn, default_n)
        return fn
    return deco


def build(name: str, n_requests: int = 0, seed: int = 0,
          **overrides) -> Tuple[List[Request], SimKwargs]:
    sc = SCENARIOS[name]
    return sc.build(n_requests or sc.default_n, seed, **overrides)


def _nonhomogeneous_arrivals(rng: np.random.Generator, n: int,
                             rate_fn: Callable[[np.ndarray], np.ndarray],
                             rate_max: float, horizon: float) -> np.ndarray:
    """Thinning sampler for a non-homogeneous Poisson process; returns the
    first ``n`` accepted arrival times (wraps the horizon if needed)."""
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        # draw candidate gaps in bulk at the envelope rate
        gaps = rng.exponential(1.0 / rate_max, size=max(n, 1024))
        ts = t + np.cumsum(gaps)
        keep = rng.random(ts.size) < rate_fn(ts % horizon) / rate_max
        out.extend(ts[keep].tolist())
        t = float(ts[-1])
    return np.asarray(out[:n])


# --------------------------------------------------------------- scenarios
@register("diurnal",
          "sinusoidal day/night arrival rate; capacity must track the wave",
          default_n=4000)
def diurnal(n_requests: int, seed: int = 0, *, period: float = 1800.0,
            base_rate: float = 6.0, amplitude: float = 0.85,
            interactive_frac: float = 0.85,
            batch_ttft_slo: float = 900.0) -> Tuple[List[Request], SimKwargs]:
    rng = np.random.default_rng(seed)
    rate_max = base_rate * (1 + amplitude)

    def rate(ts: np.ndarray) -> np.ndarray:
        return base_rate * (1 + amplitude * np.sin(2 * np.pi * ts / period))

    times = _nonhomogeneous_arrivals(rng, n_requests, rate, rate_max, period)
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    reqs = [make_interactive(int(ins[i]), int(outs[i]), float(times[i]))
            if cls[i] else
            make_batch(int(ins[i]), int(outs[i]), float(times[i]),
                       ttft_slo=batch_ttft_slo)
            for i in range(n_requests)]
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs, {"max_time": float(times[-1]) + 600.0}


@register("burst_spikes",
          "quiet Poisson base + short high-rate spikes separated by idle "
          "gaps; stresses Theta over-provisioning and idle-skip",
          default_n=4000)
def burst_spikes(n_requests: int, seed: int = 0, *, n_bursts: int = 8,
                 burst_rate: float = 120.0, base_rate: float = 0.5,
                 gap: float = 300.0,
                 interactive_frac: float = 1.0) -> Tuple[List[Request], SimKwargs]:
    rng = np.random.default_rng(seed)
    n_bursts = max(min(n_bursts, n_requests), 1)   # tiny-n guard
    per_burst = max(n_requests // n_bursts, 1)
    times: List[float] = []
    t0 = 30.0
    for _ in range(n_bursts):
        gaps = rng.exponential(1.0 / burst_rate, per_burst)
        ts = t0 + np.cumsum(gaps)
        times.extend(ts.tolist())
        t0 = float(ts[-1]) + gap
    # sparse background traffic between bursts
    n_bg = n_requests - per_burst * n_bursts
    if n_bg > 0:
        times.extend(rng.uniform(0.0, t0, n_bg).tolist())
    times = np.sort(np.asarray(times))
    ins, outs = _token_lengths(rng, len(times))
    cls = rng.random(len(times)) < interactive_frac
    reqs = [make_interactive(int(ins[i]), int(outs[i]), float(times[i]))
            if cls[i] else
            make_batch(int(ins[i]), int(outs[i]), float(times[i]))
            for i in range(len(times))]
    return reqs, {"max_time": float(times[-1]) + gap + 300.0}


@register("multi_tenant_slo",
          "four tenants with distinct (TTFT, ITL) SLO classes sharing the "
          "cluster: premium/standard interactive + urgent/overnight batch",
          default_n=4000)
def multi_tenant_slo(n_requests: int, seed: int = 0, *,
                     arrival_rate: float = 12.0) -> Tuple[List[Request], SimKwargs]:
    rng = np.random.default_rng(seed)
    # (weight, request_type, ttft_slo, itl_slo)
    tenants = [
        (0.35, RequestType.INTERACTIVE, 5.0, 0.1),     # premium chat
        (0.35, RequestType.INTERACTIVE, 15.0, 0.3),    # standard chat
        (0.15, RequestType.BATCH, 600.0, BATCH_ITL_SLO),   # urgent batch
        (0.15, RequestType.BATCH, 3600.0, BATCH_ITL_SLO),  # overnight batch
    ]
    gaps = rng.exponential(1.0 / arrival_rate, n_requests)
    times = np.cumsum(gaps)
    ins, outs = _token_lengths(rng, n_requests)
    weights = np.asarray([w for w, *_ in tenants])
    choice = rng.choice(len(tenants), size=n_requests,
                        p=weights / weights.sum())
    reqs = []
    for i in range(n_requests):
        _, rtype, ttft, itl = tenants[int(choice[i])]
        reqs.append(Request(int(ins[i]), int(outs[i]), rtype,
                            SLO(ttft, itl), float(times[i])))
    return reqs, {"max_time": float(times[-1]) + 900.0}


@register("heavy_tail",
          "Pareto-tailed output lengths (a few requests generate for "
          "minutes); stresses completion estimates and KV growth",
          default_n=2500)
def heavy_tail(n_requests: int, seed: int = 0, *, arrival_rate: float = 8.0,
               pareto_shape: float = 1.2,
               interactive_frac: float = 0.8) -> Tuple[List[Request], SimKwargs]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / arrival_rate, n_requests)
    times = np.cumsum(gaps)
    ins, _ = _token_lengths(rng, n_requests)
    outs = np.clip((rng.pareto(pareto_shape, n_requests) + 1) * 48,
                   4, 4 * MAX_TOKENS).astype(int)
    cls = rng.random(n_requests) < interactive_frac
    reqs = [make_interactive(int(ins[i]), int(outs[i]), float(times[i]))
            if cls[i] else
            make_batch(int(ins[i]), int(outs[i]), float(times[i]),
                       ttft_slo=1800.0)
            for i in range(n_requests)]
    return reqs, {"max_time": float(times[-1]) + 1800.0}


@register("backlog_drain",
          "large batch queue dumped at t=0 under a live interactive "
          "stream (Fig. 19 regime): deadline-driven bulk scaling",
          default_n=4000)
def backlog_drain(n_requests: int, seed: int = 0, *,
                  backlog_frac: float = 0.8, arrival_rate: float = 10.0,
                  batch_ttft_slo: float = 1200.0) -> Tuple[List[Request], SimKwargs]:
    rng = np.random.default_rng(seed)
    n_backlog = int(n_requests * backlog_frac)
    n_live = n_requests - n_backlog
    ins_b, outs_b = _token_lengths(rng, n_backlog)
    reqs = [make_batch(int(ins_b[i]), int(outs_b[i]), 0.0,
                       ttft_slo=batch_ttft_slo) for i in range(n_backlog)]
    gaps = rng.exponential(1.0 / arrival_rate, n_live)
    times = np.cumsum(gaps)
    ins_l, outs_l = _token_lengths(rng, n_live)
    reqs.extend(make_interactive(int(ins_l[i]), int(outs_l[i]),
                                 float(times[i])) for i in range(n_live))
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs, {"max_time": batch_ttft_slo + 1200.0}
