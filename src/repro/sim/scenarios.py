"""Scenario library: named workload generators beyond the paper's traces.

Each scenario builds a columnar :class:`~repro.sim.workload.Trace`
exercising a distinct control-plane regime — diurnal capacity tracking,
spike absorption (Theta), multi-tenant SLO mixes, heavy-tail output
lengths, batch-backlog drains, multi-model fleets, trace replay,
instance-failure injection, slow-node degradation, and the multi-cluster
fleet plane (region-aware routing, batch consolidation, spillover,
heterogeneous accelerators) — in the trace-driven multi-SLO evaluation
style of SLOs-Serve (arXiv:2504.08784) and the forecast/diurnal workloads
of SageServe (arXiv:2502.14617). Generation is fully vectorized (NumPy
column fills, no per-request Python loop), so million-request scenarios
build in well under a second.

Scenarios register into ``SCENARIOS`` and are consumed by
``benchmarks/scenario_sweep.py`` (and ``benchmarks/run.py``)::

    from repro.sim.scenarios import SCENARIOS, build, build_trace
    reqs, sim_kw = build("diurnal", n_requests=5000, seed=0)      # Requests
    trace, sim_kw = build_trace("trace_replay", n_requests=10**6) # columnar

Every builder takes ``(n_requests, seed, **overrides)`` and returns
``(trace, sim_kwargs)``; ``build`` materializes the trace into ``Request``
objects for legacy callers while ``build_trace`` hands the columnar form
straight to ``simulate_events`` (lazy chunked materialization).
``sim_kwargs`` carries a suggested ``max_time`` and, where relevant,
a ``failures`` :class:`~repro.sim.simulator.FailurePlan` /
``degradations`` :class:`~repro.sim.simulator.DegradationPlan` /
``outages`` :class:`~repro.sim.simulator.OutagePlan` /
``flash_crowds`` :class:`~repro.sim.simulator.FlashCrowdPlan` to pass to
``simulate_events``, a ``models`` tuple for configuring a multi-model
controller (``ChironController(models=...)``), and — for the fleet
scenarios — a zero-arg ``fleet`` factory building the
:class:`~repro.sim.fleet.Fleet` that ``simulate_fleet`` drives (the trace
itself stays single-cluster-compatible: origins are simply ignored by the
single-cluster engines).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import BATCH_ITL_SLO, Request
from repro.sim.workload import (MAX_TOKENS, Trace, _token_lengths,
                                make_trace)

SimKwargs = Dict[str, object]
Builder = Callable[..., Tuple[Trace, SimKwargs]]


@dataclass
class Scenario:
    name: str
    description: str
    build: Builder
    default_n: int = 3000


SCENARIOS: Dict[str, Scenario] = {}


def register(name: str, description: str, default_n: int = 3000):
    def deco(fn: Builder) -> Builder:
        SCENARIOS[name] = Scenario(name, description, fn, default_n)
        return fn
    return deco


def build_trace(name: str, n_requests: int = 0, seed: int = 0,
                **overrides) -> Tuple[Trace, SimKwargs]:
    """Columnar form — feed the Trace straight to ``simulate_events``."""
    sc = SCENARIOS[name]
    return sc.build(n_requests or sc.default_n, seed, **overrides)


def build(name: str, n_requests: int = 0, seed: int = 0,
          **overrides) -> Tuple[List[Request], SimKwargs]:
    """Legacy form: materialized ``Request`` objects."""
    trace, kw = build_trace(name, n_requests, seed, **overrides)
    return trace.materialize(), kw


def _nonhomogeneous_arrivals(rng: np.random.Generator, n: int,
                             rate_fn: Callable[[np.ndarray], np.ndarray],
                             rate_max: float, horizon: float) -> np.ndarray:
    """Thinning sampler for a non-homogeneous Poisson process; returns the
    first ``n`` accepted arrival times (wraps the horizon if needed).
    Candidates are drawn and thinned in vectorized batches."""
    chunks: List[np.ndarray] = []
    got = 0
    t = 0.0
    while got < n:
        # draw candidate gaps in bulk at the envelope rate
        gaps = rng.exponential(1.0 / rate_max, size=max(n, 1024))
        ts = t + np.cumsum(gaps)
        keep = ts[rng.random(ts.size) < rate_fn(ts % horizon) / rate_max]
        chunks.append(keep)
        got += keep.size
        t = float(ts[-1])
    return np.concatenate(chunks)[:n]


# --------------------------------------------------------------- scenarios
@register("diurnal",
          "sinusoidal day/night arrival rate; capacity must track the wave",
          default_n=4000)
def diurnal(n_requests: int, seed: int = 0, *, period: float = 1800.0,
            base_rate: float = 6.0, amplitude: float = 0.85,
            interactive_frac: float = 0.85,
            batch_ttft_slo: float = 900.0) -> Tuple[Trace, SimKwargs]:
    rng = np.random.default_rng(seed)
    rate_max = base_rate * (1 + amplitude)

    def rate(ts: np.ndarray) -> np.ndarray:
        return base_rate * (1 + amplitude * np.sin(2 * np.pi * ts / period))

    times = _nonhomogeneous_arrivals(rng, n_requests, rate, rate_max, period)
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    trace = make_trace(times, ins, outs, cls, batch_ttft_slo=batch_ttft_slo)
    return trace, {"max_time": trace.duration + 600.0}


@register("burst_spikes",
          "quiet Poisson base + short high-rate spikes separated by idle "
          "gaps; stresses Theta over-provisioning and idle-skip",
          default_n=4000)
def burst_spikes(n_requests: int, seed: int = 0, *, n_bursts: int = 8,
                 burst_rate: float = 120.0, base_rate: float = 0.5,
                 gap: float = 300.0,
                 interactive_frac: float = 1.0) -> Tuple[Trace, SimKwargs]:
    rng = np.random.default_rng(seed)
    n_bursts = max(min(n_bursts, n_requests), 1)   # tiny-n guard
    per_burst = max(n_requests // n_bursts, 1)
    # each burst is a Poisson run; bursts are separated by ``gap`` of
    # silence — cumulative sum over per-burst gap offsets, all vectorized
    gaps = rng.exponential(1.0 / burst_rate, (n_bursts, per_burst))
    within = np.cumsum(gaps, axis=1)
    starts = 30.0 + np.concatenate(
        ([0.0], np.cumsum(within[:-1, -1] + gap)))
    times = (starts[:, None] + within).ravel()
    t_end = float(times[-1])
    # sparse background traffic between bursts
    n_bg = n_requests - per_burst * n_bursts
    if n_bg > 0:
        times = np.concatenate([times, rng.uniform(0.0, t_end + gap, n_bg)])
    n = times.size
    ins, outs = _token_lengths(rng, n)
    cls = rng.random(n) < interactive_frac
    trace = make_trace(times, ins, outs, cls)
    return trace, {"max_time": trace.duration + gap + 300.0}


@register("multi_tenant_slo",
          "four tenants with distinct (TTFT, ITL) SLO classes sharing the "
          "cluster: premium/standard interactive + urgent/overnight batch",
          default_n=4000)
def multi_tenant_slo(n_requests: int, seed: int = 0, *,
                     arrival_rate: float = 12.0) -> Tuple[Trace, SimKwargs]:
    rng = np.random.default_rng(seed)
    # (weight, interactive?, ttft_slo, itl_slo)
    tenants = np.array([
        (0.35, 1, 5.0, 0.1),       # premium chat
        (0.35, 1, 15.0, 0.3),      # standard chat
        (0.15, 0, 600.0, BATCH_ITL_SLO),    # urgent batch
        (0.15, 0, 3600.0, BATCH_ITL_SLO),   # overnight batch
    ])
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    choice = rng.choice(len(tenants), size=n_requests,
                        p=tenants[:, 0] / tenants[:, 0].sum())
    trace = make_trace(times, ins, outs,
                       tenants[choice, 1].astype(bool),
                       ttft_slo=tenants[choice, 2],
                       itl_slo=tenants[choice, 3])
    return trace, {"max_time": trace.duration + 900.0}


@register("heavy_tail",
          "Pareto-tailed output lengths (a few requests generate for "
          "minutes); stresses completion estimates and KV growth",
          default_n=2500)
def heavy_tail(n_requests: int, seed: int = 0, *, arrival_rate: float = 8.0,
               pareto_shape: float = 1.2,
               interactive_frac: float = 0.8) -> Tuple[Trace, SimKwargs]:
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, _ = _token_lengths(rng, n_requests)
    outs = np.clip((rng.pareto(pareto_shape, n_requests) + 1) * 48,
                   4, 4 * MAX_TOKENS).astype(np.int64)
    cls = rng.random(n_requests) < interactive_frac
    trace = make_trace(times, ins, outs, cls, batch_ttft_slo=1800.0)
    return trace, {"max_time": trace.duration + 1800.0}


@register("backlog_drain",
          "large batch queue dumped at t=0 under a live interactive "
          "stream (Fig. 19 regime): deadline-driven bulk scaling",
          default_n=4000)
def backlog_drain(n_requests: int, seed: int = 0, *,
                  backlog_frac: float = 0.8, arrival_rate: float = 10.0,
                  batch_ttft_slo: float = 1200.0) -> Tuple[Trace, SimKwargs]:
    rng = np.random.default_rng(seed)
    n_backlog = int(n_requests * backlog_frac)
    n_live = n_requests - n_backlog
    ins_b, outs_b = _token_lengths(rng, n_backlog)
    backlog = make_trace(np.zeros(n_backlog), ins_b, outs_b,
                         np.zeros(n_backlog, dtype=bool),
                         batch_ttft_slo=batch_ttft_slo, sort=False)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_live))
    ins_l, outs_l = _token_lengths(rng, n_live)
    live = make_trace(times, ins_l, outs_l, np.ones(n_live, dtype=bool),
                      sort=False)
    trace = Trace.concat([backlog, live]).sorted_by_arrival()
    return trace, {"max_time": batch_ttft_slo + 1200.0}


@register("multi_model_fleet",
          "two-model fleet (8B chat + 70B premium) sharing one chip "
          "budget: per-model IBP/Algorithm-2 loops and model-keyed routing",
          default_n=4000)
def multi_model_fleet(n_requests: int, seed: int = 0, *,
                      models: Sequence[str] = ("llama-8b", "llama-70b"),
                      model_weights: Sequence[float] = (0.7, 0.3),
                      arrival_rate: float = 10.0,
                      interactive_frac: float = 0.85,
                      batch_ttft_slo: float = 900.0) -> Tuple[Trace, SimKwargs]:
    rng = np.random.default_rng(seed)
    w = np.asarray(model_weights, dtype=np.float64)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    midx = rng.choice(len(models), size=n_requests,
                      p=w / w.sum()).astype(np.int32)
    trace = make_trace(times, ins, outs, cls,
                       batch_ttft_slo=batch_ttft_slo,
                       model_idx=midx, models=tuple(models))
    return trace, {"max_time": trace.duration + 900.0,
                   "models": tuple(models)}


@register("trace_replay",
          "replay a CSV/JSONL trace (Azure LLM inference style) — or a "
          "synthetic stand-in with its conversation/code mix when no "
          "path is given; the 1M-request scale scenario",
          default_n=20000)
def trace_replay(n_requests: int, seed: int = 0, *,
                 path: Optional[str] = None,
                 stream: bool = False,
                 chunk_requests: int = 65536,
                 max_time: Optional[float] = None,
                 arrival_rate: float = 60.0,
                 code_frac: float = 0.35,
                 interactive_frac: float = 1.0,
                 slack: float = 600.0) -> Tuple[Trace, SimKwargs]:
    if path is not None:
        from repro.sim.trace_io import load_trace, stream_trace
        if stream:
            # windowed replay: the file (gzip ok) is parsed in chunks as
            # the simulation consumes it — the multi-day-trace mode. The
            # horizon is unknowable without reading the whole file, so
            # pass ``max_time`` yourself to cap a run (default: run to
            # completion).
            src = stream_trace(path, chunk_requests=chunk_requests,
                               max_requests=n_requests)
            return src, {"max_time": float("inf") if max_time is None
                         else max_time}
        trace = load_trace(path, max_requests=n_requests)
        # deliberately no "models" kwarg: a production trace may carry
        # hundreds of transient deployments, and pre-configuring them all
        # would pin a permanent per-model instance floor — the controller's
        # on-demand discovery path provisions only models with live work
        # (pass models=... to the controller yourself for a small fleet)
        return trace, {"max_time": trace.duration + slack}
    # Azure-LLM-inference-style stand-in: a conversation class (short
    # prompts, chatty outputs) and a code class (long prompts, short
    # completions) under a mildly diurnal rate — the public trace's shape
    rng = np.random.default_rng(seed)
    period = max(n_requests / arrival_rate, 600.0)

    def rate(ts: np.ndarray) -> np.ndarray:
        return arrival_rate * (1 + 0.3 * np.sin(2 * np.pi * ts / period))

    times = _nonhomogeneous_arrivals(rng, n_requests, rate,
                                     1.3 * arrival_rate, period)
    is_code = rng.random(n_requests) < code_frac
    conv_in, conv_out = _token_lengths(rng, n_requests)
    code_in = np.clip(rng.lognormal(6.3, 0.8, n_requests), 32,
                      4 * MAX_TOKENS).astype(np.int64)   # median ~545
    code_out = np.clip(rng.lognormal(4.0, 0.7, n_requests), 4,
                       MAX_TOKENS).astype(np.int64)      # median ~55
    ins = np.where(is_code, code_in, conv_in)
    outs = np.where(is_code, code_out, conv_out)
    cls = rng.random(n_requests) < interactive_frac
    trace = make_trace(times, ins, outs, cls)
    return trace, {"max_time": trace.duration + slack}


@register("slow_nodes",
          "steady interactive stream with injected slow-node degradation "
          "(ITL inflation, not removal): detection via the health EWMA, "
          "routing must steer around the victims until they recover",
          default_n=3000)
def slow_nodes(n_requests: int, seed: int = 0, *,
               arrival_rate: float = 12.0,
               interactive_frac: float = 0.9,
               n_degradations: int = 3,
               factor: float = 4.0,
               duration: float = 240.0,
               batch_ttft_slo: float = 900.0) -> Tuple[Trace, SimKwargs]:
    from repro.sim.simulator import DegradationPlan
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    trace = make_trace(times, ins, outs, cls, batch_ttft_slo=batch_ttft_slo)
    span = trace.duration
    deg_times = np.sort(span * (0.15 + 0.6 * rng.random(n_degradations)))
    return trace, {"max_time": span + 900.0,
                   "degradations": DegradationPlan(
                       deg_times.tolist(), factor=factor,
                       duration=duration, seed=seed)}


# ---------------------------------------------------------- fleet scenarios
def _origin_column(rng: np.random.Generator, n: int,
                   origins: Sequence[str],
                   weights: Sequence[float]) -> np.ndarray:
    w = np.asarray(weights, dtype=np.float64)
    return rng.choice(len(origins), size=n, p=w / w.sum()).astype(np.int32)


@register("multi_region",
          "three regional clusters (cheap economy chips in us) under the "
          "fleet plane: the placer consolidates batch onto the cheapest "
          "cluster while each region's interactive traffic serves locally",
          default_n=3000)
def multi_region(n_requests: int, seed: int = 0, *,
                 arrival_rate: float = 12.0,
                 interactive_frac: float = 0.7,
                 batch_ttft_slo: float = 900.0,
                 chips_per_cluster: int = 160) -> Tuple[Trace, SimKwargs]:
    regions = ("us", "eu", "ap")
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    oidx = _origin_column(rng, n_requests, regions, (0.4, 0.35, 0.25))
    trace = make_trace(times, ins, outs, cls, batch_ttft_slo=batch_ttft_slo,
                       origin_idx=oidx, origins=regions)

    def fleet():
        from repro.sim.fleet import ClusterSpec, Fleet, FleetTopology
        specs = [
            ClusterSpec("us-central", "us", max_chips=chips_per_cluster,
                        accelerator="v4e"),     # cheapest $/token
            ClusterSpec("eu-west", "eu", max_chips=chips_per_cluster),
            ClusterSpec("ap-south", "ap", max_chips=chips_per_cluster),
        ]
        topo = FleetTopology(regions, latency={
            ("us", "eu"): 0.06, ("us", "ap"): 0.11, ("eu", "ap"): 0.09})
        return Fleet(specs, topo, models=("llama-8b",))

    return trace, {"max_time": trace.duration + 900.0, "fleet": fleet}


@register("regional_spillover",
          "a small regional cluster hit by an origin-local spike that "
          "exceeds its chip budget: the router must spill interactive "
          "work to the neighbouring region and hand it back afterwards",
          default_n=3000)
def regional_spillover(n_requests: int, seed: int = 0, *,
                       base_rate: float = 4.0, spike_rate: float = 240.0,
                       spike_frac: float = 0.5,
                       small_chips: int = 4,
                       big_chips: int = 240) -> Tuple[Trace, SimKwargs]:
    regions = ("us", "eu")
    rng = np.random.default_rng(seed)
    n_spike = int(n_requests * spike_frac)
    n_base = n_requests - n_spike
    base_t = np.cumsum(rng.exponential(1.0 / base_rate, n_base))
    ins_b, outs_b = _token_lengths(rng, n_base)
    base = make_trace(base_t, ins_b, outs_b, np.ones(n_base, dtype=bool),
                      origin_idx=_origin_column(rng, n_base, regions,
                                                (0.7, 0.3)),
                      origins=regions, sort=False)
    # the spike lands mid-trace, entirely us-origin, far above what the
    # small us cluster can absorb
    t0 = 0.4 * float(base_t[-1])
    spike_t = t0 + np.cumsum(rng.exponential(1.0 / spike_rate, n_spike))
    ins_s, outs_s = _token_lengths(rng, n_spike)
    spike = make_trace(spike_t, ins_s, outs_s, np.ones(n_spike, dtype=bool),
                       origin_idx=np.zeros(n_spike, dtype=np.int32),
                       origins=regions, sort=False)
    trace = Trace.concat([base, spike]).sorted_by_arrival()

    def fleet():
        from repro.sim.fleet import ClusterSpec, Fleet, FleetTopology
        specs = [ClusterSpec("us-edge", "us", max_chips=small_chips),
                 ClusterSpec("eu-hub", "eu", max_chips=big_chips)]
        topo = FleetTopology(regions, latency={("us", "eu"): 0.07})
        return Fleet(specs, topo, models=("llama-8b",))

    return trace, {"max_time": trace.duration + 900.0, "fleet": fleet}


@register("heterogeneous_accelerators",
          "one region, three chip generations (premium/baseline/economy): "
          "cost-per-token routing should pack batch onto the economy part "
          "and keep interactive latency on the fast parts",
          default_n=3000)
def heterogeneous_accelerators(n_requests: int, seed: int = 0, *,
                               arrival_rate: float = 12.0,
                               interactive_frac: float = 0.55,
                               batch_ttft_slo: float = 900.0) \
        -> Tuple[Trace, SimKwargs]:
    regions = ("us",)
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    trace = make_trace(times, ins, outs, cls, batch_ttft_slo=batch_ttft_slo,
                       origin_idx=np.zeros(n_requests, dtype=np.int32),
                       origins=regions)

    def fleet():
        from repro.sim.fleet import ClusterSpec, Fleet, FleetTopology
        specs = [
            ClusterSpec("us-premium", "us", max_chips=64,
                        accelerator="v5p"),
            ClusterSpec("us-baseline", "us", max_chips=128,
                        accelerator="v5e"),
            ClusterSpec("us-economy", "us", max_chips=192,
                        accelerator="v4e"),
        ]
        return Fleet(specs, FleetTopology(regions), models=("llama-8b",))

    return trace, {"max_time": trace.duration + 900.0, "fleet": fleet}


def _tenant_column(rng: np.random.Generator, n: int,
                   tenants: Sequence[str],
                   weights: Sequence[float]) -> np.ndarray:
    w = np.asarray(weights, dtype=np.float64)
    return rng.choice(len(tenants), size=n, p=w / w.sum()).astype(np.int32)


@register("zone_outage",
          "two-region fleet where every instance in one zone crashes at "
          "once mid-trace and its chip budget returns in staged tranches: "
          "the hierarchy must re-provision into the surviving zone and "
          "then back as capacity is restored",
          default_n=3000)
def zone_outage(n_requests: int, seed: int = 0, *,
                arrival_rate: float = 12.0,
                interactive_frac: float = 0.9,
                chips_per_cluster: int = 96,
                victim: Optional[str] = "us-east",
                outage_at_frac: float = 0.3,
                outage_duration: Optional[float] = None,
                recovery_stages: int = 2,
                stage_interval: float = 30.0,
                batch_ttft_slo: float = 900.0) -> Tuple[Trace, SimKwargs]:
    from repro.sim.simulator import OutagePlan
    regions = ("us", "eu")
    tenants = ("acme", "globex")
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    oidx = _origin_column(rng, n_requests, regions, (0.55, 0.45))
    tidx = _tenant_column(rng, n_requests, tenants, (0.6, 0.4))
    trace = make_trace(times, ins, outs, cls, batch_ttft_slo=batch_ttft_slo,
                       origin_idx=oidx, origins=regions,
                       tenant_idx=tidx, tenants=tenants)
    span = trace.duration
    # the outage scales with the trace so short smoke runs still leave
    # post-restoration traffic to measure recovery against
    if outage_duration is None:
        outage_duration = 0.2 * span
    plan = OutagePlan(start=outage_at_frac * span,
                      duration=outage_duration, cluster=victim,
                      recovery_stages=recovery_stages,
                      stage_interval=stage_interval, seed=seed)

    def fleet():
        from repro.sim.fleet import ClusterSpec, Fleet, FleetTopology
        specs = [ClusterSpec("us-east", "us", max_chips=chips_per_cluster),
                 ClusterSpec("eu-west", "eu", max_chips=chips_per_cluster)]
        topo = FleetTopology(regions, latency={("us", "eu"): 0.07})
        return Fleet(specs, topo, models=("llama-8b",))

    return trace, {"max_time": span + 900.0, "fleet": fleet,
                   "outages": plan}


@register("flash_crowd",
          "steady single-model stream plus a seeded ramp of a second "
          "model that goes zero-to-dominant in minutes: on-the-fly model "
          "discovery, placement warm-up, and recovery once the crowd "
          "passes",
          default_n=3000)
def flash_crowd(n_requests: int, seed: int = 0, *,
                arrival_rate: float = 10.0,
                interactive_frac: float = 0.9,
                crowd_model: str = "llama-70b",
                peak_rate: float = 8.0,
                ramp: Optional[float] = None,
                crowd_duration: Optional[float] = None,
                crowd_at_frac: float = 0.3,
                batch_ttft_slo: float = 900.0) -> Tuple[Trace, SimKwargs]:
    from repro.sim.simulator import FlashCrowdPlan
    tenants = ("acme", "globex")
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    tidx = _tenant_column(rng, n_requests, tenants, (0.6, 0.4))
    base = make_trace(times, ins, outs, cls, batch_ttft_slo=batch_ttft_slo,
                      models=("llama-8b",),
                      tenant_idx=tidx, tenants=tenants, sort=False)
    span = base.duration
    # crowd window scales with the trace (same reasoning as zone_outage)
    if crowd_duration is None:
        crowd_duration = 0.3 * span
    if ramp is None:
        ramp = 0.25 * crowd_duration
    plan = FlashCrowdPlan(start=crowd_at_frac * span, ramp=ramp,
                          duration=crowd_duration, model=crowd_model,
                          peak_rate=peak_rate, seed=seed)
    # the crowd itself: seeded ramp arrivals of the second model, all
    # interactive, attributed to the crowd-heavy tenant
    crowd_t = plan.arrival_times()
    n_crowd = crowd_t.size
    ins_c, outs_c = _token_lengths(rng, n_crowd)
    crowd = make_trace(crowd_t, ins_c, outs_c,
                       np.ones(n_crowd, dtype=bool),
                       models=(crowd_model,),
                       tenant_idx=np.ones(n_crowd, dtype=np.int32),
                       tenants=tenants, sort=False)
    trace = Trace.concat([base, crowd]).sorted_by_arrival()
    return trace, {"max_time": trace.duration + 900.0,
                   "models": ("llama-8b", crowd_model),
                   "flash_crowds": plan}


def _heavy_tokens(rng: np.random.Generator, n: int,
                  prompt_med: float, output_med: float):
    """Near-constant heavy requests (tight lognormal): the overload
    scenarios need sustained saturation, not a lucky light-token lull."""
    ins = np.clip(rng.lognormal(np.log(prompt_med), 0.25, n),
                  64, 4 * MAX_TOKENS).astype(np.int64)
    outs = np.clip(rng.lognormal(np.log(output_med), 0.25, n),
                   16, MAX_TOKENS).astype(np.int64)
    return ins, outs


@register("retry_storm",
          "sustained interactive overload far past a capped cluster: "
          "SLO-aware admission rejects infeasible arrivals, rejected "
          "clients re-submit with jittered exponential backoff, and the "
          "deadline sweep sheds what still cannot make its window",
          default_n=1200)
def retry_storm(n_requests: int, seed: int = 0, *,
                arrival_rate: float = 80.0,
                ttft_slo: float = 3.0,
                prompt_med: float = 1500.0,
                output_med: float = 400.0,
                max_chips: int = 4,
                slack: float = 1.0,
                max_retries: int = 3,
                base_backoff: float = 2.0,
                retry_budget: float = 45.0,
                overload_enabled: bool = True) -> Tuple[Trace, SimKwargs]:
    from repro.sim.overload import OverloadConfig
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _heavy_tokens(rng, n_requests, prompt_med, output_med)
    trace = make_trace(times, ins, outs,
                       np.ones(n_requests, dtype=bool), ttft_slo=ttft_slo)
    kw: SimKwargs = {"max_time": trace.duration + 600.0,
                     "max_chips": max_chips}
    if overload_enabled:
        kw["overload"] = OverloadConfig.full(
            slack=slack, max_retries=max_retries,
            base_backoff=base_backoff, budget=retry_budget)
    return trace, kw


@register("graceful_brownout",
          "mixed interactive+batch stream with a mid-trace overload "
          "wave: sustained-overload hysteresis engages brownout (batch "
          "deferred and preempted, hopeless interactive backlog shed), "
          "then exits cleanly once the wave passes",
          default_n=2000)
def graceful_brownout(n_requests: int, seed: int = 0, *,
                      base_rate: float = 10.0,
                      storm_rate: float = 70.0,
                      storm_frac: float = 0.4,
                      interactive_frac: float = 0.75,
                      ttft_slo: float = 4.0,
                      batch_ttft_slo: float = 1800.0,
                      prompt_med: float = 1200.0,
                      output_med: float = 350.0,
                      max_chips: int = 6,
                      slack: float = 1.0,
                      max_retries: int = 1,
                      base_backoff: float = 3.0,
                      retry_budget: float = 30.0,
                      overload_enabled: bool = True) -> Tuple[Trace, SimKwargs]:
    from repro.sim.overload import OverloadConfig
    rng = np.random.default_rng(seed)
    n_storm = int(n_requests * storm_frac)
    n_base = n_requests - n_storm
    base_t = np.cumsum(rng.exponential(1.0 / base_rate, n_base))
    ins_b, outs_b = _heavy_tokens(rng, n_base, prompt_med, output_med)
    cls = rng.random(n_base) < interactive_frac
    base = make_trace(base_t, ins_b, outs_b, cls, ttft_slo=np.where(
        cls, ttft_slo, batch_ttft_slo), sort=False)
    # the wave lands mid-trace, all interactive, far past capacity —
    # long enough that the brownout hysteresis confirms it is sustained
    t0 = 0.35 * float(base_t[-1])
    storm_t = t0 + np.cumsum(rng.exponential(1.0 / storm_rate, n_storm))
    ins_s, outs_s = _heavy_tokens(rng, n_storm, prompt_med, output_med)
    storm = make_trace(storm_t, ins_s, outs_s,
                       np.ones(n_storm, dtype=bool), ttft_slo=ttft_slo,
                       sort=False)
    trace = Trace.concat([base, storm]).sorted_by_arrival()
    kw: SimKwargs = {"max_time": trace.duration + 900.0,
                     "max_chips": max_chips}
    if overload_enabled:
        kw["overload"] = OverloadConfig.full(
            slack=slack, max_retries=max_retries,
            base_backoff=base_backoff, budget=retry_budget)
    return trace, kw


@register("instance_failures",
          "steady interactive stream with injected instance crashes: the "
          "hierarchy must re-provision and re-queue displaced work",
          default_n=3000)
def instance_failures(n_requests: int, seed: int = 0, *,
                      arrival_rate: float = 12.0,
                      interactive_frac: float = 0.9,
                      n_failures: int = 4,
                      batch_ttft_slo: float = 900.0) -> Tuple[Trace, SimKwargs]:
    from repro.sim.simulator import FailurePlan
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    ins, outs = _token_lengths(rng, n_requests)
    cls = rng.random(n_requests) < interactive_frac
    trace = make_trace(times, ins, outs, cls, batch_ttft_slo=batch_ttft_slo)
    # crashes spread over the middle of the trace (jittered, seeded): the
    # fleet is warm when they land and has traffic left to recover for
    span = trace.duration
    crash_times = np.sort(span * (0.2 + 0.6 * rng.random(n_failures)))
    return trace, {"max_time": span + 900.0,
                   "failures": FailurePlan(crash_times.tolist(), seed=seed)}
