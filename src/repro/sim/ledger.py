"""Columnar request ledger: struct-of-arrays outcome store for the event core.

The workload plane has been columnar since the ``Trace`` refactor, but the
simulation hot path still recorded every outcome by mutating ``Request``
objects, and every metric was a Python loop over those objects — at
million-request scale the *reduction* pass cost seconds on top of the
simulation itself. The :class:`RequestLedger` closes that gap: one
preallocated column per per-request outcome (first-token time, finish
time, tokens generated, lifecycle state, lifetime-mean ITL) plus views of
the immutable workload columns (arrival, token lengths, class, SLOs,
model/origin vocabulary indices).

The event core writes the ledger by integer **row id** (``Request.row``)
at the exact sites it writes the corresponding ``Request`` attribute, so
the object view and the columnar view never disagree; ``Request`` stays
the admission-boundary currency for queues and controllers. Everything
*aggregate* — SLO attainment, per-model/per-class rollups, completion
rate, token totals, TTFT percentiles — becomes a vectorized reduction
over the ledger (see :class:`repro.sim.metrics.RunResult`), which is what
keeps a 1M-request replay's summary at array speed.

Rows are assigned in arrival order (the sorted trace's row order). Stream
replays grow the ledger chunk by chunk (amortized doubling), so the row
space always covers every request the simulator has seen.

Lifecycle state is encoded as int8 (``STATE_CODES`` maps from
:class:`~repro.serving.request.RequestState`): QUEUED=0, RUNNING=1,
PREEMPTED=2, FINISHED=3, plus the overload-plane terminal states
REJECTED=4, SHED=5, EXPIRED=6. Unwritten float cells are NaN (never
observed).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request, RequestState, RequestType

# int8 lifecycle codes (stable: the ledger round-trips through files).
# 4..6 are the overload-plane terminal states (append-only).
QUEUED, RUNNING, PREEMPTED, FINISHED = 0, 1, 2, 3
REJECTED, SHED, EXPIRED = 4, 5, 6
STATE_CODES: Dict[RequestState, int] = {
    RequestState.QUEUED: QUEUED,
    RequestState.RUNNING: RUNNING,
    RequestState.PREEMPTED: PREEMPTED,
    RequestState.FINISHED: FINISHED,
    RequestState.REJECTED: REJECTED,
    RequestState.SHED: SHED,
    RequestState.EXPIRED: EXPIRED,
}
# Terminal codes: a row in one of these states is done (accounting
# identity: the terminal counts sum to n over a completed run)
TERMINAL_CODES = (FINISHED, REJECTED, SHED, EXPIRED)

# Mirror registry: ``Request`` attribute -> ledger outcome column written
# at the same mutation site (``led.<col>[req.row] = ...``). The static
# mirror auditor (``repro.analysis``, rule MIR101) walks assignments
# against this mapping, and the runtime shadow verifier rebuilds the
# columns from the objects and asserts exact agreement — extend it when
# adding a mirrored outcome field.
LEDGER_MIRRORS: Dict[str, str] = {
    "state": "state",
    "first_token_time": "first_token_time",
    "finish_time": "finish_time",
    "tokens_generated": "tokens_generated",
    "retries": "retries",
}
# Derived mirror (documented for the shadow verifier, not auto-audited:
# the object side is a list *append*, not an assignment): the event core
# records the lifetime-mean ITL of ``Request.itl_samples`` in
# ``mean_itl`` at finish time.
LEDGER_DERIVED_MIRRORS: Dict[str, str] = {"itl_samples": "mean_itl"}


class RequestLedger:
    """Struct-of-arrays per-request outcome store (see module docstring).

    Workload columns (``arrival``, ``prompt_len``, ``output_len``,
    ``interactive``, ``ttft_slo``, ``itl_slo``, ``model_idx``,
    ``origin_idx``, ``tenant_idx``) are immutable inputs; outcome columns
    (``first_token_time``, ``finish_time``, ``tokens_generated``,
    ``state``, ``mean_itl``) are written by the event core via row id.
    """

    __slots__ = ("n", "arrival", "prompt_len", "output_len", "interactive",
                 "ttft_slo", "itl_slo", "model_idx", "origin_idx",
                 "tenant_idx", "models", "origins", "tenants",
                 "first_token_time", "finish_time",
                 "tokens_generated", "state", "mean_itl", "retries",
                 "_backing", "_cap")

    def __init__(self, n: int, *, models: Tuple[str, ...] = (),
                 origins: Tuple[str, ...] = (),
                 tenants: Tuple[str, ...] = ()):
        self.n = n
        self._backing: Dict[str, np.ndarray] = {}
        self._cap = 0
        self.models = tuple(models)
        self.origins = tuple(origins)
        self.tenants = tuple(tenants)
        self.arrival = np.zeros(n, dtype=np.float64)
        self.prompt_len = np.zeros(n, dtype=np.int64)
        self.output_len = np.zeros(n, dtype=np.int64)
        self.interactive = np.zeros(n, dtype=bool)
        self.ttft_slo = np.zeros(n, dtype=np.float64)
        self.itl_slo = np.zeros(n, dtype=np.float64)
        self.model_idx = np.zeros(n, dtype=np.int32)
        self.origin_idx = np.zeros(n, dtype=np.int32)
        self.tenant_idx = np.zeros(n, dtype=np.int32)
        self.first_token_time = np.full(n, np.nan)
        self.finish_time = np.full(n, np.nan)
        self.tokens_generated = np.zeros(n, dtype=np.int64)
        self.state = np.zeros(n, dtype=np.int8)
        self.mean_itl = np.full(n, np.nan)
        self.retries = np.zeros(n, dtype=np.int32)

    # ------------------------------------------------------- construction
    @classmethod
    def from_trace(cls, trace) -> "RequestLedger":
        """Ledger over an arrival-sorted :class:`~repro.sim.workload.Trace`
        — row i is trace row i. The workload columns are shared views
        (the trace is immutable by convention), outcome columns fresh."""
        led = cls(trace.n, models=trace.models, origins=trace.origins,
                  tenants=getattr(trace, "tenants", ()))
        led.arrival = trace.arrival
        led.prompt_len = trace.prompt_len
        led.output_len = trace.output_len
        led.interactive = trace.interactive
        led.ttft_slo = trace.ttft_slo
        led.itl_slo = trace.itl_slo
        led.model_idx = trace.model_idx
        led.origin_idx = trace.origin_idx
        tidx = getattr(trace, "tenant_idx", None)
        if tidx is not None:
            led.tenant_idx = tidx
        att = getattr(trace, "attempt", None)
        if att is not None:
            # pre-consumed client retry attempts (replayed overload trace)
            led.retries[:] = att
        return led

    @classmethod
    def from_requests(cls, reqs: Sequence[Request],
                      assign_rows: bool = True) -> "RequestLedger":
        """Columnarize a request list (row i = list position i); stamps
        ``req.row`` so the event core can write outcomes by id. Existing
        lifecycle state is carried over (a re-ledgered half-run request
        keeps its history)."""
        models: List[str] = []
        mseen: Dict[str, int] = {}
        origins: List[str] = []
        oseen: Dict[str, int] = {}
        tenants: List[str] = []
        tseen: Dict[str, int] = {}
        led = cls(len(reqs))
        for i, r in enumerate(reqs):
            if assign_rows:
                r.row = i
            mi = mseen.get(r.model)
            if mi is None:
                mi = mseen[r.model] = len(models)
                models.append(r.model)
            led.model_idx[i] = mi
            if r.origin is not None:
                oi = oseen.get(r.origin)
                if oi is None:
                    oi = oseen[r.origin] = len(origins)
                    origins.append(r.origin)
                led.origin_idx[i] = oi
            if r.tenant is not None:
                ti = tseen.get(r.tenant)
                if ti is None:
                    ti = tseen[r.tenant] = len(tenants)
                    tenants.append(r.tenant)
                led.tenant_idx[i] = ti
            led.arrival[i] = r.arrival_time
            led.prompt_len[i] = r.prompt_len
            led.output_len[i] = r.output_len
            led.interactive[i] = r.is_interactive
            led.ttft_slo[i] = r.slo.ttft
            led.itl_slo[i] = r.slo.itl
            led.state[i] = STATE_CODES[r.state]
            led.tokens_generated[i] = r.tokens_generated
            led.retries[i] = r.retries
            if r.first_token_time is not None:
                led.first_token_time[i] = r.first_token_time
            if r.finish_time is not None:
                led.finish_time[i] = r.finish_time
            if r.itl_samples:
                led.mean_itl[i] = sum(r.itl_samples) / len(r.itl_samples)
        led.models = tuple(models)
        led.origins = tuple(origins)
        led.tenants = tuple(tenants)
        return led

    # column -> (dtype, fill value for unwritten outcome cells)
    _COLUMNS = (
        ("arrival", np.float64, 0.0), ("prompt_len", np.int64, 0),
        ("output_len", np.int64, 0), ("interactive", bool, False),
        ("ttft_slo", np.float64, 0.0), ("itl_slo", np.float64, 0.0),
        ("model_idx", np.int32, 0), ("origin_idx", np.int32, 0),
        ("tenant_idx", np.int32, 0),
        ("first_token_time", np.float64, np.nan),
        ("finish_time", np.float64, np.nan),
        ("tokens_generated", np.int64, 0), ("state", np.int8, 0),
        ("mean_itl", np.float64, np.nan), ("retries", np.int32, 0),
    )

    def _reserve(self, extra: int) -> None:
        """Amortized-doubling growth for the stream path: backing arrays
        at least double on overflow and the public columns become
        exact-length views, so N rows over C chunks cost O(N) total
        copying instead of O(C*N)."""
        need = self.n + extra
        cap = self._cap if self._cap > 0 else 0
        if cap == 0:
            # first growth (or a ledger built without backing arrays):
            # current columns become the live prefix of fresh backing
            cap = max(need, 1024)
            for name, dtype, fill in self._COLUMNS:
                back = np.full(cap, fill, dtype=dtype)
                back[:self.n] = getattr(self, name)
                self._backing[name] = back
        elif need > cap:
            while cap < need:
                cap *= 2
            for name, dtype, fill in self._COLUMNS:
                back = np.full(cap, fill, dtype=dtype)
                back[:self.n] = self._backing[name]
                self._backing[name] = back
        else:
            return
        self._cap = cap

    def _expose(self) -> None:
        """Point the public columns at the live prefix of the backing."""
        n = self.n
        for name, _, _ in self._COLUMNS:
            setattr(self, name, self._backing[name][:n])

    def extend_from_trace(self, trace) -> int:
        """Stream mode: append a chunk's workload columns; returns the
        first row id of the appended block. The chunk's model/origin
        vocabularies are merged into the ledger's. Growth is amortized
        doubling (public columns are views of backing arrays)."""
        base = self.n
        mremap = self._merge_vocab("models", trace.models)
        oremap = self._merge_vocab("origins", trace.origins)
        tremap = self._merge_vocab("tenants",
                                   getattr(trace, "tenants", ()))
        self._reserve(trace.n)
        b = self._backing
        hi = base + trace.n
        b["arrival"][base:hi] = trace.arrival
        b["prompt_len"][base:hi] = trace.prompt_len
        b["output_len"][base:hi] = trace.output_len
        b["interactive"][base:hi] = trace.interactive
        b["ttft_slo"][base:hi] = trace.ttft_slo
        b["itl_slo"][base:hi] = trace.itl_slo
        b["model_idx"][base:hi] = mremap[trace.model_idx]
        b["origin_idx"][base:hi] = oremap[trace.origin_idx] \
            if len(oremap) else trace.origin_idx
        tidx = getattr(trace, "tenant_idx", None)
        if tidx is None:
            tidx = np.zeros(trace.n, dtype=np.int32)
        b["tenant_idx"][base:hi] = tremap[tidx] if len(tremap) else tidx
        att = getattr(trace, "attempt", None)
        if att is not None:
            b["retries"][base:hi] = att
        # outcome cells keep their fill values (nan / 0)
        self.n = hi
        self._expose()
        return base

    def _merge_vocab(self, attr: str, vocab: Tuple[str, ...]) -> np.ndarray:
        mine = list(getattr(self, attr))
        remap = np.empty(max(len(vocab), 1), dtype=np.int32)
        for i, name in enumerate(vocab):
            if name not in mine:
                mine.append(name)
            remap[i] = mine.index(name)
        setattr(self, attr, tuple(mine))
        return remap[:len(vocab)]

    # ------------------------------------------------- overload lifecycle
    # Each helper moves the object state and its ledger column together in
    # one function — the MIR104 auditor requires exactly this pairing for
    # every terminal write, so the engines route all overload-plane state
    # transitions through here instead of open-coding them.
    def mark_rejected(self, req: Request) -> None:
        """Terminal REJECTED: refused at admission (infeasible TTFT)."""
        req.state = RequestState.REJECTED
        if req.row >= 0:
            self.state[req.row] = REJECTED

    def mark_shed(self, req: Request) -> None:
        """Terminal SHED: proactively dropped from the queue (brownout)."""
        req.state = RequestState.SHED
        if req.row >= 0:
            self.state[req.row] = SHED

    def mark_expired(self, req: Request) -> None:
        """Terminal EXPIRED: deadline passed while still queued."""
        req.state = RequestState.EXPIRED
        if req.row >= 0:
            self.state[req.row] = EXPIRED

    def mark_queued(self, req: Request) -> None:
        """A retry attempt re-enters the lifecycle (REJECTED/SHED ->
        QUEUED before the re-admission gate runs)."""
        req.state = RequestState.QUEUED
        if req.row >= 0:
            self.state[req.row] = QUEUED

    def bump_retry(self, req: Request) -> int:
        """Consume one client retry attempt (object + column together —
        the ``retries`` mirror is MIR101-audited like any other)."""
        req.retries = req.retries + 1
        if req.row >= 0:
            self.retries[req.row] = req.retries
        return req.retries

    # -------------------------------------------------------- reductions
    def class_mask(self, rtype: Optional[RequestType]) -> Optional[np.ndarray]:
        if rtype is None:
            return None
        if rtype == RequestType.INTERACTIVE:
            return self.interactive
        return ~self.interactive

    def finished_mask(self) -> np.ndarray:
        return self.state == FINISHED

    def state_counts(self) -> np.ndarray:
        """Requests per lifecycle code (one bincount; index with the
        module constants, e.g. ``counts[REJECTED]``)."""
        if not self.n:
            return np.zeros(EXPIRED + 1, dtype=np.int64)
        return np.bincount(self.state, minlength=EXPIRED + 1)

    def goodput_mask(self) -> np.ndarray:
        """Rows that finished *and* met their SLO — the overload plane's
        currency: shed/rejected/expired rows and SLO-blown completions
        both fall out."""
        return self.slo_met_mask()

    def goodput(self, duration: float,
                rtype: Optional[RequestType] = None) -> float:
        """SLO-met completions per second over ``duration``."""
        if not duration:
            return 0.0
        good = self.goodput_mask()
        mask = self.class_mask(rtype)
        if mask is not None:
            good = good & mask
        return float(np.count_nonzero(good)) / duration

    def ttft(self) -> np.ndarray:
        """Per-row TTFT (NaN where no first token was observed)."""
        return self.first_token_time - self.arrival

    def ttft_met_mask(self) -> np.ndarray:
        ftt = self.first_token_time
        with np.errstate(invalid="ignore"):
            return ~np.isnan(ftt) & (ftt - self.arrival <= self.ttft_slo)

    def itl_met_mask(self, tolerance: float = 1.0) -> np.ndarray:
        """Mean observed ITL within the SLO; rows with no samples count as
        met (mirrors :meth:`Request.itl_met`)."""
        mi = self.mean_itl
        with np.errstate(invalid="ignore"):
            return np.isnan(mi) | (mi <= self.itl_slo * tolerance)

    def slo_met_mask(self) -> np.ndarray:
        return self.finished_mask() & self.ttft_met_mask() \
            & self.itl_met_mask()

    def slo_attainment(self, rtype: Optional[RequestType] = None) -> float:
        mask = self.class_mask(rtype)
        met = self.slo_met_mask()
        if mask is None:
            return float(np.count_nonzero(met)) / self.n if self.n else 1.0
        tot = int(np.count_nonzero(mask))
        if not tot:
            return 1.0
        return float(np.count_nonzero(met & mask)) / tot

    def slo_by_model(self) -> Dict[str, float]:
        """Per-model SLO attainment, first-appearance order (one bincount
        pass — no per-request Python)."""
        if not self.n:
            return {}
        nm = max(len(self.models), int(self.model_idx.max()) + 1)
        tot = np.bincount(self.model_idx, minlength=nm)
        met = np.bincount(self.model_idx, weights=self.slo_met_mask(),
                          minlength=nm)
        first = np.full(nm, self.n, dtype=np.int64)
        # first appearance: reversed assignment leaves the earliest index
        first[self.model_idx[::-1]] = np.arange(self.n - 1, -1, -1)
        order = [int(i) for i in np.argsort(first, kind="stable")
                 if tot[i] > 0]
        return {self.models[i] if i < len(self.models) else str(i):
                float(met[i]) / int(tot[i]) for i in order}
