"""Overload control plane: admission, shedding, retries, brownout, breakers.

Chiron's hierarchy decides *how much* capacity to run; this module is the
survival layer for the regime where the chip budget is exhausted and the
autoscaler can no longer help. Four cooperating mechanisms, all disabled
by default (an engine run without an :class:`OverloadConfig` is
bit-identical to one predating this module):

- **Admission** (QLM-style): an interactive arrival whose estimated TTFT
  at *max budget* is already infeasible is refused at route time
  (terminal state REJECTED) instead of queueing doomed work.
- **Deadline shedding**: a vectorized sweep over the columnar interactive
  lanes drops entries whose deadline has already passed (EXPIRED). Batch
  work is *deferred, never dropped* — its lanes are left intact.
- **Client retries** (:class:`RetryPolicy`): rejected/shed requests
  re-arrive as heap events with jittered exponential backoff, so retry
  storms and their damping are actually simulated. Jitter comes from
  counter-based Knuth-hash draws keyed on (ledger row, attempt) — fully
  deterministic, no RNG state, bit-identical under telemetry/shadow.
- **Brownout** (:class:`BrownoutState`) and **circuit breakers**
  (:class:`CircuitBreaker`): sustained-overload detection with
  enter/exit hysteresis suspends batch backfill and evicts batch from
  mixed instances; fleets additionally stop routing into clusters whose
  rejection-rate EWMA tripped (open -> half-open -> closed), deflecting
  to healthy regions at the price of the network hop.

Every decision is stamped into the ``obs`` decision ledger with the term
that fired, so ``python -m repro.obs`` can show *why* goodput held.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

# Counter-based deterministic jitter (the PR-9 detector-noise idiom):
# Knuth multiplicative hash + golden-ratio decorrelation per attempt.
_KNUTH = 2654435761
_GOLDEN = 0x9E3779B9


@dataclass(frozen=True)
class RetryPolicy:
    """Deterministic client retry model for rejected/shed requests.

    Attempt ``k`` (1-based) re-arrives after
    ``base_backoff * 2**(k-1) * (1 + jitter * u)`` seconds where
    ``u in [0, 1)`` is a counter-based hash of (row, k). A retry is
    abandoned (the request goes terminal) once attempts are exhausted or
    the re-arrival would land past ``arrival + budget``.
    """
    max_retries: int = 3
    base_backoff: float = 2.0       # seconds before the first retry
    jitter: float = 0.5             # fractional jitter on each backoff
    budget: float = 120.0           # client gives up this long after arrival

    def backoff(self, row: int, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based) of ledger row ``row``."""
        base = self.base_backoff * (2.0 ** max(attempt - 1, 0))
        h = ((row + 1) * _KNUTH + attempt * _GOLDEN) & 0xFFFFFFFF
        return base * (1.0 + self.jitter * (h / 4294967296.0))


@dataclass(frozen=True)
class AdmissionConfig:
    """SLO-aware admission: reject an interactive arrival when its
    estimated queueing delay exceeds ``slack`` times its TTFT SLO."""
    slack: float = 1.0


@dataclass(frozen=True)
class SheddingConfig:
    """Deadline sweep over the interactive lanes at control ticks.
    ``grace`` extends the deadline before a queued request is expired."""
    grace: float = 0.0


@dataclass(frozen=True)
class BrownoutConfig:
    """Sustained-overload detection with hysteresis. Overloaded means:
    at least ``queue_min`` interactive requests waiting while the free
    chip budget cannot fit one more instance. ``enter_ticks`` consecutive
    overloaded control ticks enter brownout; ``exit_ticks`` healthy ticks
    exit it."""
    enter_ticks: int = 3
    exit_ticks: int = 5
    queue_min: int = 8


@dataclass(frozen=True)
class BreakerConfig:
    """Fleet circuit breaker on a cluster's admission-rejection EWMA."""
    ewma_alpha: float = 0.3         # per-outcome EWMA smoothing
    open_threshold: float = 0.5     # rejection-rate EWMA that opens
    cooldown: float = 30.0          # open -> half-open after this long
    trial_successes: int = 3        # half-open accepts needed to close
    min_samples: int = 10           # outcomes before the EWMA is trusted


@dataclass(frozen=True)
class OverloadConfig:
    """Feature switchboard for the overload plane. ``None`` sub-configs
    are off; an all-``None`` config is inert (the engines treat it the
    same as passing no config at all)."""
    admission: Optional[AdmissionConfig] = None
    shedding: Optional[SheddingConfig] = None
    retry: Optional[RetryPolicy] = None
    brownout: Optional[BrownoutConfig] = None

    @property
    def active(self) -> bool:
        return (self.admission is not None or self.shedding is not None
                or self.retry is not None or self.brownout is not None)

    @classmethod
    def full(cls, *, slack: float = 1.0, max_retries: int = 3,
             base_backoff: float = 2.0, budget: float = 120.0) -> "OverloadConfig":
        """Everything on with scenario-friendly defaults."""
        return cls(admission=AdmissionConfig(slack=slack),
                   shedding=SheddingConfig(),
                   retry=RetryPolicy(max_retries=max_retries,
                                     base_backoff=base_backoff,
                                     budget=budget),
                   brownout=BrownoutConfig())


class WaitGauge:
    """Estimated interactive queueing delay per model *at max budget*.

    Reuses the controller's per-model QLM :class:`WaitingTimeEstimator`
    (output-length moments learned from completions) with a service rate
    of ``n_instances = max_chips // chips_per_instance`` instances at the
    interactive-ITL-optimal batch — i.e. the most optimistic capacity the
    cluster could ever field. If the wait is infeasible *at that* rate,
    no autoscaling decision can save the request.
    """

    __slots__ = ("_controller", "_cluster", "_rates")

    def __init__(self, controller, cluster):
        self._controller = controller
        self._cluster = cluster
        # model -> (tokens/s per instance, instances at max budget, chips)
        self._rates: Dict[str, Tuple[float, int, int]] = {}

    @property
    def supported(self) -> bool:
        return hasattr(self._controller, "_estimator_for")

    def _rate(self, model: str) -> Tuple[float, int, int]:
        r = self._rates.get(model)
        if r is None:
            perf = self._cluster.perf_factory(model)
            b = perf.optimal_batch(self._controller.itl_slo_interactive,
                                   mean_ctx=512.0)
            thr = perf.throughput(b, mean_ctx=512.0)
            chips = max(int(perf.chips), 1)
            n_inst = max(self._cluster.max_chips // chips, 1)
            r = self._rates[model] = (thr, n_inst, chips)
        return r

    def wait(self, queue, model: str) -> float:
        """Estimated delay for a new arrival behind the current lane."""
        thr, n_inst, _ = self._rate(model)
        est = self._controller._estimator_for(model)
        return est.waiting_time(queue.n_interactive_for(model), thr,
                                n_instances=n_inst)

    def per_request_wait(self, model: str) -> float:
        """Estimated service delay contributed by one queued request."""
        thr, n_inst, _ = self._rate(model)
        est = self._controller._estimator_for(model)
        return est.waiting_time(1, thr, n_instances=n_inst)

    def min_chips(self) -> int:
        """Smallest instance footprint among the controller's models —
        the budget headroom below which the cluster cannot grow."""
        models = getattr(self._controller, "model_list", None) \
            or [getattr(self._controller, "model", "llama-8b")]
        return min(self._rate(m)[2] for m in models)


def is_overloaded(cluster, queue, gauge: WaitGauge,
                  cfg: BrownoutConfig) -> bool:
    """The brownout entry signal: interactive backlog with no budget
    headroom left to scale into."""
    if queue.n_interactive < cfg.queue_min:
        return False
    free = cluster.max_chips - cluster.used_chips()
    return free < gauge.min_chips()


class BrownoutState:
    """Hysteresis counter for brownout mode (one per cluster).
    (``engaged``, not ``active`` — the latter is an instance-plane
    mirror attribute and would trip the MIR102 auditor.)"""

    __slots__ = ("engaged", "_hot", "_cool")

    def __init__(self):
        self.engaged = False
        self._hot = 0
        self._cool = 0

    def update(self, overloaded: bool, cfg: BrownoutConfig) -> Optional[bool]:
        """Feed one control tick; returns True on enter, False on exit,
        None when the mode did not change."""
        if overloaded:
            self._hot += 1
            self._cool = 0
        else:
            self._cool += 1
            self._hot = 0
        if not self.engaged and self._hot >= cfg.enter_ticks:
            self.engaged = True
            return True
        if self.engaged and self._cool >= cfg.exit_ticks:
            self.engaged = False
            return False
        return None


# Breaker state codes (stamped into the obs decision ledger's itype slot)
BRK_CLOSED, BRK_HALF_OPEN, BRK_OPEN = 0, 1, 2


class CircuitBreaker:
    """Per-cluster breaker on the admission-rejection EWMA.

    closed --(ewma > open_threshold)--> open --(cooldown)--> half-open
    --(trial accepts)--> closed, or --(any rejection)--> open again.
    """

    __slots__ = ("cfg", "state", "ewma", "samples", "opened_at",
                 "_successes")

    def __init__(self, cfg: BreakerConfig):
        self.cfg = cfg
        self.state = BRK_CLOSED
        self.ewma = 0.0
        self.samples = 0
        self.opened_at = 0.0
        self._successes = 0

    def allows(self, now: float) -> bool:
        """May traffic be routed here? Transitions open -> half-open
        after the cooldown (check :attr:`state` for the stamp)."""
        if self.state == BRK_OPEN:
            if now - self.opened_at >= self.cfg.cooldown:
                self.state = BRK_HALF_OPEN
                self._successes = 0
                return True
            return False
        return True

    def record(self, rejected: bool, now: float) -> Optional[int]:
        """Feed one admission outcome; returns the new state code on a
        transition, None otherwise."""
        a = self.cfg.ewma_alpha
        x = 1.0 if rejected else 0.0
        self.ewma = x if self.samples == 0 else a * x + (1.0 - a) * self.ewma
        self.samples += 1
        if self.state == BRK_HALF_OPEN:
            if rejected:
                self.state = BRK_OPEN
                self.opened_at = now
                return BRK_OPEN
            self._successes += 1
            if self._successes >= self.cfg.trial_successes:
                self.state = BRK_CLOSED
                self.ewma = 0.0     # fresh slate after a confirmed close
                self.samples = 0
                return BRK_CLOSED
        elif self.state == BRK_CLOSED \
                and self.samples >= self.cfg.min_samples \
                and self.ewma > self.cfg.open_threshold:
            self.state = BRK_OPEN
            self.opened_at = now
            return BRK_OPEN
        return None
