"""Cluster controllers: Chiron (hierarchical) and the baselines.

A controller's ``control(cluster, queue, now)`` runs every control interval
and turns backpressure into provision/retire actions; ``route`` places
queued requests onto instances per the paper's preferential routing.

Multi-model fleets: ``ChironController(models=[...])`` runs one full
hierarchy per model — a per-model IBP/Theta interactive scaler and a
per-model Algorithm-2 batch scaler whose request groups are maintained off
that model's queue lane — while every provision draws from the single
shared chip budget (``SimCluster.max_chips``). Routing is model-keyed end
to end: a request is only ever offered to instances of its own model
(``SimInstance.can_admit`` enforces the invariant as a backstop). Models
seen in the arrival stream but not configured are registered on the fly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.baselines import LlumnixAutoscaler
from repro.core.global_autoscaler import BatchAutoscaler, InteractiveAutoscaler
from repro.core.local_autoscaler import LocalAutoscaler
from repro.core.waiting_time import WaitingTimeEstimator
from repro.obs.recorder import (R_BBP_ADD as _R_BBP_ADD,
                                R_BBP_IDLE as _R_BBP_IDLE,
                                R_BBP_TRIM as _R_BBP_TRIM,
                                R_IBP_HIGH as _R_IBP_HIGH,
                                R_IBP_LOW as _R_IBP_LOW)
from repro.serving.global_queue import GlobalQueue
from repro.serving.request import Request, RequestType
from repro.sim.cluster import (SLOW_SUSPECT_RATIO, InstanceType, SimCluster,
                               SimInstance)

_SCAN_INF = float("inf")


def _best_fit(insts: List[SimInstance]) -> Optional[SimInstance]:
    """Most-loaded instance that can still admit (packing). Packing — not
    least-loaded spreading — keeps interactive requests concentrated so
    IBP counts genuinely-busy instances and mixed spare capacity stays
    spare (otherwise every mixed instance 'runs interactive' and the
    interactive scaler over-provisions 3x its own additions).

    Instances whose health EWMA marks them suspected-slow are routed
    around whenever a healthy candidate exists — degradation detection
    must not strand requests, so a fully-degraded pool still serves."""
    cands = [i for i in insts if i.active]
    if not cands:
        return None
    healthy = [i for i in cands if not i.suspected_slow]
    return max(healthy or cands, key=lambda i: i.slot_utilization())


def _scan_admit(pool: List[SimInstance],
                req: Request) -> Tuple[Optional[SimInstance], float]:
    """One fused pass over a same-model pool: admission check (active,
    batch slot free, KV wall) and best-fit packing (max slot utilization,
    first max wins, suspected-slow instances only as a last resort) —
    semantically identical to ``_best_fit([i for i in pool if
    i.can_admit(req)])`` but without building candidate lists or paying a
    method call per instance. This is the per-arrival routing hot path.

    Returns ``(winner, rej_slack)`` where ``rej_slack`` is the largest
    ``wall - kv`` over instances this scan rejected *by the KV wall*
    (``-1.0`` when none were). The wall test is the only request-dependent
    admission check — a later request with ``prompt_len > rej_slack`` is
    provably rejected by every instance this scan rejected, which is what
    lets the positive-scan memo in ``route_arrival_burst`` skip the
    rescan without changing any decision."""
    best = None
    best_u = -1.0
    slow_best = None
    slow_u = -1.0
    rej = -1.0
    pl = req.prompt_len
    inf = _SCAN_INF
    for inst in pool:
        if not inst.active:
            continue
        n = len(inst.running)
        loc = inst.local
        mb = loc.max_batch_size if loc is not None \
            else (inst.static_batch or 64)
        if n >= mb:
            continue
        wall = inst._c_wall
        if wall != inf:
            if inst.event_mode:
                kv = inst._kv_prefill + inst._kv_dec_base \
                    + inst._n_dec * inst.vclock
            else:
                kv = inst._kv_tokens
            if kv + pl > wall:
                s = wall - kv
                if s > rej:
                    rej = s
                continue
        u = n / mb if mb >= 1 else float(n)
        if inst.health_ewma > SLOW_SUSPECT_RATIO:
            if u > slow_u:
                slow_u, slow_best = u, inst
        elif u > best_u:
            best_u, best = u, inst
    return (best if best is not None else slow_best), rej


class BaseController:
    """Shared routing: interactive -> interactive then mixed (preempting
    batch); batch -> batch instances then spare mixed capacity; every
    lookup stays inside the request's own model pools.

    ``route`` is the full preferential pass (every fixed tick / control
    tick); the event core additionally calls ``route_interactive`` on every
    event (zero-queuing) and ``backfill`` for just-freed instances, so the
    hot path never rescans the whole cluster per completion.
    """

    serves_batch_on_mixed = True
    # flight recorder (repro.obs) attached by the engines when telemetry
    # is armed; None costs one predicted branch per control tick
    obs = None
    # brownout mode (repro.sim.overload): while True, batch work is
    # deferred — no batch routing or backfill — so every slot serves the
    # interactive backlog. Set by the engines' overload plane; the False
    # default keeps overload-free runs bit-identical.
    brownout_active = False

    def route(self, cluster: SimCluster, queue: GlobalQueue, now: float) -> None:
        self.route_interactive(cluster, queue, now, use_memo=False)
        if not queue.n_batch or self.brownout_active:
            return
        for model in queue.batch_models():
            pools = [cluster.by_model(model, InstanceType.BATCH)]
            if self.serves_batch_on_mixed:
                pools.append(cluster.by_model(model, InstanceType.MIXED))
            for pool in pools:
                self.backfill(pool, queue, now)

    def route_interactive(self, cluster: SimCluster, queue: GlobalQueue,
                          now: float, use_memo: bool = True) -> None:
        if not queue.n_interactive:     # hot path: most events route nothing
            return
        # ---- interactive: zero-queuing, one pass per model lane
        for model in queue.interactive_models():
            self._route_interactive_model(cluster, queue, model, now,
                                          use_memo)

    def _route_interactive_model(self, cluster: SimCluster,
                                 queue: GlobalQueue, model: str,
                                 now: float, use_memo: bool = True) -> None:
        if not isinstance(cluster, SimCluster):
            # duck-typed cluster (RealCluster): the generic can_admit
            # path — no memo, no coefficient-cached scan
            req = queue.peek_interactive(model)
            while req is not None:
                inst = self._find_slot_generic(cluster, queue, model,
                                               req, now)
                if inst is None:
                    break
                inst.admit(queue.pop_interactive(model), now)
                req = queue.peek_interactive(model)
            return
        # saturation memo: when this lane's head couldn't be placed, the
        # outcome can only change once capacity moves — an instance frees a
        # slot / activates / is provisioned (all bump ``route_version``) or
        # the head itself changes (a front requeue). Until then the failed
        # scan would just repeat, so skip it. A memo is
        # ``(version, batch, head)`` and matches when the head is the same
        # request and either the version or the event batch is unchanged
        # (the batch arm covers verdicts whose own eviction pass mutated
        # state — valid for the rest of that batch, stale after it). Full
        # control-tick passes (``use_memo=False``) always rescan: local
        # autoscalers may have raised batch ceilings without touching the
        # version.
        try:
            blocked = self._route_blocked
        except AttributeError:
            blocked = self._route_blocked = {}
        req = queue.peek_interactive(model)
        if use_memo:
            memo = blocked.get(model)
            if memo is not None and memo[2] is req \
                    and (memo[0] == cluster.route_version
                         or memo[1] == cluster.batch_seq):
                return
        while req is not None:
            # version *before* the attempt: a failed eviction pass can
            # itself free capacity (its settle-advance pops finishes and
            # bumps the version), and the memo must not mask that
            v0 = cluster.route_version
            inst = self._find_slot(cluster, queue, model, req, now)
            if inst is None:
                # lane saturated; record (pre-attempt version, head) so
                # the next no-capacity-change event skips the scan
                blocked[model] = (v0, -1, req)
                break
            inst.admit(queue.pop_interactive(model), now)
            req = queue.peek_interactive(model)

    def _find_slot(self, cluster: SimCluster, queue: GlobalQueue,
                   model: str, req: Request,
                   now: float) -> Optional[SimInstance]:
        """Find (or make, by evicting batch work) a slot for one
        interactive request: interactive pool, then mixed pool, then
        batch preemption on a same-model mixed instance. The eviction
        branch mutates (victim requeued); the caller admits into the
        returned instance immediately."""
        inter, mixed = cluster.pool_pair(model)
        if inter:
            inst, _ = _scan_admit(inter, req)
            if inst is not None:
                return inst
        if mixed:
            inst, _ = _scan_admit(mixed, req)
            if inst is not None:
                return inst
            # preempt a batch request on a same-model mixed instance (the
            # O(1) batch-count guard keeps a saturated all-interactive
            # cluster from rescanning every batch)
            for inst in mixed:
                if not inst.active or len(inst.running) \
                        - inst._n_interactive == 0:
                    continue
                victim = inst.evict_one_batch(now)
                if victim is not None:
                    queue.requeue(victim)
                    return inst
        return None

    def _find_slot_generic(self, cluster, queue: GlobalQueue, model: str,
                           req: Request, now: float):
        """`_find_slot` for duck-typed clusters/instances (the real
        engine): the original `can_admit`/`_best_fit` pass."""
        for pool in (cluster.by_model(model, InstanceType.INTERACTIVE),
                     cluster.by_model(model, InstanceType.MIXED)):
            inst = _best_fit([i for i in pool if i.can_admit(req)])
            if inst is not None:
                return inst
        for inst in cluster.by_model(model, InstanceType.MIXED):
            if not inst.active or inst.n_running_batch() == 0:
                continue
            victim = inst.evict_one_batch(now)
            if victim is not None:
                queue.requeue(victim)
                return inst
        return None

    def route_arrival(self, cluster: SimCluster, queue: GlobalQueue,
                      req: Request, now: float) -> bool:
        """Zero-queuing fast path for a single just-arrived interactive
        request whose lane is empty (the event core calls this before
        enqueueing, when no other event shares the timestamp): place it
        directly — skipping the queue round-trip the full pass would
        immediately undo — or return False for a normal enqueue, leaving
        the saturation memo set exactly as a failed lane pass would."""
        if req.request_type != RequestType.INTERACTIVE:
            return False
        v0 = cluster.route_version
        inst = self._find_slot(cluster, queue, req.model, req, now)
        if inst is None:
            try:
                blocked = self._route_blocked
            except AttributeError:
                blocked = self._route_blocked = {}
            if cluster.route_version == v0:
                # clean verdict: valid until capacity moves
                blocked[req.model] = (v0, -1, req)
            else:
                # the attempt itself mutated state (eviction settle) so
                # the verdict only holds for the rest of this event batch
                # — exactly the once-per-batch attempt the full pass makes
                blocked[req.model] = (-1, cluster.batch_seq, req)
            return False
        inst.admit(req, now)
        return True

    def route_arrival_burst(self, cluster: SimCluster, queue: GlobalQueue,
                            reqs: List[Request], now: float,
                            observe=None) -> None:
        """Cohort fast path: route a whole same-timestamp arrival burst
        in one call — decision-identical to the per-request
        ``observe_arrival`` + ``route_arrival``-or-push loop, with the
        per-request overhead hoisted (one ``pool_pair`` lookup per
        model run instead of per request, the memo dict resolved once).
        Interactive requests place zero-queuing while their lanes stay
        empty; everything else (and every request after the first
        placement failure backs the lane up) enqueues normally.

        The *positive-scan memo* removes the pool scan from the
        steady-state path entirely. After an admit we remember
        ``(route_version, winner, rej_slack)`` per model. On the next
        same-model arrival, if the version is unchanged (every
        routing-relevant mutation bumps it — admits, frees, provisioning,
        activation, eviction, health flips, local ceiling moves) then the
        only instance whose scan inputs moved is the winner itself, whose
        utilization strictly *rose* — so it is still the first strict
        maximum and a fresh scan would pick it again, provided (a) it
        still passes the admission checks (revalidated here against the
        exact scan predicate) and (b) the new prompt cannot un-reject an
        instance the original scan rejected. Capacity/active rejections
        are request-independent; only the KV-wall test depends on
        ``prompt_len``, and ``prompt_len > rej_slack`` keeps every
        wall-rejected instance rejected. Any check failing falls back to
        the full scan, so decisions are bit-identical either way.

        One subtlety: an admit is only a *pure insert* when its embedded
        settle-advance popped no finishes — a settle pop drops the
        winner's utilization, so it may no longer be the maximum even
        though only its own state moved. The memo is therefore stored
        only when ``len(running)`` grew by exactly one (the admit's net
        effect was the insert); otherwise the next arrival rescans."""
        try:
            blocked = self._route_blocked
        except AttributeError:
            blocked = self._route_blocked = {}
        try:
            pick = self._route_pick
        except AttributeError:
            pick = self._route_pick = {}
        pool_pair = cluster.pool_pair
        push = queue.push
        scan = _scan_admit
        it = RequestType.INTERACTIVE
        inf = _SCAN_INF
        last_model = None
        inter = mixed = None
        for req in reqs:
            if observe is not None:
                observe(req, now)
            if req.request_type != it or queue._icount:
                push(req)
                continue
            model = req.model
            v0 = cluster.route_version
            pk = pick.get(model)
            if pk is not None and pk[0] == v0 and req.prompt_len > pk[2]:
                cand = pk[1]
                if cand.active:
                    loc = cand.local
                    if len(cand.running) < (
                            loc.max_batch_size if loc is not None
                            else (cand.static_batch or 64)):
                        wall = cand._c_wall
                        if wall != inf:
                            if cand.event_mode:
                                kv = cand._kv_prefill + cand._kv_dec_base \
                                    + cand._n_dec * cand.vclock
                            else:
                                kv = cand._kv_tokens
                            ok = kv + req.prompt_len <= wall
                        else:
                            ok = True
                        if ok:
                            n0 = len(cand.running)
                            cand.admit(req, now)
                            if len(cand.running) == n0 + 1:
                                pick[model] = (cluster.route_version,
                                               cand, pk[2])
                            continue
            # pools resolved only on memo miss — a hit never touches them
            if model != last_model:
                inter, mixed = pool_pair(model)
                last_model = model
            rej = -1.0
            inst = None
            if inter:
                inst, rej = scan(inter, req)
            if inst is None and mixed:
                inst, r2 = scan(mixed, req)
                if r2 > rej:
                    rej = r2
                if inst is None:
                    # preempt batch work on a same-model mixed instance
                    # (same order and guards as _find_slot)
                    for cand in mixed:
                        if not cand.active or len(cand.running) \
                                - cand._n_interactive == 0:
                            continue
                        victim = cand.evict_one_batch(now)
                        if victim is not None:
                            queue.requeue(victim)
                            inst = cand
                            break
            if inst is None:
                # saturated: leave the memo exactly as route_arrival would
                if cluster.route_version == v0:
                    blocked[model] = (v0, -1, req)
                else:
                    blocked[model] = (-1, cluster.batch_seq, req)
                push(req)
            else:
                n0 = len(inst.running)
                inst.admit(req, now)
                if len(inst.running) == n0 + 1:
                    pick[model] = (cluster.route_version, inst, rej)

    def backfill(self, insts, queue: GlobalQueue, now: float) -> None:
        """Fill spare capacity on ``insts`` from their models' batch lanes.
        The queue pops in service order (resume lane, then earliest
        deadline / FCFS) at O(log n) per admission — no per-pass sort."""
        if self.brownout_active:
            return                   # brownout: batch strictly deferred
        for inst in insts:
            if inst.itype == InstanceType.INTERACTIVE:
                continue             # interactive pool never serves batch
            if inst.health_ewma > SLOW_SUSPECT_RATIO:
                continue             # route around degraded nodes; the
                                     # batch scaler re-adds the capacity
            model = inst.model
            if not isinstance(inst, SimInstance):
                # duck-typed instance (real engine): generic can_admit
                while inst.active and inst.n_running < inst.max_batch_size \
                        and queue.n_batch_for(model):
                    req = queue.peek_batch(model)
                    if not inst.can_admit(req):
                        break
                    inst.admit(queue.pop_batch_fcfs(model), now)
                continue
            wall = inst._c_wall
            # cheap slot-full rejection before touching the queue
            while inst.active and queue.n_batch_for(model):
                n = len(inst.running)
                loc = inst.local
                mb = loc.max_batch_size if loc is not None \
                    else (inst.static_batch or 64)
                if n >= mb:
                    break
                if wall != float("inf"):
                    req = queue.peek_batch(model)
                    kv = inst._kv_prefill + inst._kv_dec_base \
                        + inst._n_dec * inst.vclock if inst.event_mode \
                        else inst._kv_tokens
                    if kv + req.prompt_len > wall:
                        break
                inst.admit(queue.pop_batch_fcfs(model), now)

    def brownout_preempt_batch(self, cluster: SimCluster,
                               queue: GlobalQueue, now: float) -> int:
        """Brownout's aggressive arm: evict every batch request running
        on a mixed instance back to the queue (host-saved KV lands in
        the resume lanes, so nothing is lost) so the whole mixed pool
        serves the interactive backlog. Returns the eviction count."""
        n = 0
        for inst in (cluster._active.values()
                     if isinstance(cluster, SimCluster)
                     else cluster.active_instances()):
            if inst.itype != InstanceType.MIXED:
                continue
            while inst.n_running_batch() > 0:
                victim = inst.evict_one_batch(now)
                if victim is None:
                    break
                queue.requeue(victim)
                n += 1
        return n

    def control(self, cluster: SimCluster, queue: GlobalQueue,
                now: float) -> None:
        raise NotImplementedError


@dataclass
class ChironController(BaseController):
    """The paper's hierarchical autoscaler (local + global), replicated
    per model when ``models`` lists a fleet."""
    model: str = "llama-8b"
    models: Optional[Sequence[str]] = None  # multi-model fleet; None = [model]
    theta: float = 1.0 / 3.0
    delta: float = 0.1
    itl_slo_interactive: float = 0.2
    itl_slo_batch: float = 2.0
    local_enabled: bool = True          # False -> "Global" ablation arm
    global_enabled: bool = True         # False -> "Local" ablation arm
    static_batch: int = 64              # used when local_enabled=False
    estimator: WaitingTimeEstimator = field(default_factory=WaitingTimeEstimator)
    min_instances: int = 1
    init_batch: int = 8
    max_batch: int = 4096
    group_k: int = 0                    # -1 disables request groups (Fig. 6)
    # paper §5.2: Theta is chosen from historical arrival spikes (tail
    # spike 3x -> Theta = 1/3). auto_theta re-estimates it online from the
    # observed arrival process every `theta_refresh` seconds — per model:
    # each model runs its own refresh clock, and `theta_refresh_per_model`
    # overrides the cadence for models whose arrival processes drift on a
    # different timescale than the fleet default.
    auto_theta: bool = False
    theta_refresh: float = 120.0
    theta_refresh_per_model: Optional[Dict[str, float]] = None
    # arrival history kept per model for Theta re-estimation: a rolling
    # window (recent spikes are what Theta hedges against) that also
    # bounds memory on million-request replays
    theta_history: int = 4096

    def __post_init__(self):
        self.model_list: List[str] = list(self.models) if self.models \
            else [self.model]
        if self.model not in self.model_list:
            # model= was left at its default (or named a model outside the
            # fleet): the fleet's first entry becomes the primary
            self.model = self.model_list[0]
        self._configured = set(self.model_list)
        self.interactive_scalers: Dict[str, InteractiveAutoscaler] = {}
        self._batch_scalers: Dict[str, Optional[BatchAutoscaler]] = {}
        self._arrivals: Dict[str, List[float]] = {}
        # per-model waiting-time estimators: models with divergent output
        # distributions must not pollute each other's QLM fit. The primary
        # model keeps the `estimator` field itself (legacy single-model
        # behaviour is bit-identical).
        self.estimators: Dict[str, WaitingTimeEstimator] = {
            self.model: self.estimator}
        self._out_models: Dict[str, object] = {}
        self._next_theta_update: Dict[str, float] = {}
        for m in self.model_list:
            self._register_model(m)

    # ------------------------------------------------------------ helpers
    @property
    def interactive_scaler(self) -> InteractiveAutoscaler:
        """Legacy single-model accessor (the primary model)."""
        return self.interactive_scalers[self.model]

    def _register_model(self, model: str) -> None:
        # discovered (unconfigured) models get no instance floor: once
        # their traffic drains, their fleet may drop to zero instances
        floor = self.min_instances if model in self._configured else 0
        self.interactive_scalers[model] = InteractiveAutoscaler(
            self.theta, self.delta, floor)
        self._batch_scalers[model] = None
        self._arrivals[model] = []
        self._next_theta_update[model] = self._theta_cadence(model)

    def _theta_cadence(self, model: str) -> float:
        if self.theta_refresh_per_model \
                and model in self.theta_refresh_per_model:
            return self.theta_refresh_per_model[model]
        return self.theta_refresh

    def _estimator_for(self, model: str) -> WaitingTimeEstimator:
        est = self.estimators.get(model)
        if est is None:
            est = self.estimators[model] = WaitingTimeEstimator(
                quantile_z=self.estimator.quantile_z)
        return est

    def _ensure_model(self, model: str) -> None:
        if model not in self.interactive_scalers:
            self.model_list.append(model)
            self._register_model(model)

    def set_model_placed(self, model: str, placed: bool) -> None:
        """Placement pin from a fleet-level placer: a placed model keeps
        the configured instance floor (a warm foothold); unplacing drops
        the floor to zero so the model's local fleet drains away."""
        self._ensure_model(model)
        if placed:
            self._configured.add(model)
        else:
            self._configured.discard(model)
        self.interactive_scalers[model].min_instances = \
            self.min_instances if placed else 0

    def _mk_local(self, slo: float) -> Optional[LocalAutoscaler]:
        if not self.local_enabled:
            return None
        return LocalAutoscaler(itl_slo=slo, init_batch=self.init_batch,
                               max_batch=self.max_batch)

    def _provision(self, cluster: SimCluster, itype: InstanceType,
                   now: float, model: Optional[str] = None) -> Optional[SimInstance]:
        slo = self.itl_slo_batch if itype == InstanceType.BATCH \
            else self.itl_slo_interactive
        return cluster.provision(
            model or self.model, itype, now,
            local_autoscaler=self._mk_local(slo),
            static_batch=None if self.local_enabled else self.static_batch)

    def batch_instance_throughput(self, cluster: SimCluster,
                                  model: Optional[str] = None) -> float:
        perf = cluster.perf_factory(model or self.model)
        b = perf.optimal_batch(self.itl_slo_batch, mean_ctx=512.0)
        return perf.throughput(b, mean_ctx=512.0)

    # ------------------------------------------------------------ control
    def observe_arrival(self, req: Request, now: float) -> None:
        m = req.model
        if m not in self.interactive_scalers:   # inline _ensure_model
            self.model_list.append(m)
            self._register_model(m)
        if self.auto_theta \
                and req.request_type == RequestType.INTERACTIVE:
            self._arrivals[m].append(now)

    def _refresh_theta(self, now: float) -> None:
        """Per-model Theta re-estimation: every model runs its own refresh
        clock (its own cadence), so a model whose arrival process shifts
        quickly is not held hostage by the fleet-wide schedule."""
        if not self.auto_theta:
            return
        from repro.sim.workload import arrival_spikes
        for model, arrivals in self._arrivals.items():
            if now < self._next_theta_update[model]:
                continue
            self._next_theta_update[model] = now + self._theta_cadence(model)
            if len(arrivals) > self.theta_history:   # rolling window
                del arrivals[:-self.theta_history]
            if len(arrivals) < 20:
                continue
            spikes = arrival_spikes(np.asarray(arrivals), 30.0)
            if spikes.size:
                tail = float(np.percentile(spikes, 99.0))
                self.interactive_scalers[model].theta = 1.0 / max(tail, 1.0)

    def control(self, cluster: SimCluster, queue: GlobalQueue,
                now: float) -> None:
        # 0. bootstrap + optional Theta re-estimation from arrival history.
        # Configured models always keep a foothold; models discovered from
        # the arrival stream are provisioned on demand only — a replayed
        # trace with many transient deployments must not pin a chip per
        # deployment forever.
        self._refresh_theta(now)
        sim = isinstance(cluster, SimCluster)
        for m in self.model_list:
            if cluster.n_instances_of(m) if sim else cluster.instances_of(m):
                continue
            if m in self._configured or queue.n_interactive_for(m) \
                    or queue.n_batch_for(m):
                self._provision(cluster, InstanceType.MIXED, now, m)

        # 1. local autoscaling + health tracking on every instance (the
        # health EWMA is the slow-node detection signal routing reads;
        # updates are per-instance independent, so the active registry's
        # order is as good as the instance list's and costs no scan)
        local_enabled = self.local_enabled
        for inst in (cluster._active.values() if sim
                     else cluster.active_instances()):
            inst.update_health()
            if local_enabled:
                inst.update_local_autoscaler()

        # 2./3. one global loop per model, all sharing the chip budget.
        # Drained models (no instances, no queued work — only possible for
        # discovered ones after the bootstrap above) cost two O(1) checks,
        # so per-tick work tracks the active fleet, not every model ever
        # seen in a long replay.
        if self.global_enabled:
            for m in self.model_list:
                if not (cluster.n_instances_of(m) if sim
                        else cluster.instances_of(m)) \
                        and not queue.n_interactive_for(m) \
                        and not queue.n_batch_for(m):
                    continue
                self._control_model(cluster, queue, m, now)

    def _control_model(self, cluster: SimCluster, queue: GlobalQueue,
                       model: str, now: float) -> None:
        # 2. interactive/mixed scaling on this model's IBP
        inter = cluster.by_model(model, InstanceType.INTERACTIVE)
        mixed = cluster.by_model(model, InstanceType.MIXED)
        n_running = sum(1 for i in inter + mixed if i.runs_interactive())
        iscaler = self.interactive_scalers[model]
        dec = iscaler.update(n_running, len(inter), len(mixed))
        obs = self.obs
        if dec.delta_instances > 0:
            if obs is not None:     # Algorithm 1: IBP above the band
                obs.set_context(_R_IBP_HIGH, dec.ibp,
                                iscaler.theta + iscaler.delta)
            for _ in range(dec.delta_instances):
                if self._provision(cluster, InstanceType.MIXED, now,
                                   model) is None:
                    break               # shared chip budget exhausted
        elif dec.delta_instances < 0:
            if obs is not None:     # Algorithm 1: IBP below the band
                obs.set_context(_R_IBP_LOW, dec.ibp,
                                iscaler.theta - iscaler.delta)
            floor = self.min_instances if model in self._configured else 0
            idle_mixed = [i for i in mixed
                          if i.active and not i.runs_interactive()]
            idle_mixed.sort(key=lambda i: i.n_running)
            for inst in idle_mixed[:-dec.delta_instances]:
                if len(cluster.by_model(model, InstanceType.MIXED)) + \
                        len(cluster.by_model(model,
                                             InstanceType.INTERACTIVE)) \
                        <= floor:
                    break
                for r in cluster.retire(inst):
                    queue.requeue(r)

        # 3. batch scaling on this model's BBP (Algorithm 2)
        scaler = self._batch_scalers[model]
        if scaler is None:
            scaler = self._batch_scalers[model] = BatchAutoscaler(
                self._estimator_for(model),
                self.batch_instance_throughput(cluster, model),
                group_k=self.group_k, model=model)
        spare = sum(i.spare_throughput()
                    for i in cluster.by_model(model, InstanceType.MIXED)
                    if i.active)
        n_batch_inst = len(cluster.by_model(model, InstanceType.BATCH))
        n_active_batch = 0
        for itype in InstanceType:
            for i in cluster.by_model(model, itype):
                n_active_batch += i.n_running_batch()
        # pass the queue itself: request groups are maintained
        # incrementally off its per-model add/remove stream
        dec2 = scaler.update(
            queue, now,
            n_batch_instances=n_batch_inst,
            spare_mixed_throughput=spare,
            n_active_batch_requests=n_active_batch)
        if dec2.retire_all:
            if obs is not None:     # Algorithm 2: no batch work left
                obs.set_context(_R_BBP_IDLE, float(dec2.bbp_before), 0.0)
            for inst in list(cluster.by_model(model, InstanceType.BATCH)):
                for r in cluster.retire(inst):
                    queue.requeue(r)
        elif dec2.remove_instances > 0:
            # Algorithm 2 minimality: surrender excess batch instances
            # while BBP stays 0 — idle/least-loaded (and still-loading)
            # instances first, displaced requests re-enter the queue
            if obs is not None:
                obs.set_context(_R_BBP_TRIM, float(dec2.bbp_before), 0.0)
            victims = sorted(cluster.by_model(model, InstanceType.BATCH),
                             key=lambda i: (i.active, i.n_running))
            for inst in victims[:dec2.remove_instances]:
                for r in cluster.retire(inst):
                    queue.requeue(r)
        else:
            if obs is not None and dec2.add_instances:
                # Algorithm 2: BBP > 0, add until it clears
                obs.set_context(_R_BBP_ADD, float(dec2.bbp_before), 0.0)
            for _ in range(dec2.add_instances):
                if self._provision(cluster, InstanceType.BATCH, now,
                                   model) is None:
                    break               # shared chip budget exhausted
        if obs is not None:
            obs.record_signals(
                now, cluster, model,
                dec.ibp, iscaler.theta,
                dec2.bbp_before, scaler.last_wait,
                queue.n_interactive_for(model),
                queue.n_batch_for(model),
                len(inter), len(mixed),
                len(cluster.by_model(model, InstanceType.BATCH)))

    def observe_completion(self, req: Request) -> None:
        # per-model output-length fit: each model's QLM estimator only
        # sees its own completions (output models cached flat — this runs
        # once per finished request, so ``OutputLengthModel.observe`` is
        # inlined: same moment-sum arithmetic, one call fewer)
        om = self._out_models.get(req.model)
        if om is None:
            om = self._out_models[req.model] = \
                self._estimator_for(req.model).output_model
        o = req.output_len
        om._n += 1
        om._sum += o
        om._sumsq += o * o
        om._stale = True


@dataclass
class LlumnixController(BaseController):
    """Utilization-band autoscaler; SLO-unaware, no queue deferral.
    Single-model baseline (the paper's comparison arm)."""
    model: str = "llama-8b"
    low: float = 0.3
    high: float = 0.8
    static_batch: int = 64
    min_instances: int = 1

    def __post_init__(self):
        self.scaler = LlumnixAutoscaler(self.low, self.high,
                                        self.min_instances)

    # every Llumnix instance serves whatever arrives -> model as MIXED
    def control(self, cluster: SimCluster, queue: GlobalQueue,
                now: float) -> None:
        if not cluster.instances:
            cluster.provision(self.model, InstanceType.MIXED, now,
                              static_batch=self.static_batch)
        insts = cluster.active_instances()
        util = (sum(i.kv_utilization() for i in insts) / len(insts)) \
            if insts else 1.0
        delta = self.scaler.update(util, len(cluster.instances), len(queue))
        if delta > 0:
            for _ in range(delta):
                cluster.provision(self.model, InstanceType.MIXED, now,
                                  static_batch=self.static_batch)
        elif delta < 0:
            idle = [i for i in insts if i.n_running == 0]
            for inst in idle[:(-delta)]:
                if len(cluster.instances) <= self.min_instances:
                    break
                cluster.retire(inst)

    def observe_completion(self, req: Request) -> None:
        pass
