"""Cluster controllers: Chiron (hierarchical) and the baselines.

A controller's ``control(cluster, queue, now)`` runs every control interval
and turns backpressure into provision/retire actions; ``route`` places
queued requests onto instances per the paper's preferential routing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.baselines import LlumnixAutoscaler
from repro.core.global_autoscaler import BatchAutoscaler, InteractiveAutoscaler
from repro.core.local_autoscaler import LocalAutoscaler
from repro.core.waiting_time import WaitingTimeEstimator
from repro.serving.global_queue import GlobalQueue
from repro.serving.request import Request, RequestType
from repro.sim.cluster import InstanceType, SimCluster, SimInstance


def _best_fit(insts: List[SimInstance]) -> Optional[SimInstance]:
    """Most-loaded instance that can still admit (packing). Packing — not
    least-loaded spreading — keeps interactive requests concentrated so
    IBP counts genuinely-busy instances and mixed spare capacity stays
    spare (otherwise every mixed instance 'runs interactive' and the
    interactive scaler over-provisions 3x its own additions)."""
    cands = [i for i in insts if i.active]
    if not cands:
        return None
    return max(cands, key=lambda i: i.slot_utilization())


class BaseController:
    """Shared routing: interactive -> interactive then mixed (preempting
    batch); batch -> batch instances then spare mixed capacity.

    ``route`` is the full preferential pass (every fixed tick / control
    tick); the event core additionally calls ``route_interactive`` on every
    event (zero-queuing) and ``backfill`` for just-freed instances, so the
    hot path never rescans the whole cluster per completion.
    """

    serves_batch_on_mixed = True

    def route(self, cluster: SimCluster, queue: GlobalQueue, now: float) -> None:
        self.route_interactive(cluster, queue, now)
        if not queue.n_batch:
            return
        pools = [cluster.by_type(InstanceType.BATCH)]
        if self.serves_batch_on_mixed:
            pools.append(cluster.by_type(InstanceType.MIXED))
        for pool in pools:
            self.backfill(pool, queue, now)

    def route_interactive(self, cluster: SimCluster, queue: GlobalQueue,
                          now: float) -> None:
        # ---- interactive: zero-queuing
        while queue.n_interactive:
            req = queue.interactive[0]
            placed = False
            for pool in (cluster.by_type(InstanceType.INTERACTIVE),
                         cluster.by_type(InstanceType.MIXED)):
                inst = _best_fit([i for i in pool if i.can_admit(req)])
                if inst is not None:
                    inst.admit(queue.pop_interactive(), now)
                    placed = True
                    break
            if not placed:
                # preempt a batch request on a mixed instance (the O(1)
                # batch-count guard keeps a saturated all-interactive
                # cluster from rescanning every batch on every pass)
                for inst in cluster.by_type(InstanceType.MIXED):
                    if not inst.active or inst.n_running_batch() == 0:
                        continue
                    victim = inst.evict_one_batch(now)
                    if victim is not None:
                        queue.requeue(victim)
                        inst.admit(queue.pop_interactive(), now)
                        placed = True
                        break
            if not placed:
                break   # cluster saturated; request waits (SLO at risk)

    def backfill(self, insts, queue: GlobalQueue, now: float) -> None:
        """Fill spare capacity on ``insts`` from the batch queue. The queue
        pops in service order (resume lane, then earliest deadline / FCFS)
        at O(log n) per admission — no per-pass sort."""
        for inst in insts:
            if inst.itype == InstanceType.INTERACTIVE:
                continue             # interactive pool never serves batch
            # cheap slot-full rejection before touching the queue
            while inst.active and inst.n_running < inst.max_batch_size \
                    and queue.n_batch:
                req = queue.peek_batch()
                if not inst.can_admit(req):
                    break
                inst.admit(queue.pop_batch_fcfs(), now)

    def control(self, cluster: SimCluster, queue: GlobalQueue,
                now: float) -> None:
        raise NotImplementedError


@dataclass
class ChironController(BaseController):
    """The paper's hierarchical autoscaler (local + global)."""
    model: str = "llama-8b"
    theta: float = 1.0 / 3.0
    delta: float = 0.1
    itl_slo_interactive: float = 0.2
    itl_slo_batch: float = 2.0
    local_enabled: bool = True          # False -> "Global" ablation arm
    global_enabled: bool = True         # False -> "Local" ablation arm
    static_batch: int = 64              # used when local_enabled=False
    estimator: WaitingTimeEstimator = field(default_factory=WaitingTimeEstimator)
    min_instances: int = 1
    init_batch: int = 8
    max_batch: int = 4096
    group_k: int = 0                    # -1 disables request groups (Fig. 6)
    # paper §5.2: Theta is chosen from historical arrival spikes (tail
    # spike 3x -> Theta = 1/3). auto_theta re-estimates it online from the
    # observed arrival process every `theta_refresh` seconds.
    auto_theta: bool = False
    theta_refresh: float = 120.0

    def __post_init__(self):
        self.interactive_scaler = InteractiveAutoscaler(
            self.theta, self.delta, self.min_instances)
        self._batch_scaler: Optional[BatchAutoscaler] = None
        self._arrivals: List[float] = []
        self._next_theta_update = self.theta_refresh

    # ------------------------------------------------------------ helpers
    def _mk_local(self, slo: float) -> Optional[LocalAutoscaler]:
        if not self.local_enabled:
            return None
        return LocalAutoscaler(itl_slo=slo, init_batch=self.init_batch,
                               max_batch=self.max_batch)

    def _provision(self, cluster: SimCluster, itype: InstanceType,
                   now: float) -> Optional[SimInstance]:
        slo = self.itl_slo_batch if itype == InstanceType.BATCH \
            else self.itl_slo_interactive
        return cluster.provision(
            self.model, itype, now,
            local_autoscaler=self._mk_local(slo),
            static_batch=None if self.local_enabled else self.static_batch)

    def batch_instance_throughput(self, cluster: SimCluster) -> float:
        perf = cluster.perf_factory(self.model)
        b = perf.optimal_batch(self.itl_slo_batch, mean_ctx=512.0)
        return perf.throughput(b, mean_ctx=512.0)

    # ------------------------------------------------------------ control
    def observe_arrival(self, req: Request, now: float) -> None:
        if self.auto_theta and req.is_interactive:
            self._arrivals.append(now)

    def _refresh_theta(self, now: float) -> None:
        if not self.auto_theta or now < self._next_theta_update:
            return
        self._next_theta_update = now + self.theta_refresh
        if len(self._arrivals) < 20:
            return
        from repro.sim.workload import arrival_spikes

        class _R:  # arrival_spikes wants .arrival_time
            __slots__ = ("arrival_time",)

            def __init__(self, t):
                self.arrival_time = t
        spikes = arrival_spikes([_R(t) for t in self._arrivals], 30.0)
        if spikes:
            import numpy as np
            tail = float(np.percentile(spikes, 99.0))
            self.interactive_scaler.theta = 1.0 / max(tail, 1.0)

    def control(self, cluster: SimCluster, queue: GlobalQueue,
                now: float) -> None:
        # 0. bootstrap + optional Theta re-estimation from arrival history
        self._refresh_theta(now)
        if not cluster.instances:
            self._provision(cluster, InstanceType.MIXED, now)

        # 1. local autoscaling on every instance
        if self.local_enabled:
            for inst in cluster.active_instances():
                inst.update_local_autoscaler()

        # 2. interactive/mixed scaling on IBP
        if self.global_enabled:
            inter = cluster.by_type(InstanceType.INTERACTIVE)
            mixed = cluster.by_type(InstanceType.MIXED)
            n_running = sum(1 for i in inter + mixed if i.runs_interactive())
            dec = self.interactive_scaler.update(n_running, len(inter),
                                                 len(mixed))
            if dec.delta_instances > 0:
                for _ in range(dec.delta_instances):
                    if self._provision(cluster, InstanceType.MIXED, now) is None:
                        break
            elif dec.delta_instances < 0:
                idle_mixed = [i for i in cluster.by_type(InstanceType.MIXED)
                              if i.active and not i.runs_interactive()]
                idle_mixed.sort(key=lambda i: i.n_running)
                for inst in idle_mixed[:-dec.delta_instances]:
                    if len(cluster.by_type(InstanceType.MIXED)) + \
                            len(cluster.by_type(InstanceType.INTERACTIVE)) \
                            <= self.min_instances:
                        break
                    for r in cluster.retire(inst):
                        queue.requeue(r)

            # 3. batch scaling on BBP (Algorithm 2)
            if self._batch_scaler is None:
                self._batch_scaler = BatchAutoscaler(
                    self.estimator, self.batch_instance_throughput(cluster),
                    group_k=self.group_k)
            spare = sum(i.spare_throughput()
                        for i in cluster.by_type(InstanceType.MIXED)
                        if i.active)
            n_batch_inst = len(cluster.by_type(InstanceType.BATCH))
            n_active_batch = sum(i.n_running_batch()
                                 for i in cluster.instances)
            # pass the queue itself: request groups are maintained
            # incrementally off its add/remove stream, not re-clustered
            dec2 = self._batch_scaler.update(
                queue, now,
                n_batch_instances=n_batch_inst,
                spare_mixed_throughput=spare,
                n_active_batch_requests=n_active_batch)
            if dec2.retire_all:
                for inst in list(cluster.by_type(InstanceType.BATCH)):
                    for r in cluster.retire(inst):
                        queue.requeue(r)
            elif dec2.remove_instances > 0:
                # Algorithm 2 minimality: surrender excess batch instances
                # while BBP stays 0 — idle/least-loaded (and still-loading)
                # instances first, displaced requests re-enter the queue
                victims = sorted(cluster.by_type(InstanceType.BATCH),
                                 key=lambda i: (i.active, i.n_running))
                for inst in victims[:dec2.remove_instances]:
                    for r in cluster.retire(inst):
                        queue.requeue(r)
            else:
                for _ in range(dec2.add_instances):
                    if self._provision(cluster, InstanceType.BATCH, now) is None:
                        break

    def observe_completion(self, req: Request) -> None:
        self.estimator.output_model.observe(req.output_len)


@dataclass
class LlumnixController(BaseController):
    """Utilization-band autoscaler; SLO-unaware, no queue deferral."""
    model: str = "llama-8b"
    low: float = 0.3
    high: float = 0.8
    static_batch: int = 64
    min_instances: int = 1

    def __post_init__(self):
        self.scaler = LlumnixAutoscaler(self.low, self.high,
                                        self.min_instances)

    # every Llumnix instance serves whatever arrives -> model as MIXED
    def control(self, cluster: SimCluster, queue: GlobalQueue,
                now: float) -> None:
        if not cluster.instances:
            cluster.provision(self.model, InstanceType.MIXED, now,
                              static_batch=self.static_batch)
        insts = cluster.active_instances()
        util = (sum(i.kv_utilization() for i in insts) / len(insts)) \
            if insts else 1.0
        delta = self.scaler.update(util, len(cluster.instances), len(queue))
        if delta > 0:
            for _ in range(delta):
                cluster.provision(self.model, InstanceType.MIXED, now,
                                  static_batch=self.static_batch)
        elif delta < 0:
            idle = [i for i in insts if i.n_running == 0]
            for inst in idle[:(-delta)]:
                if len(cluster.instances) <= self.min_instances:
                    break
                cluster.retire(inst)

    def observe_completion(self, req: Request) -> None:
        pass
