"""Trace replay I/O: round-trip ``Trace`` columns to CSV/JSONL files.

Two dialects are understood on load:

- **Native** (what ``save_trace`` writes): one row per request with the
  canonical columns ``arrival, prompt_len, output_len, interactive,
  ttft_slo, itl_slo, model`` (plus ``origin``/``tenant`` when the trace
  carries those vocabularies). Round-trips a synthetic scenario exactly.
- **Azure-LLM-inference style** (azure-public-dataset): ``TIMESTAMP,
  ContextTokens, GeneratedTokens`` — ISO timestamps are vectorized through
  ``numpy.datetime64`` and normalized so the trace starts at t=0; missing
  class/SLO columns are filled from the defaults below.

Column names are matched case-insensitively against the alias table, so
``arrival_time``/``time``/``TIMESTAMP`` all land on the arrival column and
``ContextTokens``/``input_tokens``/``prompt_len`` on the prompt column.

Format is picked by extension: ``.jsonl`` -> JSON lines, anything else is
parsed as CSV; a trailing ``.gz`` on either transparently gzips the file
(``save_trace``/``load_trace``/``stream_trace`` all honour it).

Multi-day production traces stream through :func:`stream_trace`: one file
or a list of files (day-per-file archives concatenate back to back) is
parsed into chunks yielded as a :class:`~repro.sim.workload.TraceStream`,
so replaying never holds the whole file (or its columns) in memory.
Chunks are either fixed row counts (``chunk_requests``) or — with
``window_s > 0`` — wall-clock time windows whose memory tracks the actual
arrival rate (a quiet night costs nearly nothing, a spike is still capped
by ``chunk_requests``). Streamed files must already be arrival-sorted —
the stream validates chunk boundaries; ISO timestamps are normalized
against the stream-global first timestamp.
"""
from __future__ import annotations

import csv
import gzip
import json
import math
from typing import Dict, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.serving.request import BATCH_TTFT_SLO
from repro.sim.workload import DEFAULT_MODEL, Trace, TraceStream, make_trace

# canonical column -> accepted aliases (lowercased)
_ALIASES: Dict[str, Sequence[str]] = {
    "arrival": ("arrival", "arrival_time", "timestamp", "time", "t"),
    "prompt_len": ("prompt_len", "contexttokens", "context_tokens",
                   "input_tokens", "prompt_tokens", "input_len"),
    "output_len": ("output_len", "generatedtokens", "generated_tokens",
                   "output_tokens", "gen_tokens"),
    "interactive": ("interactive", "is_interactive", "class",
                    "request_type", "type"),
    "ttft_slo": ("ttft_slo", "slo_ttft"),
    "itl_slo": ("itl_slo", "slo_itl"),
    "model": ("model", "model_name", "deployment"),
    "origin": ("origin", "origin_region", "region", "source_region"),
    "tenant": ("tenant", "tenant_id", "customer", "account"),
    "attempt": ("attempt", "retries", "retry_attempt", "attempts"),
}

_INTERACTIVE_WORDS = {"1", "true", "interactive", "chat", "conversation"}


def _canon(name: str) -> Optional[str]:
    low = name.strip().lower()
    for canon, aliases in _ALIASES.items():
        if low in aliases:
            return canon
    return None


def _fmt_path(path: str) -> str:
    """Extension used for format dispatch (``.gz`` is transparent)."""
    return path[:-3] if path.endswith(".gz") else path


def _open(path: str, mode: str):
    if path.endswith(".gz"):
        return gzip.open(path, mode + "t")
    return open(path, mode, newline="" if mode == "r" else None)


def save_trace(trace: Trace, path: str) -> None:
    """Write a trace in the native schema (CSV or ``.jsonl``; ``.gz``
    compresses)."""
    models = trace.models
    origins = trace.origins
    tenants = trace.tenants
    # retry-attempt column only when it carries information — a fresh
    # trace round-trips to the byte-identical file it always did
    attempt = trace.attempt
    if attempt is not None and not attempt.any():
        attempt = None
    att_col = attempt.tolist() if attempt is not None \
        else [0] * trace.n
    cols = zip(trace.arrival.tolist(), trace.prompt_len.tolist(),
               trace.output_len.tolist(), trace.interactive.tolist(),
               trace.ttft_slo.tolist(), trace.itl_slo.tolist(),
               trace.model_idx.tolist(), trace.origin_idx.tolist(),
               trace.tenant_idx.tolist(), att_col)
    with _open(path, "w") as f:
        if _fmt_path(path).endswith(".jsonl"):
            for t, p, o, c, tt, il, m, g, tn, a in cols:
                row = {"arrival": t, "prompt_len": p, "output_len": o,
                       "interactive": bool(c), "ttft_slo": tt,
                       "itl_slo": il, "model": models[m]}
                if origins:
                    row["origin"] = origins[g]
                if tenants:
                    row["tenant"] = tenants[tn]
                if attempt is not None:
                    row["attempt"] = a
                f.write(json.dumps(row) + "\n")
        else:
            w = csv.writer(f, lineterminator="\n")   # RFC-4180 quoting
            header = ["arrival", "prompt_len", "output_len",
                      "interactive", "ttft_slo", "itl_slo", "model"]
            if origins:
                header.append("origin")
            if tenants:
                header.append("tenant")
            if attempt is not None:
                header.append("attempt")
            w.writerow(header)
            for t, p, o, c, tt, il, m, g, tn, a in cols:
                row = [repr(t), p, o, int(c), repr(tt), repr(il), models[m]]
                if origins:
                    row.append(origins[g])
                if tenants:
                    row.append(tenants[tn])
                if attempt is not None:
                    row.append(a)
                w.writerow(row)


def _parse_arrivals(raw: List[str]) -> np.ndarray:
    """Float seconds, or ISO timestamps normalized to seconds from t0."""
    try:
        return np.asarray(raw, dtype=np.float64)
    except ValueError:
        ts = np.array(raw, dtype="datetime64[us]")
        return (ts - ts.min()) / np.timedelta64(1, "s")


def _parse_interactive(raw: List[str]) -> np.ndarray:
    vals = np.array([v.strip().lower() for v in raw])
    return np.isin(vals, list(_INTERACTIVE_WORDS))


def _columns_to_trace(cols: Dict[str, List], n: int, *,
                      interactive_default: bool,
                      batch_ttft_slo: float,
                      model_default: str) -> Trace:
    if "arrival" not in cols or "prompt_len" not in cols \
            or "output_len" not in cols:
        missing = {"arrival", "prompt_len", "output_len"} - set(cols)
        raise ValueError(f"trace is missing required columns: {sorted(missing)}")
    arrival = _parse_arrivals([str(v) for v in cols["arrival"]])
    prompt = np.asarray(cols["prompt_len"], dtype=np.float64).astype(np.int64)
    output = np.asarray(cols["output_len"], dtype=np.float64).astype(np.int64)
    if "interactive" in cols:
        first = cols["interactive"][0]
        if isinstance(first, (bool, np.bool_, int, float)):
            interactive = np.asarray(cols["interactive"]).astype(bool)
        else:
            interactive = _parse_interactive([str(v) for v in
                                              cols["interactive"]])
    else:
        interactive = np.full(n, interactive_default, dtype=bool)
    ttft = np.asarray(cols["ttft_slo"], dtype=np.float64) \
        if "ttft_slo" in cols else None
    itl = np.asarray(cols["itl_slo"], dtype=np.float64) \
        if "itl_slo" in cols else None
    if "model" in cols:
        names = np.array([str(v) for v in cols["model"]])
        models, model_idx = np.unique(names, return_inverse=True)
        models = tuple(models.tolist())
        model_idx = np.asarray(model_idx, dtype=np.int32)
    else:
        models, model_idx = (model_default,), None
    if "origin" in cols:
        onames = np.array([str(v) for v in cols["origin"]])
        origins, origin_idx = np.unique(onames, return_inverse=True)
        origins = tuple(origins.tolist())
        origin_idx = np.asarray(origin_idx, dtype=np.int32)
    else:
        origins, origin_idx = (), None
    if "tenant" in cols:
        tnames = np.array([str(v) for v in cols["tenant"]])
        tenants, tenant_idx = np.unique(tnames, return_inverse=True)
        tenants = tuple(tenants.tolist())
        tenant_idx = np.asarray(tenant_idx, dtype=np.int32)
    else:
        tenants, tenant_idx = (), None
    if "attempt" in cols:
        attempt = np.asarray(cols["attempt"],
                             dtype=np.float64).astype(np.int32)
        if not attempt.any():
            attempt = None
    else:
        attempt = None
    # make_trace owns the class-mask SLO defaulting and the sort — one
    # rule for generated and loaded traces alike
    return make_trace(arrival, prompt, output, interactive,
                      ttft_slo=ttft, itl_slo=itl,
                      batch_ttft_slo=batch_ttft_slo,
                      model_idx=model_idx, models=models,
                      origin_idx=origin_idx, origins=origins,
                      tenant_idx=tenant_idx, tenants=tenants,
                      attempt=attempt)


def _read_columns(rows):
    """Accumulate parsed rows into ``(canonical columns, n)`` (ragged
    rows fail loudly)."""
    cols: Dict[str, List] = {}
    n = 0
    for row in rows:
        for k, v in row.items():
            cols.setdefault(k, []).append(v)
        n += 1
    # ragged rows leave short columns behind; fail loudly rather than shift
    for k, v in cols.items():
        if len(v) != n:
            raise ValueError(f"column {k!r} has {len(v)} values for {n} rows")
    return cols, n


def _iter_rows(path: str):
    """Yield one ``{canonical column -> raw value}`` dict per data row,
    parsing the file incrementally (shared by load and stream paths)."""
    if _fmt_path(path).endswith(".jsonl"):
        with _open(path, "r") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                row = json.loads(line)
                out = {}
                for k, v in row.items():
                    ck = _canon(k)
                    if ck is not None:
                        out[ck] = v
                yield out
    else:
        with _open(path, "r") as f:
            reader = csv.reader(f)           # RFC-4180: quoted fields safe
            header = next(reader, [])
            keys = [_canon(h) for h in header]
            for row in reader:
                if not row:
                    continue
                yield {k: v for k, v in zip(keys, row) if k is not None}


def load_trace(path: str, *, interactive_default: bool = True,
               batch_ttft_slo: float = BATCH_TTFT_SLO,
               model_default: str = DEFAULT_MODEL,
               max_requests: int = 0) -> Trace:
    """Load a CSV/JSONL trace (optionally ``.gz``) into a sorted
    :class:`Trace`.

    ``max_requests > 0`` truncates after sorting (head of the trace).
    Unknown columns are ignored; missing class/SLO/model columns are
    filled from the defaults.
    """
    cols, n = _read_columns(_iter_rows(path))
    if n == 0:
        raise ValueError(f"empty trace file: {path}")
    tr = _columns_to_trace(cols, n, interactive_default=interactive_default,
                           batch_ttft_slo=batch_ttft_slo,
                           model_default=model_default)
    if max_requests and tr.n > max_requests:
        tr = tr.head(max_requests)
    return tr


def _row_arrival_seconds(raw, t0_iso: List) -> float:
    """Arrival of one raw row in seconds: plain floats pass through, ISO
    timestamps are normalized against the stream's first timestamp
    (``t0_iso`` is a shared one-element mutable cell)."""
    try:
        return float(raw)
    except (TypeError, ValueError):
        ts = np.datetime64(str(raw), "us")
        if not t0_iso:
            t0_iso.append(ts)
        return float((ts - t0_iso[0]) / np.timedelta64(1, "s"))


def stream_trace(path: Union[str, Sequence[str]], *,
                 chunk_requests: int = 65536,
                 window_s: float = 0.0,
                 interactive_default: bool = True,
                 batch_ttft_slo: float = BATCH_TTFT_SLO,
                 model_default: str = DEFAULT_MODEL,
                 max_requests: int = 0) -> TraceStream:
    """Stream one or more CSV/JSONL traces (optionally ``.gz``) as
    arrival-ordered :class:`Trace` chunks.

    The windowed loader for multi-day production traces: at no point is
    the whole file resident — each chunk's columns are built and handed
    to the consumer (the event core's request cursor accepts the stream
    directly) before the next chunk is parsed.

    Chunking policy:

    - ``window_s == 0`` (default): fixed-size chunks of
      ``chunk_requests`` rows.
    - ``window_s > 0``: *time-windowed* chunks — a chunk closes when the
      next row's arrival crosses the current ``window_s`` boundary, so a
      day-long trace streams in wall-clock windows whose memory tracks
      the actual arrival rate rather than a fixed row count.
      ``chunk_requests`` still caps a single window's rows (a traffic
      spike inside one window must not buffer unbounded rows); ISO
      timestamps are normalized against the first row seen.

    ``path`` may be a list of files replayed back to back — day-per-file
    archives concatenate without ever being loaded together; arrival
    order must hold across the file boundary (``TraceStream`` validates
    every chunk boundary and raises otherwise). ``max_requests > 0``
    stops after that many rows.
    """
    if chunk_requests <= 0:
        raise ValueError("chunk_requests must be positive")
    if window_s < 0:
        raise ValueError("window_s must be >= 0")
    paths = [path] if isinstance(path, str) else list(path)
    if not paths:
        raise ValueError("stream_trace needs at least one path")

    def _flush(buf: List[Dict]) -> Trace:
        cols, n = _read_columns(buf)
        return _columns_to_trace(
            cols, n, interactive_default=interactive_default,
            batch_ttft_slo=batch_ttft_slo, model_default=model_default)

    def chunks() -> Iterator[Trace]:
        buf: List[Dict] = []
        served = 0
        window_end = window_s
        t0_iso: List = []
        for p in paths:
            for row in _iter_rows(p):
                raw = row.get("arrival")
                if raw is not None:
                    # normalize ISO timestamps against the stream-global
                    # t0 here (per-chunk normalization would re-zero every
                    # chunk and break cross-chunk arrival ordering)
                    try:
                        arr = float(raw)
                    except (TypeError, ValueError):
                        arr = _row_arrival_seconds(raw, t0_iso)
                        row["arrival"] = arr
                else:
                    arr = 0.0
                if window_s > 0 and arr >= window_end:
                    if buf:
                        yield _flush(buf)
                        served += len(buf)
                        buf = []
                    # jump straight to the window containing ``arr`` —
                    # stepping one window at a time would spin
                    # O(arr/window_s) on large absolute timestamps
                    # (e.g. un-normalized unix-epoch seconds)
                    window_end = (math.floor(arr / window_s) + 1) * window_s
                buf.append(row)
                if max_requests and served + len(buf) >= max_requests:
                    buf = buf[:max_requests - served]
                    yield _flush(buf)
                    return
                if len(buf) >= chunk_requests:
                    yield _flush(buf)
                    served += len(buf)
                    buf = []
        if buf:
            yield _flush(buf)

    return TraceStream(chunks())
