"""Trace replay I/O: round-trip ``Trace`` columns to CSV/JSONL files.

Two dialects are understood on load:

- **Native** (what ``save_trace`` writes): one row per request with the
  canonical columns ``arrival, prompt_len, output_len, interactive,
  ttft_slo, itl_slo, model``. Round-trips a synthetic scenario exactly.
- **Azure-LLM-inference style** (azure-public-dataset): ``TIMESTAMP,
  ContextTokens, GeneratedTokens`` — ISO timestamps are vectorized through
  ``numpy.datetime64`` and normalized so the trace starts at t=0; missing
  class/SLO columns are filled from the defaults below.

Column names are matched case-insensitively against the alias table, so
``arrival_time``/``time``/``TIMESTAMP`` all land on the arrival column and
``ContextTokens``/``input_tokens``/``prompt_len`` on the prompt column.

Format is picked by extension: ``.jsonl`` -> JSON lines, anything else is
parsed as CSV.
"""
from __future__ import annotations

import csv
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import BATCH_TTFT_SLO
from repro.sim.workload import DEFAULT_MODEL, Trace, make_trace

# canonical column -> accepted aliases (lowercased)
_ALIASES: Dict[str, Sequence[str]] = {
    "arrival": ("arrival", "arrival_time", "timestamp", "time", "t"),
    "prompt_len": ("prompt_len", "contexttokens", "context_tokens",
                   "input_tokens", "prompt_tokens", "input_len"),
    "output_len": ("output_len", "generatedtokens", "generated_tokens",
                   "output_tokens", "gen_tokens"),
    "interactive": ("interactive", "is_interactive", "class",
                    "request_type", "type"),
    "ttft_slo": ("ttft_slo", "slo_ttft"),
    "itl_slo": ("itl_slo", "slo_itl"),
    "model": ("model", "model_name", "deployment"),
}

_INTERACTIVE_WORDS = {"1", "true", "interactive", "chat", "conversation"}


def _canon(name: str) -> Optional[str]:
    low = name.strip().lower()
    for canon, aliases in _ALIASES.items():
        if low in aliases:
            return canon
    return None


def save_trace(trace: Trace, path: str) -> None:
    """Write a trace in the native schema (CSV or ``.jsonl``)."""
    models = trace.models
    cols = zip(trace.arrival.tolist(), trace.prompt_len.tolist(),
               trace.output_len.tolist(), trace.interactive.tolist(),
               trace.ttft_slo.tolist(), trace.itl_slo.tolist(),
               trace.model_idx.tolist())
    with open(path, "w") as f:
        if path.endswith(".jsonl"):
            for t, p, o, c, tt, il, m in cols:
                f.write(json.dumps({
                    "arrival": t, "prompt_len": p, "output_len": o,
                    "interactive": bool(c), "ttft_slo": tt, "itl_slo": il,
                    "model": models[m]}) + "\n")
        else:
            w = csv.writer(f, lineterminator="\n")   # RFC-4180 quoting
            w.writerow(["arrival", "prompt_len", "output_len",
                        "interactive", "ttft_slo", "itl_slo", "model"])
            for t, p, o, c, tt, il, m in cols:
                w.writerow([repr(t), p, o, int(c), repr(tt), repr(il),
                            models[m]])


def _parse_arrivals(raw: List[str]) -> np.ndarray:
    """Float seconds, or ISO timestamps normalized to seconds from t0."""
    try:
        return np.asarray(raw, dtype=np.float64)
    except ValueError:
        ts = np.array(raw, dtype="datetime64[us]")
        return (ts - ts.min()) / np.timedelta64(1, "s")


def _parse_interactive(raw: List[str]) -> np.ndarray:
    vals = np.array([v.strip().lower() for v in raw])
    return np.isin(vals, list(_INTERACTIVE_WORDS))


def _columns_to_trace(cols: Dict[str, List], n: int, *,
                      interactive_default: bool,
                      batch_ttft_slo: float,
                      model_default: str) -> Trace:
    if "arrival" not in cols or "prompt_len" not in cols \
            or "output_len" not in cols:
        missing = {"arrival", "prompt_len", "output_len"} - set(cols)
        raise ValueError(f"trace is missing required columns: {sorted(missing)}")
    arrival = _parse_arrivals([str(v) for v in cols["arrival"]])
    prompt = np.asarray(cols["prompt_len"], dtype=np.float64).astype(np.int64)
    output = np.asarray(cols["output_len"], dtype=np.float64).astype(np.int64)
    if "interactive" in cols:
        first = cols["interactive"][0]
        if isinstance(first, (bool, np.bool_, int, float)):
            interactive = np.asarray(cols["interactive"]).astype(bool)
        else:
            interactive = _parse_interactive([str(v) for v in
                                              cols["interactive"]])
    else:
        interactive = np.full(n, interactive_default, dtype=bool)
    ttft = np.asarray(cols["ttft_slo"], dtype=np.float64) \
        if "ttft_slo" in cols else None
    itl = np.asarray(cols["itl_slo"], dtype=np.float64) \
        if "itl_slo" in cols else None
    if "model" in cols:
        names = np.array([str(v) for v in cols["model"]])
        models, model_idx = np.unique(names, return_inverse=True)
        models = tuple(models.tolist())
        model_idx = np.asarray(model_idx, dtype=np.int32)
    else:
        models, model_idx = (model_default,), None
    # make_trace owns the class-mask SLO defaulting and the sort — one
    # rule for generated and loaded traces alike
    return make_trace(arrival, prompt, output, interactive,
                      ttft_slo=ttft, itl_slo=itl,
                      batch_ttft_slo=batch_ttft_slo,
                      model_idx=model_idx, models=models)


def load_trace(path: str, *, interactive_default: bool = True,
               batch_ttft_slo: float = BATCH_TTFT_SLO,
               model_default: str = DEFAULT_MODEL,
               max_requests: int = 0) -> Trace:
    """Load a CSV/JSONL trace into a sorted :class:`Trace`.

    ``max_requests > 0`` truncates after sorting (head of the trace).
    Unknown columns are ignored; missing class/SLO/model columns are
    filled from the defaults.
    """
    if path.endswith(".jsonl"):
        cols: Dict[str, List] = {}
        n = 0
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                row = json.loads(line)
                for k, v in row.items():
                    ck = _canon(k)
                    if ck is not None:
                        cols.setdefault(ck, []).append(v)
                n += 1
    else:
        with open(path, newline="") as f:
            reader = csv.reader(f)           # RFC-4180: quoted fields safe
            header = next(reader, [])
            keys = [_canon(h) for h in header]
            raw: List[List[str]] = [[] for _ in header]
            n = 0
            for row in reader:
                if not row:
                    continue
                for slot, v in zip(raw, row):
                    slot.append(v)
                n += 1
        cols = {k: v for k, v in zip(keys, raw) if k is not None}
    if n == 0:
        raise ValueError(f"empty trace file: {path}")
    # ragged rows leave short columns behind; fail loudly rather than shift
    for k, v in cols.items():
        if len(v) != n:
            raise ValueError(f"column {k!r} has {len(v)} values for {n} rows")
    tr = _columns_to_trace(cols, n, interactive_default=interactive_default,
                           batch_ttft_slo=batch_ttft_slo,
                           model_default=model_default)
    if max_requests and tr.n > max_requests:
        tr = tr.head(max_requests)
    return tr
