"""Simulated serving cluster: instances driven by the analytic perf model.

The control plane (autoscalers, routing, queues, request groups) is the
production ``repro.core`` / ``repro.serving`` code; only the data plane —
how long a decode step takes — is simulated, using ``PerfModel``. Instance
bring-up takes ``model_load_time()`` (the 15–60 s that motivates Chiron's
over-provisioning), and every provision/retire action is counted for the
hysteresis metric.

Two data-plane drivers share the same instance state:

- ``step(dt, now)``: the fixed-tick reference — every running sequence is
  walked each tick.
- ``advance(now)``: the event-core fluid model. Continuous batching gives
  every decoding sequence the same token rate, so decode progress is a
  single per-instance *virtual clock* (tokens emitted per sequence);
  sequence finish order is a heap over virtual finish times and KV/context
  aggregates are closed forms of the clock. Advancing an instance is O(1)
  plus O(log B) per completed/transitioned sequence — independent of
  batch size, which is what keeps million-request traces tractable.

Control-plane queries (``can_admit``, ``mean_ctx``, ``runs_interactive``,
``min_itl_slo``…) are all O(1) via maintained aggregates; the routing hot
path never scans a batch.
"""
from __future__ import annotations

import enum
import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.local_autoscaler import LocalAutoscaler
from repro.core.backpressure import LocalMetrics
from repro.serving.request import Request, RequestState, RequestType
from repro.sim.perf_model import PerfModel

_inst_counter = itertools.count()

# decode rate used when the quantized tick emulation truncates to zero
# tokens per tick (itl > dt: the tick loop makes no progress either)
_STALLED_ITL = 1e12

# health-EWMA ratio (observed ITL / healthy-model ITL) above which an
# instance is suspected slow and routed around (slow-node degradation)
SLOW_SUSPECT_RATIO = 1.8


class InstanceType(enum.Enum):
    INTERACTIVE = "interactive"
    MIXED = "mixed"
    BATCH = "batch"


class InstanceState(enum.Enum):
    LOADING = "loading"
    ACTIVE = "active"
    RETIRED = "retired"


@dataclass(eq=False)
class SimSeq:
    request: Request
    ctx_tokens: float            # prompt + generated so far (KV footprint)
    prefill_left: float          # seconds of prefill work remaining
    gen_f: float = 0.0           # fractional tokens generated
    # --- event-core fluid state ---
    decoding: bool = False
    prefill_done_t: float = 0.0  # absolute sim time prefill completes
    v0: float = 0.0              # instance vclock at decode entry
    gen_base: float = 0.0        # gen_f  - vclock while decoding
    ctx_base: float = 0.0        # ctx    - vclock while decoding

    @property
    def done(self) -> bool:
        return self.request.tokens_generated >= self.request.output_len


class SimInstance:
    def __init__(self, perf: PerfModel, itype: InstanceType, now: float, *,
                 local_autoscaler: Optional[LocalAutoscaler] = None,
                 static_batch: Optional[int] = None,
                 load_time: Optional[float] = None):
        self.id = next(_inst_counter)
        self.perf = perf
        self.model = perf.model_name
        self.itype = itype
        self.state = InstanceState.LOADING
        self.active = False          # mirrors state (hot-path flag)
        self.ready_time = now + (load_time if load_time is not None
                                 else perf.model_load_time())
        self.local = local_autoscaler
        self.static_batch = static_batch
        self.running: Dict[int, SimSeq] = {}    # req_id -> seq (ins. order)
        self.created_at = now
        # slow-node degradation: ground-truth ITL inflation (set by the
        # injection event) and the *observed* health signal the control
        # plane detects it with — an EWMA of observed-vs-model ITL ratio
        # updated at control ticks. Routing avoids suspected instances.
        self.slow_factor = 1.0
        self.health_ewma = 1.0
        # O(1) aggregates over ``running`` (the routing/control hot path
        # queries these every pass; scanning the batch would be O(B))
        self._kv_tokens = 0.0        # fixed-tick: sum of ctx_tokens
        self._n_interactive = 0
        self._slo_counts: Dict[float, int] = {}
        self._batch_lifo: List[int] = []   # batch admits (lazy-stale rids)
        # --- event-core state (unused on the fixed-tick path) ---
        self.event_mode = False
        self.last_advance = now      # sim time the fluid state is valid at
        self.vclock = 0.0            # fluid tokens emitted per decoding seq
        self._n_dec = 0              # decoding seqs
        self._kv_prefill = 0.0       # sum ctx over prefilling seqs
        self._kv_dec_base = 0.0      # sum ctx_base over decoding seqs
        self._prefill_heap: List[Tuple[float, int]] = []   # (t_done, rid)
        self._decode_heap: List[Tuple[float, int]] = []    # (vfin, rid)
        self._epoch = 0              # invalidates scheduled events
        self._pending_finished: List[Request] = []
        self._cluster = None         # backref set by SimCluster.provision

    # ------------------------------------------------------------ state
    def activate_if_ready(self, now: float) -> None:
        if self.state == InstanceState.LOADING and now >= self.ready_time:
            self.state = InstanceState.ACTIVE
            self.active = True

    @property
    def max_batch_size(self) -> int:
        if self.local is not None:
            return self.local.max_batch_size
        return self.static_batch or 64

    @property
    def n_running(self) -> int:
        return len(self.running)

    def n_running_batch(self) -> int:
        return len(self.running) - self._n_interactive

    def mean_ctx(self) -> float:
        n = len(self.running)
        return self.kv_tokens() / n if n else 0.0

    def kv_tokens(self) -> float:
        if self.event_mode:
            return self._kv_prefill + self._kv_dec_base \
                + self._n_dec * self.vclock
        return self._kv_tokens

    def kv_utilization(self) -> float:
        cap = self.perf.kv_capacity_tokens()
        if not math.isfinite(cap):
            return self.n_running / max(self.max_batch_size, 1)
        return self.kv_tokens() / cap

    def slot_utilization(self) -> float:
        return self.n_running / max(self.max_batch_size, 1)

    def current_itl(self) -> float:
        if not self.running:
            return 0.0
        return self.perf.itl(self.n_running, max(self.mean_ctx(), 1.0)) \
            * self.slow_factor

    def current_throughput(self) -> float:
        if not self.running:
            return 0.0
        return self.n_running / self.current_itl()

    def spare_throughput(self) -> float:
        """Tokens/s of unused slot capacity (used for BBP multiplexing)."""
        spare = self.max_batch_size - self.n_running
        if spare <= 0:
            return 0.0
        itl = self.perf.itl(self.max_batch_size, max(self.mean_ctx(), 512.0)) \
            * self.slow_factor
        return spare / itl

    def update_health(self, alpha: float = 0.5) -> None:
        """EWMA the observed-vs-model ITL ratio (the detection signal for
        slow-node degradation; called once per control tick). In the fluid
        model the observed ITL is exactly ``model * slow_factor``, so the
        ratio needs no second perf evaluation. Idle instances update too
        (a health probe): routing refuses suspected instances, so without
        this a drained victim could never clear its flag after recovery
        and would strand healthy capacity forever."""
        if not self.active:
            return
        self.health_ewma += alpha * (self.slow_factor - self.health_ewma)

    @property
    def suspected_slow(self) -> bool:
        return self.health_ewma > SLOW_SUSPECT_RATIO

    def runs_interactive(self) -> bool:
        return self._n_interactive > 0

    def min_itl_slo(self) -> float:
        if not self._slo_counts:
            return float("inf")
        return min(self._slo_counts)

    # ------------------------------------------------------------ intake
    def can_admit(self, req: Request) -> bool:
        if not self.active or self.n_running >= self.max_batch_size:
            return False
        if req.model != self.model:
            return False            # never serve a wrong-model request
        cap = self.perf.kv_capacity_tokens()
        if math.isfinite(cap):
            # hard admission wall well past the soft preemption inflection
            if self.kv_tokens() + req.prompt_len > 1.5 * cap:
                return False
        return True

    def admit(self, req: Request, now: float) -> None:
        if self.event_mode and self.last_advance < now:
            self.advance(now)        # settle old composition first
        restored = req.saved_kv is not None
        ctx = float(req.prompt_len + req.tokens_generated)
        prefill = 0.0 if restored else self.perf.prefill_time(req.prompt_len)
        if restored:
            req.saved_kv = None
        req.state = RequestState.RUNNING
        s = SimSeq(req, ctx, prefill, gen_f=float(req.tokens_generated))
        self.running[req.req_id] = s
        if self._cluster is not None:
            self._cluster.total_running += 1
        self._slo_counts[req.slo.itl] = \
            self._slo_counts.get(req.slo.itl, 0) + 1
        if req.is_interactive:
            self._n_interactive += 1
        else:
            self._batch_lifo.append(req.req_id)
        if self.event_mode:
            if prefill > 0:
                s.prefill_done_t = now + prefill
                heapq.heappush(self._prefill_heap, (s.prefill_done_t,
                                                    req.req_id))
                self._kv_prefill += ctx
            else:
                self._enter_decode(s, self.vclock)
                if req.first_token_time is None:
                    req.first_token_time = now
            self.mark_dirty()
        else:
            self._kv_tokens += ctx

    def evict_one_batch(self, now: float) -> Optional[Request]:
        """Mixed-instance preemption: interactive evicts batch; KV saved to
        host so the restart skips re-prefill (paper §3)."""
        if self.n_running_batch() == 0:
            return None
        if self.event_mode:
            self.advance(now)        # settle old composition first
        while self._batch_lifo:      # most-recent batch admit still running
            s = self.running.get(self._batch_lifo.pop())
            if s is None or s.request.request_type != RequestType.BATCH:
                continue             # stale entry (finished/evicted)
            self._materialize(s)
            self._remove_seq(s)
            s.request.state = RequestState.PREEMPTED
            s.request.preemptions += 1
            s.request.saved_kv = ("sim", s.ctx_tokens)
            self.mark_dirty()
            return s.request
        return None

    # ----------------------------------------------------- seq bookkeeping
    def _enter_decode(self, s: SimSeq, v_entry: float) -> None:
        s.decoding = True
        s.v0 = v_entry
        s.gen_base = s.gen_f - v_entry
        s.ctx_base = s.ctx_tokens - v_entry
        self._kv_dec_base += s.ctx_base
        self._n_dec += 1
        vfin = float(s.request.output_len) - s.gen_base
        heapq.heappush(self._decode_heap, (vfin, s.request.req_id))

    def _materialize(self, s: SimSeq) -> None:
        """Sync a decoding seq's lazy counters from the virtual clock."""
        if self.event_mode and s.decoding:
            s.gen_f = min(s.gen_base + self.vclock,
                          float(s.request.output_len))
            s.ctx_tokens = s.ctx_base + self.vclock
            s.request.tokens_generated = int(s.gen_f)

    def _remove_seq(self, s: SimSeq) -> None:
        r = s.request
        del self.running[r.req_id]
        if self._cluster is not None:
            self._cluster.total_running -= 1
        c = self._slo_counts.get(r.slo.itl, 0) - 1
        if c > 0:
            self._slo_counts[r.slo.itl] = c
        else:
            self._slo_counts.pop(r.slo.itl, None)
        if r.is_interactive:
            self._n_interactive -= 1
        if self.event_mode:
            if s.decoding:
                s.decoding = False
                self._kv_dec_base -= s.ctx_base
                self._n_dec -= 1
            else:
                self._kv_prefill -= s.ctx_tokens
        else:
            self._kv_tokens -= s.ctx_tokens
        if not self.running:       # reset float drift at emptiness
            self._kv_tokens = 0.0
            self._kv_prefill = 0.0
            self._kv_dec_base = 0.0
            self._n_interactive = 0

    # --------------------------------------------------- event-driven core
    def mark_dirty(self) -> None:
        """Flag this instance for completion-event rescheduling (and pending
        finish collection) at the end of the current event batch."""
        if self._cluster is not None:
            self._cluster.dirty.add(self)

    def drain_finished(self) -> List[Request]:
        out = self._pending_finished
        self._pending_finished = []
        return out

    def advance(self, now: float) -> None:
        """Fluid catch-up to ``now`` under the current (fixed) composition —
        the event-core counterpart of :meth:`step`.

        All decoding seqs share one token rate, so the whole pool advances
        by moving ``vclock``; prefill→decode transitions and finishes pop
        off heaps at their exact crossing times (interpolated, so a
        completion estimate firing slightly late is harmless).
        """
        dt = now - self.last_advance
        t0 = self.last_advance
        self.last_advance = now
        if dt <= 0 or not self.active or not self.running:
            return
        self.mark_dirty()
        itl = self.perf.itl(len(self.running), max(self.mean_ctx(), 1.0)) \
            * self.slow_factor
        q = self._cluster.quantize if self._cluster else 0.0
        if q > 0:
            # fixed-tick parity: int(q/itl) tokens per tick, no carry
            per_tick = int(q / itl + 1e-9)
            itl = q / per_tick if per_tick > 0 else _STALLED_ITL
        toks = 0.0
        v_old = self.vclock

        # 1. prefill completions due within (t0, now]: seq starts decoding
        #    mid-interval with vclock credit from its entry point
        ph = self._prefill_heap
        entry_debt = 0.0
        while ph and ph[0][0] <= now + 1e-12:
            t_done, rid = heapq.heappop(ph)
            s = self.running.get(rid)
            if s is None or s.decoding or s.prefill_done_t != t_done:
                continue                     # stale (departed/re-admitted)
            s.prefill_left = 0.0
            self._kv_prefill -= s.ctx_tokens
            r = s.request
            if r.first_token_time is None:
                r.first_token_time = t_done
                s.gen_f += 1.0
                s.ctx_tokens += 1.0
                toks += 1.0
            v_entry = v_old + max(t_done - t0, 0.0) / itl
            entry_debt += v_entry - v_old
            self._enter_decode(s, v_entry)

        # 2. the decode pool advances as one fluid
        if self._n_dec:
            self.vclock = v_old + dt / itl
            toks += self._n_dec * (dt / itl) - entry_debt

            # 3. finishes: pop virtual finish times the clock crossed
            dh = self._decode_heap
            while dh and dh[0][0] <= self.vclock + 1e-9:
                vfin, rid = heapq.heappop(dh)
                s = self.running.get(rid)
                if s is None or not s.decoding or abs(
                        (s.request.output_len - s.gen_base) - vfin) > 1e-6:
                    continue                 # stale entry
                over_v = self.vclock - vfin  # tokens past the true finish
                toks -= over_v
                s.ctx_tokens = s.ctx_base + vfin
                s.gen_f = float(s.request.output_len)
                r = s.request
                self._remove_seq(s)
                r.tokens_generated = r.output_len
                r.state = RequestState.FINISHED
                ft = now - over_v * itl
                if r.first_token_time is None:   # sub-itl output edge case
                    r.first_token_time = ft
                r.finish_time = max(ft, r.first_token_time)
                # one lifetime-mean ITL sample (the event core records the
                # mean the SLO check reads, not per-tick samples)
                span = r.finish_time - r.first_token_time
                r.itl_samples.append(
                    span / max(float(r.output_len) - 1.0, 1.0))
                self._pending_finished.append(r)

        if toks and self._cluster is not None:
            self._cluster.tok_accum += toks

    def next_event_in(self) -> float:
        """Seconds until this instance's next intrinsic event (a prefill
        completing or the earliest finish) under the current composition;
        inf when idle. Floored at the cluster's completion grain so nearby
        finishes coalesce into one event (and a late-drifting estimate
        re-fires geometrically rather than spinning)."""
        if not self.active or not self.running:
            return float("inf")
        best = float("inf")
        ph = self._prefill_heap
        while ph:
            t_done, rid = ph[0]
            s = self.running.get(rid)
            if s is None or s.decoding or s.prefill_done_t != t_done:
                heapq.heappop(ph)
                continue
            best = t_done - self.last_advance
            break
        dh = self._decode_heap
        while dh:
            vfin, rid = dh[0]
            s = self.running.get(rid)
            if s is None or not s.decoding or abs(
                    (s.request.output_len - s.gen_base) - vfin) > 1e-6:
                heapq.heappop(dh)
                continue
            itl = self.perf.itl(len(self.running), max(self.mean_ctx(), 1.0)) \
                * self.slow_factor
            q = self._cluster.quantize if self._cluster else 0.0
            if q > 0:
                per_tick = int(q / itl + 1e-9)
                itl = q / per_tick if per_tick > 0 else _STALLED_ITL
            eta = (vfin - self.vclock) * itl
            if eta < 1e11:               # stalled seqs schedule nothing
                best = min(best, eta)
            break
        grain = self._cluster.completion_grain if self._cluster else 1e-3
        return max(best, grain)

    # ------------------------------------------------------------ stepping
    def step(self, dt: float, now: float) -> Tuple[List[Request], int]:
        """Advance the instance by dt of simulated wall time (fixed-tick
        reference; walks every running sequence)."""
        if not self.active or not self.running:
            return [], 0
        b = self.n_running
        itl = self.perf.itl(b, max(self.mean_ctx(), 1.0)) * self.slow_factor
        finished: List[Request] = []
        tokens_out = 0
        for s in list(self.running.values()):
            budget = dt
            if s.prefill_left > 0:
                used = min(budget, s.prefill_left)
                s.prefill_left -= used
                budget -= used
                if s.prefill_left > 0:
                    continue
                if s.request.first_token_time is None:
                    s.request.first_token_time = now + used
                    s.request.tokens_generated += 1
                    s.ctx_tokens += 1
                    self._kv_tokens += 1
                    tokens_out += 1
            ntok = int(budget / itl)
            ntok = min(ntok, s.request.output_len - s.request.tokens_generated)
            if ntok > 0:
                s.request.tokens_generated += ntok
                s.ctx_tokens += ntok
                self._kv_tokens += ntok
                tokens_out += ntok
                s.request.itl_samples.append(itl)
                if s.request.first_token_time is None:
                    s.request.first_token_time = now + itl
            if s.done:
                s.request.state = RequestState.FINISHED
                s.request.finish_time = now + dt
                self._remove_seq(s)
                finished.append(s.request)
        return finished, tokens_out

    def update_local_autoscaler(self) -> None:
        if self.local is None or not self.running:
            return
        m = LocalMetrics(observed_itl=self.current_itl(),
                         throughput=self.current_throughput(),
                         itl_slo=self.min_itl_slo(),
                         n_active=self.n_running,
                         batch_size=self.local.max_batch_size)
        self.local.update(m)


class SimCluster:
    def __init__(self, perf_factory, *, max_chips: int = 400,
                 load_time: Optional[float] = None):
        """perf_factory: model_name -> PerfModel (fresh or shared)."""
        self.perf_factory = perf_factory
        self.max_chips = max_chips
        self.load_time = load_time
        self.instances: List[SimInstance] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.failures = 0            # crash-injected removals (not scaling)
        self.degradations = 0        # slow-node injections (instance kept)
        self.chip_seconds = 0.0
        self.peak_chips = 0
        self._used_chips = 0         # maintained by provision/retire
        self._pools: Dict[InstanceType, List[SimInstance]] = \
            {t: [] for t in InstanceType}
        # (model, itype) -> live pool; the multi-model routing/control path
        # (one Algorithm-2 loop per model) reads these instead of filtering
        self._model_pools: Dict[Tuple[str, InstanceType],
                                List[SimInstance]] = {}
        self.total_running = 0       # running seqs cluster-wide (O(1) idle check)
        # --- event-core state (unused on the fixed-tick path) ---
        self.event_mode = False
        self.now = 0.0               # sim time chip accounting is valid at
        self.dirty: set = set()      # instances needing event rescheduling
        self.tok_accum = 0.0         # tokens generated since last drain
        # completion estimates are coalesced to this grain: finishes inside
        # one grain are processed together (their finish times are still
        # interpolated exactly) — the same quantization a dt=0.25 fixed
        # tick imposes, at a fraction of the events
        self.completion_grain = 0.25
        # sparse fixed-tick mode (simulate_events(quantize=dt)): decode
        # rates emulate the tick loop's integer truncation (int(dt/itl)
        # tokens per tick, no carry) so both engines share dynamics
        self.quantize = 0.0

    # ------------------------------------------------------------ queries
    def by_type(self, itype: InstanceType) -> List[SimInstance]:
        """Live (maintained) pool list — treat as read-only; copy before
        retiring members while iterating."""
        return self._pools[itype]

    def by_model(self, model: str, itype: InstanceType) -> List[SimInstance]:
        """Live (model, type) pool — same read-only contract as by_type."""
        return self._model_pools.setdefault((model, itype), [])

    def instances_of(self, model: str) -> List[SimInstance]:
        """All live instances serving ``model`` (every type)."""
        return [i for t in InstanceType
                for i in self._model_pools.get((model, t), ())]

    def models_present(self) -> List[str]:
        """Distinct models with at least one live instance."""
        seen: Dict[str, None] = {}
        for inst in self.instances:
            seen.setdefault(inst.model)
        return list(seen)

    def active_instances(self) -> List[SimInstance]:
        return [i for i in self.instances if i.active]

    def used_chips(self) -> int:
        return self._used_chips

    @property
    def hysteresis(self) -> float:
        """Total scaling actions / scale-ups (paper §2.3 definition)."""
        if self.scale_ups == 0:
            return 0.0
        return (self.scale_ups + self.scale_downs) / self.scale_ups

    # ------------------------------------------------------------ scaling
    def provision(self, model: str, itype: InstanceType, now: float,
                  **inst_kw) -> Optional[SimInstance]:
        perf = self.perf_factory(model)
        if self._used_chips + perf.chips > self.max_chips:
            return None
        inst = SimInstance(perf, itype, now, load_time=self.load_time,
                           **inst_kw)
        inst.event_mode = self.event_mode
        inst._cluster = self
        self.instances.append(inst)
        self._pools[itype].append(inst)
        self._model_pools.setdefault((model, itype), []).append(inst)
        self.scale_ups += 1
        self._used_chips += perf.chips
        self.peak_chips = max(self.peak_chips, self._used_chips)
        return inst

    def retire(self, inst: SimInstance) -> List[Request]:
        """Remove an instance; returns displaced requests for requeueing."""
        displaced = self._remove_instance(inst)
        self.scale_downs += 1
        return displaced

    def degrade_instance(self, inst: SimInstance, factor: float,
                         now: float) -> None:
        """Slow-node injection: inflate the victim's ITL by ``factor``
        without removing it. Fluid state is settled first so only future
        decode progress runs slow; in-flight work stays put (the partial
        failure mode crashes cannot model)."""
        if self.event_mode:
            inst.advance(now)        # settle at the healthy rate first
        inst.slow_factor = factor
        inst.mark_dirty()            # completion estimates must re-fire
        self.degradations += 1

    def recover_instance(self, inst: SimInstance, now: float) -> None:
        if self.event_mode:
            inst.advance(now)        # settle at the degraded rate first
        inst.slow_factor = 1.0
        inst.mark_dirty()

    def fail_instance(self, inst: SimInstance) -> List[Request]:
        """Crash an instance (failure injection): like ``retire`` but the
        removal is counted as a failure, not an autoscaling action, so the
        hysteresis metric stays a controller property. In-flight requests
        lose their on-device KV (``saved_kv=None`` — they must re-prefill
        elsewhere) and are returned for requeueing."""
        displaced = self._remove_instance(inst)
        self.failures += 1
        return displaced

    def _remove_instance(self, inst: SimInstance) -> List[Request]:
        if self.event_mode:
            inst.advance(self.now)   # settle fluid state first
            self.dirty.add(inst)     # pending finishes still get drained
        displaced = []
        for s in inst.running.values():
            inst._materialize(s)
            r = s.request
            r.state = RequestState.PREEMPTED
            r.saved_kv = None   # instance gone; must re-prefill elsewhere
            displaced.append(r)
        self.total_running -= len(inst.running)
        inst.running.clear()
        inst._batch_lifo.clear()
        inst._kv_tokens = 0.0
        inst._kv_prefill = 0.0
        inst._kv_dec_base = 0.0
        inst._n_dec = 0
        inst._n_interactive = 0
        inst._slo_counts.clear()
        inst._prefill_heap.clear()
        inst._decode_heap.clear()
        inst.state = InstanceState.RETIRED
        inst.active = False
        self.instances.remove(inst)
        self._pools[inst.itype].remove(inst)
        self._model_pools[(inst.model, inst.itype)].remove(inst)
        self._used_chips -= inst.perf.chips
        return displaced

    def tick_accounting(self, dt: float) -> None:
        self.chip_seconds += self.used_chips() * dt

    # --------------------------------------------------- event-driven core
    def advance_time(self, t: float) -> None:
        """Accrue chip-seconds over [now, t] (composition is constant
        between event batches) and move the cluster clock."""
        if t > self.now:
            self.chip_seconds += self._used_chips * (t - self.now)
            self.now = t

    def drain_dirty(self) -> List[SimInstance]:
        # deterministic order: set iteration is address-dependent, and this
        # order fixes event tie-breaks, backfill order, and the sequence
        # completions reach the estimator — same seed must mean same run
        out = sorted(self.dirty, key=lambda i: i.id)
        self.dirty.clear()
        return out

    def take_tokens(self) -> float:
        out = self.tok_accum
        self.tok_accum = 0.0
        return out
