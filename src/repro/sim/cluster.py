"""Simulated serving cluster: instances driven by the analytic perf model.

The control plane (autoscalers, routing, queues, request groups) is the
production ``repro.core`` / ``repro.serving`` code; only the data plane —
how long a decode step takes — is simulated, using ``PerfModel``. Instance
bring-up takes ``model_load_time()`` (the 15–60 s that motivates Chiron's
over-provisioning), and every provision/retire action is counted for the
hysteresis metric.

Two data-plane drivers share the same instance state:

- ``step(dt, now)``: the fixed-tick reference — every running sequence is
  walked each tick.
- ``advance(now)``: the event-core fluid model. Continuous batching gives
  every decoding sequence the same token rate, so decode progress is a
  single per-instance *virtual clock* (tokens emitted per sequence);
  sequence finish order is a heap over virtual finish times and KV/context
  aggregates are closed forms of the clock. Advancing an instance is O(1)
  plus O(log B) per completed/transitioned sequence — independent of
  batch size, which is what keeps million-request traces tractable.

Control-plane queries (``can_admit``, ``mean_ctx``, ``runs_interactive``,
``min_itl_slo``…) are all O(1) via maintained aggregates; the routing hot
path never scans a batch.

The vectorized instance plane (:class:`InstancePlane`) mirrors every
instance's fluid scalars — virtual clock, catch-up time, running/decoding
counts, KV aggregates, slow factor — plus its cached ``PerfModel`` ITL
coefficients into struct-of-arrays NumPy columns, kept in sync
incrementally by the mutation sites. The control-tick catch-up
(``SimCluster.catch_up``) then advances every instance without a pending
intrinsic event in **one array pass** (identical arithmetic to the scalar
``advance``, so decisions are bit-for-bit equivalent) and falls back to
the per-object path only for instances whose prefill/finish heap actually
crosses the tick. Below ``SimCluster.vec_min`` live instances the scalar
loop wins on NumPy fixed costs and is used instead — the plane is the
production-scale path, not a small-fleet tax.

Outcome recording is columnar too: when ``SimCluster.ledger`` is set (the
event engines install a :class:`repro.sim.ledger.RequestLedger`), every
``Request`` attribute write in the hot path (first token, finish, state,
tokens) lands in the ledger at ``Request.row`` as well, so run metrics
reduce over arrays instead of a million objects.
"""
from __future__ import annotations

import enum
import heapq
import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.local_autoscaler import LocalAutoscaler
from repro.core.backpressure import LocalMetrics
from repro.obs.recorder import (SPAN_ADMIT as _SPAN_ADMIT,
                                SPAN_PREEMPT as _SPAN_PREEMPT)
from repro.serving.request import Request, RequestState, RequestType
from repro.sim import ledger as _ledger
from repro.sim.perf_model import STEP_OVERHEAD, PerfModel

_inst_counter = itertools.count()

_INF = float("inf")
_heappush = heapq.heappush           # hot-path aliases: skip the module
_heappop = heapq.heappop             # attribute load per heap operation


def _by_id(inst) -> int:
    return inst.id

# decode rate used when the quantized tick emulation truncates to zero
# tokens per tick (itl > dt: the tick loop makes no progress either)
_STALLED_ITL = 1e12

# health-EWMA ratio (observed ITL / healthy-model ITL) above which an
# instance is suspected slow and routed around (slow-node degradation)
SLOW_SUSPECT_RATIO = 1.8

_HASH_SCALE = 1.0 / 4294967296.0     # uint32 hash -> [0, 1)


@dataclass(frozen=True)
class DetectorConfig:
    """Noisy slow-node detector knobs (``SimCluster.detector``).

    The detector sees one *observed* ITL-ratio sample per instance per
    control tick — the ground-truth ``slow_factor`` corrupted by
    multiplicative measurement noise and optional sample-level
    false-positive / false-negative flips — and tests the window median
    through the health EWMA. Detection therefore takes a few ticks and
    can mis-fire, like a real control plane; the fluid-exact ratio is
    never read by the detection path.

    All randomness is a counter-based integer hash of (instance id,
    sample index, seed): deterministic, replayable, and independent of
    every seeded RNG stream in the engines, so detector noise can never
    perturb victim draws or arrival sequences.
    """
    window: int = 5        # median window (samples = control ticks)
    alpha: float = 0.5     # health-EWMA gain on the window median
    noise: float = 0.1     # multiplicative measurement noise (+-10%)
    fp_rate: float = 0.0   # P(healthy sample reads as slow)
    fn_rate: float = 0.0   # P(slow sample reads as healthy)
    seed: int = 0          # decorrelates the sample hash stream


_DEFAULT_DETECTOR = DetectorConfig()

# Mirror registries: ``SimInstance`` fluid scalar -> ``InstancePlane``
# column kept in sync at every mutation site (directly, via
# ``_sync_plane()``, or via ``plane.alloc``/``plane.free``). The static
# mirror auditor (``repro.analysis``, rule MIR102) checks assignments
# against these mappings and the runtime shadow verifier asserts the
# columns agree with the objects — extend them when mirroring a new
# scalar into the plane.
PLANE_MIRRORS: Dict[str, str] = {
    "active": "active",
    "vclock": "vclock",
    "last_advance": "last_advance",
    "slow_factor": "slow",
    "_n_dec": "n_dec",
    "_kv_prefill": "kv_prefill",
    "_kv_dec_base": "kv_dec_base",
}
# Container mirror: mutating the ``running`` dict (admission, removal,
# clear) must land in the ``n_running`` column the same way.
PLANE_CONTAINER_MIRRORS: Dict[str, str] = {"running": "n_running"}


class InstanceType(enum.Enum):
    INTERACTIVE = "interactive"
    MIXED = "mixed"
    BATCH = "batch"


class InstanceState(enum.Enum):
    LOADING = "loading"
    ACTIVE = "active"
    RETIRED = "retired"


class SimSeq:
    """One running sequence (slotted: allocated once per admission)."""

    __slots__ = ("request", "ctx_tokens", "prefill_left", "gen_f",
                 "decoding", "prefill_done_t", "v0", "gen_base", "ctx_base")

    def __init__(self, request: Request, ctx_tokens: float,
                 prefill_left: float, gen_f: float = 0.0):
        self.request = request
        self.ctx_tokens = ctx_tokens     # prompt + generated (KV footprint)
        self.prefill_left = prefill_left  # seconds of prefill work left
        self.gen_f = gen_f               # fractional tokens generated
        # --- event-core fluid state ---
        self.decoding = False
        self.prefill_done_t = 0.0        # absolute sim time prefill done
        self.v0 = 0.0                    # instance vclock at decode entry
        self.gen_base = 0.0              # gen_f - vclock while decoding
        self.ctx_base = 0.0              # ctx   - vclock while decoding

    @property
    def done(self) -> bool:
        return self.request.tokens_generated >= self.request.output_len


_new_seq = SimSeq.__new__               # hot-path constructor bypass


class InstancePlane:
    """Struct-of-arrays mirror of per-instance fluid state + cached ITL
    coefficients (see module docstring). Slots are allocated at provision
    and freed at retirement; mutation sites keep the columns in sync via
    ``SimInstance._sync_plane`` so ``catch_up`` can advance the whole
    fleet in one vectorized pass.
    """

    def __init__(self, cap: int = 64):
        self.cap = cap
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self.owner: List[Optional["SimInstance"]] = [None] * cap
        z = np.zeros
        # dynamic fluid state
        self.active = z(cap, dtype=bool)
        self.n_running = z(cap, dtype=np.int64)
        self.n_dec = z(cap, dtype=np.int64)
        self.kv_prefill = z(cap)
        self.kv_dec_base = z(cap)
        self.vclock = z(cap)
        self.last_advance = z(cap)
        self.slow = np.ones(cap)
        # earliest (possibly stale-conservative) intrinsic events
        self.next_prefill = np.full(cap, _INF)
        self.next_vfin = np.full(cap, _INF)
        # cached PerfModel ITL coefficients (static per slot)
        self.mem_base = z(cap)
        self.mem_kv = z(cap)
        self.comp_seq = z(cap)
        self.coll = z(cap)
        self.kv_cap = np.full(cap, _INF)
        self.prefix = z(cap)
        self.spec_on = z(cap, dtype=bool)
        self.spec_over = z(cap)
        self.spec_speed = np.ones(cap)

    def _grow(self) -> None:
        old = self.cap
        self.cap = cap = old * 2
        self._free.extend(range(cap - 1, old - 1, -1))
        self.owner.extend([None] * old)
        for name in ("active", "n_running", "n_dec", "kv_prefill",
                     "kv_dec_base", "vclock", "last_advance", "slow",
                     "next_prefill", "next_vfin", "mem_base", "mem_kv",
                     "comp_seq", "coll", "kv_cap", "prefix", "spec_on",
                     "spec_over", "spec_speed"):
            a = getattr(self, name)
            pad = np.empty(old, dtype=a.dtype)
            if name in ("next_prefill", "next_vfin", "kv_cap"):
                pad.fill(np.inf)
            elif name in ("slow", "spec_speed"):
                pad.fill(1)
            else:
                pad.fill(0)
            setattr(self, name, np.concatenate([a, pad]))

    def alloc(self, inst: "SimInstance") -> int:
        if not self._free:
            self._grow()
        s = self._free.pop()
        self.owner[s] = inst
        self.active[s] = inst.active
        self.n_running[s] = 0
        self.n_dec[s] = 0
        self.kv_prefill[s] = 0.0
        self.kv_dec_base[s] = 0.0
        self.vclock[s] = 0.0
        self.last_advance[s] = inst.last_advance
        self.slow[s] = inst.slow_factor
        self.next_prefill[s] = _INF
        self.next_vfin[s] = _INF
        self.mem_base[s] = inst._c_mem_base
        self.mem_kv[s] = inst._c_mem_kv
        self.comp_seq[s] = inst._c_comp
        self.coll[s] = inst._c_coll
        self.kv_cap[s] = inst._c_cap
        self.prefix[s] = inst._c_prefix
        self.spec_on[s] = inst._c_spec
        self.spec_over[s] = inst._c_spec_over
        self.spec_speed[s] = inst._c_spec_speed
        return s

    def free(self, slot: int) -> None:
        self.owner[slot] = None
        self.active[slot] = False
        self.n_running[slot] = 0
        self.n_dec[slot] = 0
        self.next_prefill[slot] = _INF
        self.next_vfin[slot] = _INF
        self._free.append(slot)

    def catch_up(self, t: float, cluster: "SimCluster",
                 batch_seq: int) -> None:
        """Vectorized fluid catch-up of every running instance to ``t``.

        One array pass computes each instance's frozen-composition ITL
        with the exact operation order of ``PerfModel.itl`` (bit-for-bit
        the scalar result), detects which instances have an intrinsic
        event (prefill completion / decode finish) crossing the interval,
        advances the rest in bulk, and caches their next-completion ETA
        for the sweep. Crossing instances fall back to the scalar
        ``advance`` (heap pops, interpolation).
        """
        nr = self.n_running
        m = self.active & (nr > 0) & (self.last_advance < t)
        slots = np.nonzero(m)[0]
        if slots.size == 0:
            return
        b = nr[slots]
        dt = t - self.last_advance[slots]
        nd = self.n_dec[slots]
        vc = self.vclock[slots]
        kv = self.kv_prefill[slots] + self.kv_dec_base[slots] + nd * vc
        ctx = np.maximum(kv / b, 1.0)
        itl = self._itl(slots, b, ctx)
        ratio = dt / itl
        vnew = np.where(nd > 0, vc + ratio, vc)
        crossing = (self.next_prefill[slots] <= t + 1e-12) \
            | ((nd > 0) & (self.next_vfin[slots] <= vnew + 1e-9))
        fast = ~crossing
        fs = slots[fast]
        owner = self.owner
        if fs.size:
            self.last_advance[fs] = t
            self.vclock[fs] = vnew[fast]
            ndf = nd[fast]
            dec = ndf > 0
            if dec.any():
                cluster.tok_accum += float(np.sum(ndf[dec]
                                                  * ratio[fast][dec]))
            # next-completion ETA under the *new* composition-frozen ITL
            # (exactly what next_event_in would recompute at the sweep)
            kv2 = self.kv_prefill[fs] + self.kv_dec_base[fs] \
                + ndf * self.vclock[fs]
            ctx2 = np.maximum(kv2 / nr[fs], 1.0)
            itl2 = self._itl(fs, nr[fs], ctx2)
            eta = np.minimum(self.next_prefill[fs] - t,
                             (self.next_vfin[fs] - self.vclock[fs]) * itl2)
            np.maximum(eta, cluster.completion_grain, out=eta)
            dirty = cluster.dirty
            vcol = self.vclock
            for s, e in zip(fs.tolist(), eta.tolist()):
                inst = owner[s]
                inst.vclock = vcol[s]
                inst.last_advance = t
                inst._eta_val = e
                inst._eta_stamp = batch_seq
                dirty.add(inst)
        for s in slots[crossing].tolist():
            owner[s].advance(t)

    def _itl(self, slots: np.ndarray, b: np.ndarray,
             ctx: np.ndarray) -> np.ndarray:
        """Vector twin of ``SimInstance._itl_now`` — identical op order."""
        mem = self.mem_base[slots] + b * ctx * self.mem_kv[slots]
        comp = b * self.comp_seq[slots]
        t = np.maximum(mem, comp) + self.coll[slots] + STEP_OVERHEAD
        sp = self.spec_on[slots]
        if sp.any():
            t = np.where(sp, t * (1 + self.spec_over[slots] * np.sqrt(b))
                         / self.spec_speed[slots], t)
        cap = self.kv_cap[slots]
        demand = b * (ctx + self.prefix[slots])
        with np.errstate(invalid="ignore"):
            over = demand / cap - 1.0
            pre = demand > cap
        if pre.any():
            t = np.where(pre, t * (1.0 + 4.0 * over + 8.0 * over * over), t)
        return t * self.slow[slots]


class SimInstance:
    def __init__(self, perf: PerfModel, itype: InstanceType, now: float, *,
                 local_autoscaler: Optional[LocalAutoscaler] = None,
                 static_batch: Optional[int] = None,
                 load_time: Optional[float] = None):
        self.id = next(_inst_counter)
        self.perf = perf
        self.model = perf.model_name
        self.itype = itype
        self.state = InstanceState.LOADING
        self.active = False          # mirrors state (hot-path flag)
        self.ready_time = now + (load_time if load_time is not None
                                 else perf.model_load_time())
        self.local = local_autoscaler
        self.static_batch = static_batch
        self.running: Dict[int, SimSeq] = {}    # req_id -> seq (ins. order)
        self.created_at = now
        # slow-node degradation: ground-truth ITL inflation (set by the
        # injection event) and the *observed* health signal the control
        # plane detects it with — an EWMA over the median of a ring
        # buffer of noisy observed-ITL-ratio samples pushed at control
        # ticks (see DetectorConfig). Routing avoids suspected instances.
        self.slow_factor = 1.0
        self.health_ewma = 1.0
        self._obs_buf: List[float] = []   # noisy ITL-ratio sample window
        self._obs_n = 0                   # samples drawn (hash counter)
        # O(1) aggregates over ``running`` (the routing/control hot path
        # queries these every pass; scanning the batch would be O(B))
        self._kv_tokens = 0.0        # fixed-tick: sum of ctx_tokens
        self._n_interactive = 0
        self._slo_counts: Dict[float, int] = {}
        self._batch_lifo: List[int] = []   # batch admits (lazy-stale rids)
        # --- event-core state (unused on the fixed-tick path) ---
        self.event_mode = False
        self.last_advance = now      # sim time the fluid state is valid at
        self.vclock = 0.0            # fluid tokens emitted per decoding seq
        self._n_dec = 0              # decoding seqs
        self._kv_prefill = 0.0       # sum ctx over prefilling seqs
        self._kv_dec_base = 0.0      # sum ctx_base over decoding seqs
        self._prefill_heap: List[Tuple[float, int]] = []   # (t_done, rid)
        self._decode_heap: List[Tuple[float, int]] = []    # (vfin, rid)
        self._epoch = 0              # invalidates scheduled events
        self._pending_finished: List[Request] = []
        self._cluster = None         # backref set by SimCluster.provision
        self.slot = -1               # InstancePlane slot (set by provision)
        self._plane: Optional[InstancePlane] = None
        self._eta_val = 0.0          # cached post-advance completion ETA
        self._eta_stamp = -1         # event batch it is valid for
        # inlined PerfModel ITL coefficients — ``_itl_now`` is the scalar
        # hot-path twin of ``PerfModel.itl`` (identical arithmetic; the
        # method-call + attribute-chase overhead is what it removes)
        self._c_mem_base = perf._mem_t_base
        self._c_mem_kv = perf._mem_t_per_kvtok
        self._c_comp = perf._comp_t_per_seq
        self._c_coll = perf._coll_t
        self._c_cap = perf._kv_cap
        self._c_wall = 1.5 * perf._kv_cap if math.isfinite(perf._kv_cap) \
            else _INF
        self._c_prefix = float(perf.prefix_hit_tokens) \
            if perf.prefix_caching else 0.0
        self._c_spec = perf.speculative_decoding
        self._c_spec_over = perf.spec_draft_overhead
        self._c_spec_speed = perf.spec_accept_speedup
        # prefill_time twin: (2 * n_active) * eff_len / flops + overhead
        # with the same grouping as PerfModel.prefill_time
        self._c_2na = 2 * perf.n_active
        self._c_flops = perf._flops_per_s
        self._c_pc = perf.prefix_caching
        self._c_hit = perf.prefix_hit_tokens
        # packed copy for the hottest callers (advance/admit): one
        # attribute load + tuple unpack instead of nine attribute loads
        self._c_itl = (self._c_mem_base, self._c_mem_kv, self._c_comp,
                       self._c_coll, self._c_spec, self._c_spec_over,
                       self._c_spec_speed, self._c_cap, self._c_prefix)

    # ------------------------------------------------------------ state
    def activate_if_ready(self, now: float) -> None:
        # The lost-READY fix lives at the call sites: max(t, inst.ready_time).
        # repro-lint: ok(DET205, callers clamp now to ready_time)
        if self.state == InstanceState.LOADING and now >= self.ready_time:
            self.state = InstanceState.ACTIVE
            self.active = True
            c = self._cluster
            if c is not None:
                c.n_loading -= 1
                c._active[self.id] = self
                c.route_version += 1
            if self.slot >= 0:
                self._plane.active[self.slot] = True

    @property
    def max_batch_size(self) -> int:
        if self.local is not None:
            return self.local.max_batch_size
        return self.static_batch or 64

    @property
    def n_running(self) -> int:
        return len(self.running)

    def n_running_batch(self) -> int:
        return len(self.running) - self._n_interactive

    def mean_ctx(self) -> float:
        n = len(self.running)
        return self.kv_tokens() / n if n else 0.0

    def kv_tokens(self) -> float:
        if self.event_mode:
            return self._kv_prefill + self._kv_dec_base \
                + self._n_dec * self.vclock
        return self._kv_tokens

    def kv_utilization(self) -> float:
        cap = self._c_cap
        if not math.isfinite(cap):
            return self.n_running / max(self.max_batch_size, 1)
        return self.kv_tokens() / cap

    def slot_utilization(self) -> float:
        return self.n_running / max(self.max_batch_size, 1)

    def _itl_now(self, b: int, ctx: float) -> float:
        """Scalar ITL at batch ``b`` / mean context ``ctx`` — inlined
        ``PerfModel.itl`` (identical operation order, hence identical
        floats) times the degradation ``slow_factor``."""
        mem_t = self._c_mem_base + b * ctx * self._c_mem_kv
        comp_t = b * self._c_comp
        t = (mem_t if mem_t >= comp_t else comp_t) \
            + self._c_coll + STEP_OVERHEAD
        if self._c_spec:
            t = t * (1 + self._c_spec_over * math.sqrt(b)) \
                / self._c_spec_speed
        cap = self._c_cap
        if cap != _INF:
            demand = b * (ctx + self._c_prefix)
            if demand > cap:
                over = demand / cap - 1.0
                t *= 1.0 + 4.0 * over + 8.0 * over * over
        return t * self.slow_factor

    def current_itl(self) -> float:
        if not self.running:
            return 0.0
        return self._itl_now(len(self.running), max(self.mean_ctx(), 1.0))

    def current_throughput(self) -> float:
        if not self.running:
            return 0.0
        return self.n_running / self.current_itl()

    def spare_throughput(self) -> float:
        """Tokens/s of unused slot capacity (used for BBP multiplexing)."""
        spare = self.max_batch_size - self.n_running
        if spare <= 0:
            return 0.0
        itl = self._itl_now(self.max_batch_size, max(self.mean_ctx(), 512.0))
        return spare / itl

    def update_health(self, alpha: Optional[float] = None) -> None:
        """Push one *noisy* observed-ITL-ratio sample and re-test health
        (the detection signal for slow-node degradation; called once per
        control tick). The sample is the ground-truth ``slow_factor``
        corrupted by deterministic hash noise plus optional FP/FN flips
        (``DetectorConfig``); the detector EWMAs the window **median**,
        so detection lags injection by a few ticks and isolated flipped
        samples are suppressed — the fluid-exact ratio is no longer read
        by the detection path. Idle instances update too (a health
        probe): routing refuses suspected instances, so without this a
        drained victim could never clear its flag after recovery and
        would strand healthy capacity forever.

        A flip of the *suspected* flag bumps the cluster route version:
        routing reads health only through that flag, and the positive
        scan memo (``_scan_admit`` reuse across arrivals) relies on the
        version capturing every routing-visible change."""
        if not self.active:
            return
        c = self._cluster
        det = c.detector if c is not None else _DEFAULT_DETECTOR
        n = self._obs_n = self._obs_n + 1
        # counter-based integer hash (Knuth multiplicative) — one draw
        # per (instance, sample index, seed); no RNG object, so sampling
        # can never perturb the engines' seeded victim/arrival streams
        h = ((self.id + 1) * 2654435761 + n * 40503
             + (det.seed + 1) * 69069) & 0xFFFFFFFF
        obs = self.slow_factor \
            * (1.0 + det.noise * (2.0 * h * _HASH_SCALE - 1.0))
        if det.fp_rate > 0.0 or det.fn_rate > 0.0:
            h2 = (h * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF
            if self.slow_factor == 1.0:
                if h2 * _HASH_SCALE < det.fp_rate:
                    obs = SLOW_SUSPECT_RATIO * 1.25  # spurious slow read
            elif h2 * _HASH_SCALE < det.fn_rate:
                obs = 1.0                            # missed slow read
        buf = self._obs_buf
        if len(buf) < det.window:
            buf.append(obs)
        else:
            buf[n % det.window] = obs
        stat = sorted(buf)[len(buf) // 2]            # window median
        a = det.alpha if alpha is None else alpha
        was = self.health_ewma > SLOW_SUSPECT_RATIO
        self.health_ewma += a * (stat - self.health_ewma)
        if (self.health_ewma > SLOW_SUSPECT_RATIO) != was \
                and c is not None:
            c.route_version += 1

    @property
    def suspected_slow(self) -> bool:
        return self.health_ewma > SLOW_SUSPECT_RATIO

    def runs_interactive(self) -> bool:
        return self._n_interactive > 0

    def min_itl_slo(self) -> float:
        if not self._slo_counts:
            return _INF
        return min(self._slo_counts)

    # ------------------------------------------------------------ intake
    def can_admit(self, req: Request) -> bool:
        if not self.active or len(self.running) >= self.max_batch_size:
            return False
        if req.model != self.model:
            return False            # never serve a wrong-model request
        # hard admission wall well past the soft preemption inflection
        # (wall = 1.5 * kv capacity; inf when KV is unbounded)
        if self.kv_tokens() + req.prompt_len > self._c_wall:
            return False
        return True

    def admit(self, req: Request, now: float) -> None:
        if self.event_mode and self.last_advance < now:
            self.advance(now, False)  # settle old composition first
        restored = req.saved_kv is not None
        ctx = float(req.prompt_len + req.tokens_generated)
        if restored:
            prefill = 0.0
            req.saved_kv = None
        else:
            # inlined PerfModel.prefill_time (identical grouping/floats)
            eff = req.prompt_len
            if self._c_pc:
                eff = eff - self._c_hit
                if eff < 16:
                    eff = 16
            prefill = self._c_2na * eff / self._c_flops + STEP_OVERHEAD
        req.state = RequestState.RUNNING
        c = self._cluster
        led = c.ledger if c is not None else None
        if led is not None and req.row >= 0:
            led.state[req.row] = _ledger.RUNNING
        if c is not None and c.obs is not None:
            # FlightRecorder.record_span inlined (the one per-request
            # telemetry hook): sampling hash + one staged tuple append
            rec = c.obs
            if req.row >= 0 and ((req.row + 1) * 2654435761
                                 + rec._span_mix) \
                    & 0xFFFFFFFF < rec._span_limit:
                rec._sp_stage.append(
                    (now, req.row, _SPAN_ADMIT, self.id))
        # slotted SimSeq built without the constructor call (hot: once
        # per admission) — field-for-field what __init__ would set
        s = _new_seq(SimSeq)
        s.request = req
        s.ctx_tokens = ctx
        s.prefill_left = prefill
        s.gen_f = float(req.tokens_generated)
        s.decoding = False
        s.prefill_done_t = 0.0
        s.v0 = 0.0
        s.gen_base = 0.0
        s.ctx_base = 0.0
        self.running[req.req_id] = s
        if c is not None:
            c.total_running += 1
        sc = self._slo_counts
        k = req.slo.itl
        sc[k] = sc.get(k, 0) + 1
        if req.request_type == RequestType.INTERACTIVE:
            self._n_interactive += 1
        else:
            self._batch_lifo.append(req.req_id)
        if self.event_mode:
            if prefill > 0:
                s.prefill_done_t = now + prefill
                _heappush(self._prefill_heap, (s.prefill_done_t,
                                               req.req_id))
                self._kv_prefill += ctx
            else:
                self._enter_decode(s, self.vclock)
                if req.first_token_time is None:
                    req.first_token_time = now
                    if led is not None and req.row >= 0:
                        led.first_token_time[req.row] = now
            if c is not None:                # inline mark_dirty
                c.dirty.add(self)
                c.route_version += 1
            # inline _sync_plane's early-out (hot: once per admit)
            if self.slot >= 0 and c is not None and c.plane_live:
                self._sync_plane()
            else:
                self._eta_stamp = -1
            if c is not None:
                # cache the post-admit completion ETA while the
                # composition is hot: the sweep's ``sweep_etas`` sees a
                # fresh stamp and skips its ``next_event_in`` recompute
                # (identical value — active with a non-empty batch, so
                # the guard it adds over ``_compute_eta`` is vacuous).
                # The composition ITL is inlined when it will be used
                # (same expression grouping as ``mean_ctx``/``_itl_now``
                # inside ``_compute_eta`` — identical floats).
                if self._n_dec and c.quantize == 0.0:
                    n2 = len(self.running)
                    ctx2 = (self._kv_prefill + self._kv_dec_base
                            + self._n_dec * self.vclock) / n2
                    if ctx2 < 1.0:
                        ctx2 = 1.0
                    (c_mem_base, c_mem_kv, c_comp, c_coll, c_spec,
                     c_spec_over, c_spec_speed, cap, c_prefix) = \
                        self._c_itl
                    mem_t = c_mem_base + n2 * ctx2 * c_mem_kv
                    comp_t = n2 * c_comp
                    itl = (mem_t if mem_t >= comp_t else comp_t) \
                        + c_coll + STEP_OVERHEAD
                    if c_spec:
                        itl = itl * (1 + c_spec_over
                                     * math.sqrt(n2)) / c_spec_speed
                    if cap != _INF:
                        demand = n2 * (ctx2 + c_prefix)
                        if demand > cap:
                            over = demand / cap - 1.0
                            itl *= 1.0 + 4.0 * over + 8.0 * over * over
                    itl *= self.slow_factor
                    running = self.running
                    best = _INF
                    ph = self._prefill_heap
                    while ph:
                        t_done, rid = ph[0]
                        s2 = running.get(rid)
                        if s2 is None or s2.decoding \
                                or s2.prefill_done_t != t_done:
                            _heappop(ph)
                            continue
                        best = t_done - self.last_advance
                        break
                    dh = self._decode_heap
                    while dh:
                        vfin, rid = dh[0]
                        s2 = running.get(rid)
                        if s2 is None or not s2.decoding:
                            _heappop(dh)
                            continue
                        d = (s2.request.output_len - s2.gen_base) - vfin
                        if d > 1e-6 or d < -1e-6:
                            _heappop(dh)
                            continue
                        eta = (vfin - self.vclock) * itl
                        if eta < 1e11 and eta < best:
                            best = eta
                        break
                    grain = c.completion_grain
                    self._eta_val = best if best >= grain else grain
                else:
                    self._eta_val = self._compute_eta()
                self._eta_stamp = c.batch_seq
        else:
            self._kv_tokens += ctx

    def evict_one_batch(self, now: float) -> Optional[Request]:
        """Mixed-instance preemption: interactive evicts batch; KV saved to
        host so the restart skips re-prefill (paper §3)."""
        if self.n_running_batch() == 0:
            return None
        if self.event_mode:
            self.advance(now, False)  # settle old composition first
        while self._batch_lifo:      # most-recent batch admit still running
            s = self.running.get(self._batch_lifo.pop())
            if s is None or s.request.request_type != RequestType.BATCH:
                continue             # stale entry (finished/evicted)
            self._materialize(s)
            self._remove_seq(s)
            r = s.request
            r.state = RequestState.PREEMPTED
            r.preemptions += 1
            r.saved_kv = ("sim", s.ctx_tokens)
            c = self._cluster
            if c is not None and c.ledger is not None and r.row >= 0:
                c.ledger.state[r.row] = _ledger.PREEMPTED
            if c is not None and c.obs is not None:
                c.obs.record_evict(c, now, r, self)
            self.mark_dirty()
            self._sync_plane()
            return r
        return None

    # ----------------------------------------------------- seq bookkeeping
    # Internal transition: every caller runs _sync_plane before the batch ends.
    # mirror-sync: ok(callers settle the composition via _sync_plane)
    def _enter_decode(self, s: SimSeq, v_entry: float) -> None:
        s.decoding = True
        s.v0 = v_entry
        s.gen_base = s.gen_f - v_entry
        s.ctx_base = s.ctx_tokens - v_entry
        self._kv_dec_base += s.ctx_base
        self._n_dec += 1
        vfin = float(s.request.output_len) - s.gen_base
        _heappush(self._decode_heap, (vfin, s.request.req_id))

    def _materialize(self, s: SimSeq) -> None:
        """Sync a decoding seq's lazy counters from the virtual clock."""
        if self.event_mode and s.decoding:
            s.gen_f = min(s.gen_base + self.vclock,
                          float(s.request.output_len))
            s.ctx_tokens = s.ctx_base + self.vclock
            r = s.request
            r.tokens_generated = int(s.gen_f)
            c = self._cluster
            if c is not None and c.ledger is not None and r.row >= 0:
                c.ledger.tokens_generated[r.row] = r.tokens_generated

    # Internal transition: every caller runs _sync_plane before the batch ends.
    # mirror-sync: ok(callers settle the composition via _sync_plane)
    def _remove_seq(self, s: SimSeq) -> None:
        r = s.request
        del self.running[r.req_id]
        if self._cluster is not None:
            self._cluster.total_running -= 1
        sc = self._slo_counts
        c = sc.get(r.slo.itl, 0) - 1
        if c > 0:
            sc[r.slo.itl] = c
        else:
            sc.pop(r.slo.itl, None)
        if r.request_type == RequestType.INTERACTIVE:
            self._n_interactive -= 1
        if self.event_mode:
            if s.decoding:
                s.decoding = False
                self._kv_dec_base -= s.ctx_base
                self._n_dec -= 1
            else:
                self._kv_prefill -= s.ctx_tokens
        else:
            self._kv_tokens -= s.ctx_tokens
        if not self.running:       # reset float drift at emptiness
            self._kv_tokens = 0.0
            self._kv_prefill = 0.0
            self._kv_dec_base = 0.0
            self._n_interactive = 0

    # --------------------------------------------------- event-driven core
    def mark_dirty(self) -> None:
        """Flag this instance for completion-event rescheduling (and pending
        finish collection) at the end of the current event batch. Also
        bumps the cluster's route version: anything that marks an instance
        dirty may have freed capacity, so saturated-lane routing memos
        must be revalidated."""
        c = self._cluster
        if c is not None:
            c.dirty.add(self)
            c.route_version += 1

    def _sync_plane(self) -> None:
        """Mirror this instance's fluid scalars into the plane columns
        (and invalidate its cached completion ETA). Below the vectorized
        cut-over (``cluster.plane_live`` unarmed) only the ETA stamp is
        touched — the columns would never be read, and arming resyncs
        every instance from scratch."""
        self._eta_stamp = -1
        s = self.slot
        if s < 0:
            return
        c = self._cluster
        if c is None or not c.plane_live:
            return
        pl = self._plane
        pl.vclock[s] = self.vclock
        pl.last_advance[s] = self.last_advance
        pl.n_running[s] = len(self.running)
        pl.n_dec[s] = self._n_dec
        pl.kv_prefill[s] = self._kv_prefill
        pl.kv_dec_base[s] = self._kv_dec_base
        # mirror *cleaned* heads: a stale head (seq evicted/finished) is
        # conservative for the crossing check but would poison the
        # vectorized ETA with a too-early event the scalar path (which
        # cleans inside next_event_in) would never schedule
        pl.next_prefill[s], pl.next_vfin[s] = self._clean_heads()

    def _clean_heads(self) -> Tuple[float, float]:
        """Pop invalid heap tops (departed/re-entered seqs) and return the
        earliest *valid* (prefill completion time, decode virtual finish)
        — inf where none. Popping invalid entries is unobservable: every
        consumer validity-checks entries anyway."""
        running = self.running
        ph = self._prefill_heap
        np_ = _INF
        while ph:
            t_done, rid = ph[0]
            s = running.get(rid)
            if s is None or s.decoding or s.prefill_done_t != t_done:
                heapq.heappop(ph)
                continue
            np_ = t_done
            break
        dh = self._decode_heap
        nv = _INF
        while dh:
            vfin, rid = dh[0]
            s = running.get(rid)
            if s is None or not s.decoding or abs(
                    (s.request.output_len - s.gen_base) - vfin) > 1e-6:
                heapq.heappop(dh)
                continue
            nv = vfin
            break
        return np_, nv

    def drain_finished(self) -> List[Request]:
        out = self._pending_finished
        self._pending_finished = []
        return out

    def advance(self, now: float, store_eta: bool = True) -> None:
        """Fluid catch-up to ``now`` under the current (fixed) composition —
        the event-core counterpart of :meth:`step`.

        All decoding seqs share one token rate, so the whole pool advances
        by moving ``vclock``; prefill→decode transitions and finishes pop
        off heaps at their exact crossing times (interpolated, so a
        completion estimate firing slightly late is harmless).

        ``store_eta`` caches the post-advance completion ETA in the plane
        (what ``next_event_in`` would recompute at the sweep) — callers
        that immediately change the composition again (an admit settle)
        pass False to skip the wasted work.
        """
        dt = now - self.last_advance
        t0 = self.last_advance
        self.last_advance = now
        running = self.running
        if dt <= 0 or not self.active or not running:
            if self.slot >= 0 and self._cluster is not None \
                    and self._cluster.plane_live:
                self._plane.last_advance[self.slot] = now
            return
        cluster = self._cluster
        if cluster is not None:
            cluster.dirty.add(self)          # inline mark_dirty
            cluster.route_version += 1
        # inline _itl_now at max(mean_ctx, 1.0) — identical float sequence
        n = len(running)
        v_old = self.vclock
        ctx = (self._kv_prefill + self._kv_dec_base
               + self._n_dec * v_old) / n
        if ctx < 1.0:
            ctx = 1.0
        (c_mem_base, c_mem_kv, c_comp, c_coll, c_spec,
         c_spec_over, c_spec_speed, cap, c_prefix) = self._c_itl
        mem_t = c_mem_base + n * ctx * c_mem_kv
        comp_t = n * c_comp
        itl = (mem_t if mem_t >= comp_t else comp_t) \
            + c_coll + STEP_OVERHEAD
        if c_spec:
            itl = itl * (1 + c_spec_over * math.sqrt(n)) / c_spec_speed
        if cap != _INF:
            demand = n * (ctx + c_prefix)
            if demand > cap:
                over = demand / cap - 1.0
                itl *= 1.0 + 4.0 * over + 8.0 * over * over
        sf = self.slow_factor
        if sf != 1.0:
            itl *= sf
        q = cluster.quantize if cluster is not None else 0.0
        if q > 0:
            # fixed-tick parity: int(q/itl) tokens per tick, no carry
            per_tick = int(q / itl + 1e-9)
            itl = q / per_tick if per_tick > 0 else _STALLED_ITL
        toks = 0.0
        led = cluster.ledger if cluster is not None else None

        # 1. prefill completions due within (t0, now]: seq starts decoding
        #    mid-interval with vclock credit from its entry point
        ph = self._prefill_heap
        dh = self._decode_heap
        entry_debt = 0.0
        lim = now + 1e-12
        while ph and ph[0][0] <= lim:
            t_done, rid = _heappop(ph)
            s = running.get(rid)
            if s is None or s.decoding or s.prefill_done_t != t_done:
                continue                     # stale (departed/re-admitted)
            s.prefill_left = 0.0
            self._kv_prefill -= s.ctx_tokens
            r = s.request
            if r.first_token_time is None:
                r.first_token_time = t_done
                if led is not None and r.row >= 0:
                    led.first_token_time[r.row] = t_done
                s.gen_f += 1.0
                s.ctx_tokens += 1.0
                toks += 1.0
            dpre = t_done - t0               # inline max(dpre, 0.0)
            v_entry = v_old + (dpre if dpre > 0.0 else 0.0) / itl
            entry_debt += v_entry - v_old
            # inline _enter_decode (hottest transition in the event core)
            s.decoding = True
            s.v0 = v_entry
            s.gen_base = gb = s.gen_f - v_entry
            s.ctx_base = cb = s.ctx_tokens - v_entry
            self._kv_dec_base += cb
            self._n_dec += 1
            _heappush(dh, (float(r.output_len) - gb, rid))

        # 2. the decode pool advances as one fluid
        if self._n_dec:
            self.vclock = v_old + dt / itl
            toks += self._n_dec * (dt / itl) - entry_debt

            # 3. finishes: pop virtual finish times the clock crossed
            vclock = self.vclock
            vlim = vclock + 1e-9
            sc = self._slo_counts
            while dh and dh[0][0] <= vlim:
                vfin, rid = _heappop(dh)
                s = running.get(rid)
                if s is None or not s.decoding:
                    continue                 # stale entry
                d = (s.request.output_len - s.gen_base) - vfin
                if d > 1e-6 or d < -1e-6:    # manual abs: hot stale check
                    continue
                over_v = vclock - vfin       # tokens past the true finish
                toks -= over_v
                s.ctx_tokens = s.ctx_base + vfin
                r = s.request
                s.gen_f = float(r.output_len)
                # inline _remove_seq, specialized: event_mode decoding seq
                del running[rid]
                if cluster is not None:
                    cluster.total_running -= 1
                k = r.slo.itl
                cnt = sc.get(k, 0) - 1
                if cnt > 0:
                    sc[k] = cnt
                else:
                    sc.pop(k, None)
                if r.request_type == RequestType.INTERACTIVE:
                    self._n_interactive -= 1
                s.decoding = False
                self._kv_dec_base -= s.ctx_base
                self._n_dec -= 1
                if not running:    # reset float drift at emptiness
                    self._kv_tokens = 0.0
                    self._kv_prefill = 0.0
                    self._kv_dec_base = 0.0
                    self._n_interactive = 0
                r.tokens_generated = r.output_len
                r.state = RequestState.FINISHED
                ft = now - over_v * itl
                first = r.first_token_time
                if first is None:            # sub-itl output edge case
                    first = r.first_token_time = ft
                r.finish_time = ft if ft >= first else first
                # one lifetime-mean ITL sample (the event core records the
                # mean the SLO check reads, not per-tick samples)
                span = r.finish_time - first
                den = float(r.output_len) - 1.0
                mean = span / (den if den > 1.0 else 1.0)
                r.itl_samples.append(mean)
                if led is not None and r.row >= 0:
                    row = r.row
                    led.state[row] = _ledger.FINISHED
                    led.tokens_generated[row] = r.output_len
                    led.first_token_time[row] = r.first_token_time
                    led.finish_time[row] = r.finish_time
                    led.mean_itl[row] = mean
                self._pending_finished.append(r)

        if toks and cluster is not None:
            cluster.tok_accum += toks
        # cache the sweep's completion ETA while everything is hot (heads
        # cleaned first so the plane mirrors valid heads); any later
        # composition change re-invalidates the stamp via _sync_plane
        do_eta = store_eta and running and cluster is not None \
            and q == 0.0
        if do_eta:
            # post-pop composition ITL, computed once and shared with the
            # eta (exactly what next_event_in would recompute); _itl_now
            # and _compute_eta are inlined — identical float sequences
            n2 = len(running)
            ctx2 = (self._kv_prefill + self._kv_dec_base
                    + self._n_dec * self.vclock) / n2
            if ctx2 < 1.0:
                ctx2 = 1.0
            mem_t = c_mem_base + n2 * ctx2 * c_mem_kv
            comp_t = n2 * c_comp
            itl2 = (mem_t if mem_t >= comp_t else comp_t) \
                + c_coll + STEP_OVERHEAD
            if c_spec:
                itl2 = itl2 * (1 + c_spec_over * math.sqrt(n2)) \
                    / c_spec_speed
            if cap != _INF:
                demand = n2 * (ctx2 + c_prefix)
                if demand > cap:
                    over = demand / cap - 1.0
                    itl2 *= 1.0 + 4.0 * over + 8.0 * over * over
            itl2 *= self.slow_factor
            best = _INF
            while ph:
                t_done, rid = ph[0]
                s = running.get(rid)
                if s is None or s.decoding or s.prefill_done_t != t_done:
                    _heappop(ph)
                    continue
                best = t_done - self.last_advance
                break
            while dh:
                vfin, rid = dh[0]
                s = running.get(rid)
                if s is None or not s.decoding:
                    _heappop(dh)
                    continue
                d = (s.request.output_len - s.gen_base) - vfin
                if d > 1e-6 or d < -1e-6:    # manual abs: hot stale check
                    _heappop(dh)
                    continue
                eta = (vfin - self.vclock) * itl2
                if eta < 1e11 and eta < best:  # stalled seqs: no event
                    best = eta
                break
            grain = cluster.completion_grain
            eta = best if best >= grain else grain
        # inline _sync_plane's early-out: below the vectorized cut-over
        # only the ETA stamp matters, and the call itself is hot
        if self.slot >= 0 and cluster is not None and cluster.plane_live:
            self._sync_plane()
        else:
            self._eta_stamp = -1
        if do_eta:
            self._eta_val = eta
            self._eta_stamp = cluster.batch_seq

    def _compute_eta(self, itl: Optional[float] = None) -> float:
        """Shared body of :meth:`next_event_in`: clean stale heap heads,
        return the grain-floored seconds until the next intrinsic event
        under the *current* composition. ``itl`` short-circuits the
        composition ITL when the caller (``advance``) just computed it —
        only valid with quantize off."""
        best = _INF
        running = self.running
        ph = self._prefill_heap
        while ph:
            t_done, rid = ph[0]
            s = running.get(rid)
            if s is None or s.decoding or s.prefill_done_t != t_done:
                _heappop(ph)
                continue
            best = t_done - self.last_advance
            break
        dh = self._decode_heap
        while dh:
            vfin, rid = dh[0]
            s = running.get(rid)
            if s is None or not s.decoding or abs(
                    (s.request.output_len - s.gen_base) - vfin) > 1e-6:
                _heappop(dh)
                continue
            if itl is None:
                itl = self._itl_now(len(running), max(self.mean_ctx(), 1.0))
                cluster = self._cluster
                q = cluster.quantize if cluster is not None else 0.0
                if q > 0:
                    per_tick = int(q / itl + 1e-9)
                    itl = q / per_tick if per_tick > 0 else _STALLED_ITL
            eta = (vfin - self.vclock) * itl
            if eta < 1e11 and eta < best:  # stalled seqs schedule nothing
                best = eta
            break
        grain = self._cluster.completion_grain if self._cluster else 1e-3
        return best if best >= grain else grain

    def next_event_in(self) -> float:
        """Seconds until this instance's next intrinsic event (a prefill
        completing or the earliest finish) under the current composition;
        inf when idle. Floored at the cluster's completion grain so nearby
        finishes coalesce into one event (and a late-drifting estimate
        re-fires geometrically rather than spinning)."""
        if not self.active or not self.running:
            return _INF
        return self._compute_eta()

    # ------------------------------------------------------------ stepping
    # The event engines never run it and RunResult falls back to objects.
    # mirror-sync: ok(fixed-tick reference path is ledger-less by design)
    def step(self, dt: float, now: float) -> Tuple[List[Request], int]:
        """Advance the instance by dt of simulated wall time (fixed-tick
        reference; walks every running sequence)."""
        if not self.active or not self.running:
            return [], 0
        b = self.n_running
        itl = self._itl_now(b, max(self.mean_ctx(), 1.0))
        finished: List[Request] = []
        tokens_out = 0
        for s in list(self.running.values()):
            budget = dt
            if s.prefill_left > 0:
                used = min(budget, s.prefill_left)
                s.prefill_left -= used
                budget -= used
                if s.prefill_left > 0:
                    continue
                if s.request.first_token_time is None:
                    s.request.first_token_time = now + used
                    s.request.tokens_generated += 1
                    s.ctx_tokens += 1
                    self._kv_tokens += 1
                    tokens_out += 1
            ntok = int(budget / itl)
            ntok = min(ntok, s.request.output_len - s.request.tokens_generated)
            if ntok > 0:
                s.request.tokens_generated += ntok
                s.ctx_tokens += ntok
                self._kv_tokens += ntok
                tokens_out += ntok
                s.request.itl_samples.append(itl)
                if s.request.first_token_time is None:
                    s.request.first_token_time = now + itl
            if s.done:
                s.request.state = RequestState.FINISHED
                s.request.finish_time = now + dt
                self._remove_seq(s)
                finished.append(s.request)
        return finished, tokens_out

    def update_local_autoscaler(self) -> None:
        if self.local is None or not self.running:
            return
        itl = self.current_itl()
        m = LocalMetrics(observed_itl=itl,
                         throughput=self.n_running / itl,
                         itl_slo=self.min_itl_slo(),
                         n_active=self.n_running,
                         batch_size=self.local.max_batch_size)
        before = self.local.max_batch_size
        self.local.update(m)
        if self.local.max_batch_size != before and self._cluster is not None:
            # a ceiling move changes admission capacity — routing memos
            # (saturation and positive-scan) key off route_version
            self._cluster.route_version += 1


class SimCluster:
    def __init__(self, perf_factory, *, max_chips: int = 400,
                 load_time: Optional[float] = None):
        """perf_factory: model_name -> PerfModel (fresh or shared)."""
        self.perf_factory = perf_factory
        self.max_chips = max_chips
        self.load_time = load_time
        self.instances: List[SimInstance] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.failures = 0            # crash-injected removals (not scaling)
        self.degradations = 0        # slow-node injections (instance kept)
        # noisy slow-node detector knobs (engines thread a per-run config
        # through; tests/scenarios may assign directly before the run)
        self.detector = _DEFAULT_DETECTOR
        self.chip_seconds = 0.0
        self.peak_chips = 0
        self._used_chips = 0         # maintained by provision/retire
        self._pools: Dict[InstanceType, List[SimInstance]] = \
            {t: [] for t in InstanceType}
        # (model, itype) -> live pool; the multi-model routing/control path
        # (one Algorithm-2 loop per model) reads these instead of filtering
        self._model_pools: Dict[Tuple[str, InstanceType],
                                List[SimInstance]] = {}
        self._model_count: Dict[str, int] = {}   # model -> live instances
        self._pool_pairs: Dict[str, Tuple[List[SimInstance],
                                          List[SimInstance]]] = {}
        self.total_running = 0       # running seqs cluster-wide (O(1) idle check)
        # O(1) registries: live ACTIVE instances keyed by id (provision
        # order; failure/degradation victim draws sort the small key set
        # instead of scanning every instance), count of LOADING instances
        # (quiescence check), and provisions not yet given a READY event
        self._active: Dict[int, SimInstance] = {}
        self.n_loading = 0
        self.new_loading: List[SimInstance] = []
        # bumped whenever admission capacity may have improved (instance
        # dirtied / activated / provisioned); saturated-lane routing memos
        # key on it — see BaseController.route_interactive
        self.route_version = 0
        # current event-batch stamp (set by the event loops each
        # iteration; keys the routing memo's once-per-batch arm and the
        # plane's completion-ETA cache)
        self.batch_seq = 0
        # --- event-core state (unused on the fixed-tick path) ---
        self.event_mode = False
        self.now = 0.0               # sim time chip accounting is valid at
        self.dirty: set = set()      # instances needing event rescheduling
        self.tok_accum = 0.0         # tokens generated since last drain
        # completion estimates are coalesced to this grain: finishes inside
        # one grain are processed together (their finish times are still
        # interpolated exactly) — the same quantization a dt=0.25 fixed
        # tick imposes, at a fraction of the events
        self.completion_grain = 0.25
        # sparse fixed-tick mode (simulate_events(quantize=dt)): decode
        # rates emulate the tick loop's integer truncation (int(dt/itl)
        # tokens per tick, no carry) so both engines share dynamics
        self.quantize = 0.0
        # columnar outcome store installed by the event engines; None =
        # object-only recording (fixed tick, bare unit-test clusters)
        self.ledger = None
        # flight recorder (repro.obs) attached by the engines when
        # telemetry is armed; every hook is one predicted branch when off
        self.obs = None
        # struct-of-arrays instance plane; ``catch_up`` uses the vectorized
        # pass at >= vec_min live instances (NumPy fixed costs lose below),
        # the scalar per-object loop otherwise. Equivalence tests pin
        # vec_min to 0/huge to force either path.
        self.plane = InstancePlane()
        self.vec_min = 33
        # armed once the fleet is big enough that the vectorized pass may
        # run: column syncs are skipped while unarmed (nothing reads
        # them) and arming does one full resync. Hysteresis on disarm
        # keeps a fleet hovering at the threshold from thrashing.
        self.plane_live = False

    # ------------------------------------------------------------ queries
    def by_type(self, itype: InstanceType) -> List[SimInstance]:
        """Live (maintained) pool list — treat as read-only; copy before
        retiring members while iterating."""
        return self._pools[itype]

    def by_model(self, model: str, itype: InstanceType) -> List[SimInstance]:
        """Live (model, type) pool — same read-only contract as by_type."""
        return self._model_pools.setdefault((model, itype), [])

    def pool_pair(self, model: str) -> Tuple[List[SimInstance],
                                             List[SimInstance]]:
        """(interactive pool, mixed pool) for ``model`` — the per-arrival
        routing pair, cached by model name. Pool list objects are stable
        (mutated in place, never replaced), so the cache never goes
        stale."""
        pair = self._pool_pairs.get(model)
        if pair is None:
            pair = self._pool_pairs[model] = (
                self.by_model(model, InstanceType.INTERACTIVE),
                self.by_model(model, InstanceType.MIXED))
        return pair

    def instances_of(self, model: str) -> List[SimInstance]:
        """All live instances serving ``model`` (every type)."""
        return [i for t in InstanceType
                for i in self._model_pools.get((model, t), ())]

    def n_instances_of(self, model: str) -> int:
        """O(1) live-instance count for ``model`` (maintained counter —
        the per-tick bootstrap/skip checks in the controller use this
        instead of building the ``instances_of`` list)."""
        return self._model_count.get(model, 0)

    def models_present(self) -> List[str]:
        """Distinct models with at least one live instance."""
        seen: Dict[str, None] = {}
        for inst in self.instances:
            seen.setdefault(inst.model)
        return list(seen)

    def active_instances(self) -> List[SimInstance]:
        return [i for i in self.instances if i.active]

    def active_sorted(self) -> List[SimInstance]:
        """Active instances in id order (failure/degradation victim draws;
        O(a log a) over the registry, not O(n) over every instance)."""
        out = list(self._active.values())
        out.sort(key=lambda i: i.id)
        return out

    @property
    def n_active(self) -> int:
        return len(self._active)

    def counts_by_type(self) -> Tuple[int, int, int]:
        """O(1) (interactive, mixed, batch) live-instance counts — the
        timeline-sample fast path."""
        p = self._pools
        return (len(p[InstanceType.INTERACTIVE]),
                len(p[InstanceType.MIXED]),
                len(p[InstanceType.BATCH]))

    def used_chips(self) -> int:
        return self._used_chips

    @property
    def hysteresis(self) -> float:
        """Total scaling actions / scale-ups (paper §2.3 definition)."""
        if self.scale_ups == 0:
            return 0.0
        return (self.scale_ups + self.scale_downs) / self.scale_ups

    # ------------------------------------------------------------ scaling
    def provision(self, model: str, itype: InstanceType, now: float,
                  **inst_kw) -> Optional[SimInstance]:
        perf = self.perf_factory(model)
        if self._used_chips + perf.chips > self.max_chips:
            return None
        chips0 = self._used_chips
        inst = SimInstance(perf, itype, now, load_time=self.load_time,
                           **inst_kw)
        inst.event_mode = self.event_mode
        inst._cluster = self
        inst._plane = self.plane
        inst.slot = self.plane.alloc(inst)
        self.instances.append(inst)
        self._pools[itype].append(inst)
        self._model_pools.setdefault((model, itype), []).append(inst)
        self._model_count[model] = self._model_count.get(model, 0) + 1
        self.scale_ups += 1
        self._used_chips += perf.chips
        self.peak_chips = max(self.peak_chips, self._used_chips)
        self.n_loading += 1
        self.route_version += 1
        if self.event_mode:
            self.new_loading.append(inst)
        if not self.plane_live and len(self.instances) >= self.vec_min:
            self._arm_plane()
        if self.obs is not None:
            self.obs.record_provision(self, now, model, itype,
                                      chips0, self._used_chips)
        return inst

    def _arm_plane(self) -> None:
        """Arm the vectorized plane: resync every live instance's columns
        (they were skipped while unarmed), then keep them in sync."""
        self.plane_live = True
        pl = self.plane
        for inst in self.instances:
            s = inst.slot
            if s < 0:
                continue
            pl.active[s] = inst.active
            pl.slow[s] = inst.slow_factor
            inst._sync_plane()

    def drain_new_loading(self) -> List[SimInstance]:
        """Instances provisioned since the last drain that still need a
        READY event scheduled (O(new) — replaces the per-tick scan over
        every instance)."""
        out = [i for i in self.new_loading
               if i.state == InstanceState.LOADING]
        self.new_loading.clear()
        return out

    def retire(self, inst: SimInstance) -> List[Request]:
        """Remove an instance; returns displaced requests for requeueing."""
        chips0 = self._used_chips
        displaced = self._remove_instance(inst)
        self.scale_downs += 1
        if self.obs is not None:
            self.obs.record_retire(self, self.now, inst,
                                   chips0, self._used_chips)
        return displaced

    def degrade_instance(self, inst: SimInstance, factor: float,
                         now: float) -> None:
        """Slow-node injection: inflate the victim's ITL by ``factor``
        without removing it. Fluid state is settled first so only future
        decode progress runs slow; in-flight work stays put (the partial
        failure mode crashes cannot model)."""
        if self.event_mode:
            inst.advance(now, False)  # settle at the healthy rate first
        inst.slow_factor = factor
        if inst.slot >= 0:
            self.plane.slow[inst.slot] = factor
        inst.mark_dirty()            # completion estimates must re-fire
        self.degradations += 1
        if self.obs is not None:
            self.obs.record_degrade(self, now, inst, factor)

    def recover_instance(self, inst: SimInstance, now: float) -> None:
        if self.event_mode:
            inst.advance(now, False)  # settle at the degraded rate first
        inst.slow_factor = 1.0
        if inst.slot >= 0:
            self.plane.slow[inst.slot] = 1.0
        inst.mark_dirty()
        if self.obs is not None:
            self.obs.record_recover(self, now, inst)

    def fail_instance(self, inst: SimInstance) -> List[Request]:
        """Crash an instance (failure injection): like ``retire`` but the
        removal is counted as a failure, not an autoscaling action, so the
        hysteresis metric stays a controller property. In-flight requests
        lose their on-device KV (``saved_kv=None`` — they must re-prefill
        elsewhere) and are returned for requeueing."""
        chips0 = self._used_chips
        displaced = self._remove_instance(inst)
        self.failures += 1
        if self.obs is not None:
            self.obs.record_fail(self, self.now, inst,
                                 chips0, self._used_chips)
        return displaced

    def _remove_instance(self, inst: SimInstance) -> List[Request]:
        if self.event_mode:
            inst.advance(self.now, False)   # settle fluid state first
            self.dirty.add(inst)     # pending finishes still get drained
        led = self.ledger
        displaced = []
        for s in inst.running.values():
            inst._materialize(s)
            r = s.request
            r.state = RequestState.PREEMPTED
            r.saved_kv = None   # instance gone; must re-prefill elsewhere
            if led is not None and r.row >= 0:
                led.state[r.row] = _ledger.PREEMPTED
            displaced.append(r)
        obs = self.obs
        if obs is not None:
            for r in displaced:     # lifecycle spans: back to queued
                obs.record_span(self.now, r.row, _SPAN_PREEMPT, inst.id)
        self.total_running -= len(inst.running)
        inst.running.clear()
        inst._batch_lifo.clear()
        inst._kv_tokens = 0.0
        inst._kv_prefill = 0.0
        inst._kv_dec_base = 0.0
        inst._n_dec = 0
        inst._n_interactive = 0
        inst._slo_counts.clear()
        inst._prefill_heap.clear()
        inst._decode_heap.clear()
        if inst.state == InstanceState.LOADING:
            self.n_loading -= 1
        inst.state = InstanceState.RETIRED
        inst.active = False
        self._active.pop(inst.id, None)
        if inst.slot >= 0:
            self.plane.free(inst.slot)
            inst.slot = -1
        self.instances.remove(inst)
        self._pools[inst.itype].remove(inst)
        self._model_pools[(inst.model, inst.itype)].remove(inst)
        self._model_count[inst.model] -= 1
        self._used_chips -= inst.perf.chips
        self.route_version += 1
        if self.plane_live and len(self.instances) < self.vec_min // 2:
            self.plane_live = False          # hysteresis disarm
        return displaced

    def tick_accounting(self, dt: float) -> None:
        self.chip_seconds += self.used_chips() * dt

    # --------------------------------------------------- event-driven core
    def advance_time(self, t: float) -> None:
        """Accrue chip-seconds over [now, t] (composition is constant
        between event batches) and move the cluster clock."""
        if t > self.now:
            self.chip_seconds += self._used_chips * (t - self.now)
            self.now = t

    def catch_up(self, t: float, batch_seq: int = -1) -> None:
        """Align every instance's fluid state with ``t`` (control ticks).

        At or above ``vec_min`` live instances this is one vectorized
        plane pass (plus scalar fall-back for instances with a crossing
        intrinsic event); below, the scalar loop — bit-identical results
        either way. Quantize mode always takes the scalar loop (the tick
        emulation's integer truncation isn't worth vectorizing)."""
        insts = self.instances
        if self.quantize > 0 or not self.event_mode \
                or len(insts) < self.vec_min:
            for inst in insts:
                inst.advance(t)
            return
        if not self.plane_live:
            self._arm_plane()        # vec_min lowered after provisioning
        self.plane.catch_up(t, self, batch_seq)

    def cached_eta(self, inst: SimInstance, batch_seq: int) -> float:
        """The completion ETA ``catch_up`` vector-computed for ``inst`` in
        event batch ``batch_seq``, or -1 when unavailable (mutated since /
        never computed) — the sweep then calls ``next_event_in``."""
        if inst._eta_stamp == batch_seq:
            return inst._eta_val
        return -1.0

    def sweep_etas(self, insts: List[SimInstance],
                   batch_seq: int) -> List[Tuple[SimInstance, float]]:
        """Completion ETAs for a sweep's dirty instances in one pass —
        the event loops' bulk-refill source (they stamp epochs and
        extend the heap from the returned pairs instead of re-pushing
        one estimate per instance).

        Instances whose ETA the vectorized catch-up already cached this
        event batch reuse it; the rest are recomputed — one vectorized
        pass over the plane columns when the plane is live (the
        coefficient math is ``InstancePlane._itl`` and the heads are the
        plane's *cleaned* mirrors, so the float sequence matches
        ``next_event_in`` exactly), the scalar path otherwise. Returns
        ``(instance, eta)`` pairs in input order, finite ETAs only."""
        if len(insts) < 8 or not self.plane_live or self.quantize != 0.0:
            # fused single pass — dirty sets are typically 1-2 deep and
            # the two-comprehension shape below costs more than the work
            out = []
            active = InstanceState.ACTIVE
            for inst in insts:
                if inst.state != active:
                    continue
                if inst._eta_stamp != batch_seq:
                    inst._eta_val = inst.next_event_in()
                    inst._eta_stamp = batch_seq
                e = inst._eta_val
                if e != _INF:
                    out.append((inst, e))
            return out
        stale = [i for i in insts
                 if i._eta_stamp != batch_seq
                 and i.state == InstanceState.ACTIVE]
        if stale:
            if len(stale) >= 8:
                pl = self.plane
                slots = np.fromiter((i.slot for i in stale),
                                    dtype=np.int64, count=len(stale))
                nr = pl.n_running[slots]
                run = nr > 0
                etas = np.full(len(stale), _INF)
                if run.any():
                    s = slots[run]
                    b = nr[run]
                    vc = pl.vclock[s]
                    kv = pl.kv_prefill[s] + pl.kv_dec_base[s] \
                        + pl.n_dec[s] * vc
                    ctx = np.maximum(kv / b, 1.0)
                    itl = pl._itl(s, b, ctx)
                    dec = (pl.next_vfin[s] - vc) * itl
                    dec = np.where(dec < 1e11, dec, _INF)  # stalled seqs
                    eta = np.minimum(
                        pl.next_prefill[s] - pl.last_advance[s], dec)
                    np.maximum(eta, self.completion_grain, out=eta)
                    etas[run] = eta
                for inst, e in zip(stale, etas.tolist()):
                    inst._eta_val = e
                    inst._eta_stamp = batch_seq
            else:
                for inst in stale:
                    inst._eta_val = inst.next_event_in()
                    inst._eta_stamp = batch_seq
        return [(i, i._eta_val) for i in insts
                if i.state == InstanceState.ACTIVE
                and i._eta_val != _INF and i._eta_stamp == batch_seq]

    def drain_dirty(self) -> List[SimInstance]:
        # deterministic order: set iteration is address-dependent, and this
        # order fixes event tie-breaks, backfill order, and the sequence
        # completions reach the estimator — same seed must mean same run
        d = self.dirty
        if len(d) == 1:
            return [d.pop()]
        out = sorted(d, key=_by_id)
        d.clear()
        return out

    def take_tokens(self) -> float:
        out = self.tok_accum
        self.tok_accum = 0.0
        return out
