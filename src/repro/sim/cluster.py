"""Simulated serving cluster: instances driven by the analytic perf model.

The control plane (autoscalers, routing, queues, request groups) is the
production ``repro.core`` / ``repro.serving`` code; only the data plane —
how long a decode step takes — is simulated, using ``PerfModel``. Instance
bring-up takes ``model_load_time()`` (the 15–60 s that motivates Chiron's
over-provisioning), and every provision/retire action is counted for the
hysteresis metric.
"""
from __future__ import annotations

import enum
import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.local_autoscaler import LocalAutoscaler
from repro.core.backpressure import LocalMetrics
from repro.serving.request import Request, RequestState, RequestType
from repro.sim.perf_model import PerfModel

_inst_counter = itertools.count()


class InstanceType(enum.Enum):
    INTERACTIVE = "interactive"
    MIXED = "mixed"
    BATCH = "batch"


class InstanceState(enum.Enum):
    LOADING = "loading"
    ACTIVE = "active"
    RETIRED = "retired"


@dataclass
class SimSeq:
    request: Request
    ctx_tokens: float            # prompt + generated so far (KV footprint)
    prefill_left: float          # seconds of prefill work remaining
    _itl_accum: Tuple[float, int] = (0.0, 0)

    @property
    def done(self) -> bool:
        return self.request.tokens_generated >= self.request.output_len


class SimInstance:
    def __init__(self, perf: PerfModel, itype: InstanceType, now: float, *,
                 local_autoscaler: Optional[LocalAutoscaler] = None,
                 static_batch: Optional[int] = None,
                 load_time: Optional[float] = None):
        self.id = next(_inst_counter)
        self.perf = perf
        self.itype = itype
        self.state = InstanceState.LOADING
        self.ready_time = now + (load_time if load_time is not None
                                 else perf.model_load_time())
        self.local = local_autoscaler
        self.static_batch = static_batch
        self.running: List[SimSeq] = []
        self.created_at = now

    # ------------------------------------------------------------ state
    def activate_if_ready(self, now: float) -> None:
        if self.state == InstanceState.LOADING and now >= self.ready_time:
            self.state = InstanceState.ACTIVE

    @property
    def active(self) -> bool:
        return self.state == InstanceState.ACTIVE

    @property
    def max_batch_size(self) -> int:
        if self.local is not None:
            return self.local.max_batch_size
        return self.static_batch or 64

    @property
    def n_running(self) -> int:
        return len(self.running)

    def mean_ctx(self) -> float:
        if not self.running:
            return 0.0
        return sum(s.ctx_tokens for s in self.running) / len(self.running)

    def kv_tokens(self) -> float:
        return sum(s.ctx_tokens for s in self.running)

    def kv_utilization(self) -> float:
        cap = self.perf.kv_capacity_tokens()
        if not math.isfinite(cap):
            return self.n_running / max(self.max_batch_size, 1)
        return self.kv_tokens() / cap

    def slot_utilization(self) -> float:
        return self.n_running / max(self.max_batch_size, 1)

    def current_itl(self) -> float:
        if not self.running:
            return 0.0
        return self.perf.itl(self.n_running, max(self.mean_ctx(), 1.0))

    def current_throughput(self) -> float:
        if not self.running:
            return 0.0
        return self.n_running / self.current_itl()

    def spare_throughput(self) -> float:
        """Tokens/s of unused slot capacity (used for BBP multiplexing)."""
        spare = self.max_batch_size - self.n_running
        if spare <= 0:
            return 0.0
        itl = self.perf.itl(self.max_batch_size, max(self.mean_ctx(), 512.0))
        return spare / itl

    def runs_interactive(self) -> bool:
        return any(s.request.is_interactive for s in self.running)

    def min_itl_slo(self) -> float:
        if not self.running:
            return float("inf")
        return min(s.request.slo.itl for s in self.running)

    # ------------------------------------------------------------ intake
    def can_admit(self, req: Request) -> bool:
        if not self.active or self.n_running >= self.max_batch_size:
            return False
        cap = self.perf.kv_capacity_tokens()
        if math.isfinite(cap):
            # hard admission wall well past the soft preemption inflection
            if self.kv_tokens() + req.prompt_len > 1.5 * cap:
                return False
        return True

    def admit(self, req: Request, now: float) -> None:
        restored = req.saved_kv is not None
        ctx = req.prompt_len + req.tokens_generated
        prefill = 0.0 if restored else self.perf.prefill_time(req.prompt_len)
        if restored:
            req.saved_kv = None
        req.state = RequestState.RUNNING
        self.running.append(SimSeq(req, ctx, prefill))

    def evict_one_batch(self, now: float) -> Optional[Request]:
        """Mixed-instance preemption: interactive evicts batch; KV saved to
        host so the restart skips re-prefill (paper §3)."""
        for i in reversed(range(len(self.running))):
            s = self.running[i]
            if s.request.request_type == RequestType.BATCH:
                self.running.pop(i)
                s.request.state = RequestState.PREEMPTED
                s.request.preemptions += 1
                s.request.saved_kv = ("sim", s.ctx_tokens)
                return s.request
        return None

    # ------------------------------------------------------------ stepping
    def step(self, dt: float, now: float) -> Tuple[List[Request], int]:
        """Advance the instance by dt of simulated wall time (fluid model)."""
        if not self.active or not self.running:
            return [], 0
        b = self.n_running
        itl = self.perf.itl(b, max(self.mean_ctx(), 1.0))
        finished: List[Request] = []
        tokens_out = 0
        for s in list(self.running):
            budget = dt
            if s.prefill_left > 0:
                used = min(budget, s.prefill_left)
                s.prefill_left -= used
                budget -= used
                if s.prefill_left > 0:
                    continue
                if s.request.first_token_time is None:
                    s.request.first_token_time = now + used
                    s.request.tokens_generated += 1
                    s.ctx_tokens += 1
                    tokens_out += 1
            ntok = int(budget / itl)
            ntok = min(ntok, s.request.output_len - s.request.tokens_generated)
            if ntok > 0:
                s.request.tokens_generated += ntok
                s.ctx_tokens += ntok
                tokens_out += ntok
                s.request.itl_samples.append(itl)
                if s.request.first_token_time is None:
                    s.request.first_token_time = now + itl
            if s.done:
                s.request.state = RequestState.FINISHED
                s.request.finish_time = now + dt
                self.running.remove(s)
                finished.append(s.request)
        return finished, tokens_out

    def update_local_autoscaler(self) -> None:
        if self.local is None or not self.running:
            return
        m = LocalMetrics(observed_itl=self.current_itl(),
                         throughput=self.current_throughput(),
                         itl_slo=self.min_itl_slo(),
                         n_active=self.n_running,
                         batch_size=self.local.max_batch_size)
        self.local.update(m)


class SimCluster:
    def __init__(self, perf_factory, *, max_chips: int = 400,
                 load_time: Optional[float] = None):
        """perf_factory: model_name -> PerfModel (fresh or shared)."""
        self.perf_factory = perf_factory
        self.max_chips = max_chips
        self.load_time = load_time
        self.instances: List[SimInstance] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.chip_seconds = 0.0
        self.peak_chips = 0

    # ------------------------------------------------------------ queries
    def by_type(self, itype: InstanceType) -> List[SimInstance]:
        return [i for i in self.instances if i.itype == itype]

    def active_instances(self) -> List[SimInstance]:
        return [i for i in self.instances if i.active]

    def used_chips(self) -> int:
        return sum(i.perf.chips for i in self.instances)

    @property
    def hysteresis(self) -> float:
        """Total scaling actions / scale-ups (paper §2.3 definition)."""
        if self.scale_ups == 0:
            return 0.0
        return (self.scale_ups + self.scale_downs) / self.scale_ups

    # ------------------------------------------------------------ scaling
    def provision(self, model: str, itype: InstanceType, now: float,
                  **inst_kw) -> Optional[SimInstance]:
        perf = self.perf_factory(model)
        if self.used_chips() + perf.chips > self.max_chips:
            return None
        inst = SimInstance(perf, itype, now, load_time=self.load_time,
                           **inst_kw)
        self.instances.append(inst)
        self.scale_ups += 1
        self.peak_chips = max(self.peak_chips, self.used_chips())
        return inst

    def retire(self, inst: SimInstance) -> List[Request]:
        """Remove an instance; returns displaced requests for requeueing."""
        displaced = [s.request for s in inst.running]
        for r in displaced:
            r.state = RequestState.PREEMPTED
            r.saved_kv = None   # instance gone; must re-prefill elsewhere
        inst.running.clear()
        inst.state = InstanceState.RETIRED
        self.instances.remove(inst)
        self.scale_downs += 1
        return displaced

    def tick_accounting(self, dt: float) -> None:
        self.chip_seconds += self.used_chips() * dt
