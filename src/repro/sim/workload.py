"""Workload generation: columnar traces + ShareGPT-like token distributions.

The paper's traces use 3,500 ShareGPT requests (Fig. 8 token distributions)
with Poisson arrivals for the main experiments and Gamma arrivals (varying
CV) for the burstiness robustness analysis (§6.3, Fig. 17).

The workload plane is columnar: a :class:`Trace` is a struct-of-arrays
(NumPy) view of a request stream — arrival times, token lengths, request
class, per-request SLOs, and a model column for multi-model fleets — and
every generator here fills those arrays with vectorized draws, never a
per-request Python loop. ``Request`` objects are only materialized at the
simulator boundary (``Trace.materialize`` / the event core's chunked
cursor), which is what keeps 1M+ request traces generable in milliseconds.

Trace schema (one row per request):

  arrival      float64  seconds from trace start, non-decreasing
  prompt_len   int64    input tokens
  output_len   int64    output tokens (ground truth)
  interactive  bool     True -> interactive class, False -> batch
  ttft_slo     float64  per-request TTFT SLO (seconds)
  itl_slo      float64  per-request ITL SLO (seconds/token)
  model_idx    int32    index into ``models`` (the model vocabulary)
  origin_idx   int32    index into ``origins`` (originating regions;
                        empty ``origins`` = single-region workload)
  tenant_idx   int32    index into ``tenants`` (paying tenants for
                        per-tenant attainment; empty ``tenants`` =
                        single-tenant workload)
  attempt      int32    optional: client retry attempts already consumed
                        before this submission (``None`` = fresh trace;
                        replayed overload traces carry the column so the
                        retry budget keeps counting across a round-trip)

``repro.sim.trace_io`` round-trips this schema to CSV/JSONL (including
Azure-LLM-inference-style traces) and streams multi-day files in
arrival-ordered chunks (:class:`TraceStream`).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.request import (BATCH_ITL_SLO, BATCH_TTFT_SLO,
                                   INTERACTIVE_ITL_SLO, INTERACTIVE_TTFT_SLO,
                                   Request, RequestType, SLO,
                                   request_id_counter)

# ShareGPT-ish lognormal parameters (Fig. 8: median input ~100 tokens with a
# heavy tail; outputs somewhat longer)
INPUT_MU, INPUT_SIGMA = 4.6, 1.0      # median ~100, mean ~165
OUTPUT_MU, OUTPUT_SIGMA = 5.2, 0.9    # median ~180, mean ~270
MAX_TOKENS = 2048

DEFAULT_MODEL = "llama-8b"

# infinite -1 row stamp for unledgered materialization (shared: `repeat`
# is stateless and inexhaustible, so concurrent zips interleave safely)
_NO_ROWS = itertools.repeat(-1)


# =========================================================== columnar trace
@dataclass
class Trace:
    """Struct-of-arrays request stream (see module docstring for schema).

    All columns share one length; ``models`` is the model vocabulary that
    ``model_idx`` indexes. Construction normalizes dtypes; use
    :meth:`sorted_by_arrival` before handing a trace to the simulator.
    """
    arrival: np.ndarray
    prompt_len: np.ndarray
    output_len: np.ndarray
    interactive: np.ndarray
    ttft_slo: np.ndarray
    itl_slo: np.ndarray
    model_idx: np.ndarray
    models: Tuple[str, ...] = (DEFAULT_MODEL,)
    origin_idx: Optional[np.ndarray] = None   # None/empty origins = no column
    origins: Tuple[str, ...] = ()
    tenant_idx: Optional[np.ndarray] = None   # None/empty tenants = no column
    tenants: Tuple[str, ...] = ()
    attempt: Optional[np.ndarray] = None      # None = no retry history

    def __post_init__(self):
        self.arrival = np.asarray(self.arrival, dtype=np.float64)
        n = self.arrival.shape[0]
        self.prompt_len = np.asarray(self.prompt_len, dtype=np.int64)
        self.output_len = np.asarray(self.output_len, dtype=np.int64)
        self.interactive = np.asarray(self.interactive, dtype=bool)
        self.ttft_slo = np.asarray(self.ttft_slo, dtype=np.float64)
        self.itl_slo = np.asarray(self.itl_slo, dtype=np.float64)
        self.model_idx = np.asarray(self.model_idx, dtype=np.int32)
        self.models = tuple(self.models)
        self.origins = tuple(self.origins)
        self.tenants = tuple(self.tenants)
        if self.origin_idx is None:
            self.origin_idx = np.zeros(n, dtype=np.int32)
        self.origin_idx = np.asarray(self.origin_idx, dtype=np.int32)
        if self.tenant_idx is None:
            self.tenant_idx = np.zeros(n, dtype=np.int32)
        self.tenant_idx = np.asarray(self.tenant_idx, dtype=np.int32)
        if self.attempt is not None:
            self.attempt = np.asarray(self.attempt, dtype=np.int32)
            if self.attempt.shape != (n,):
                raise ValueError(f"Trace column 'attempt' has shape "
                                 f"{self.attempt.shape}, want ({n},)")
        for name in ("prompt_len", "output_len", "interactive",
                     "ttft_slo", "itl_slo", "model_idx", "origin_idx",
                     "tenant_idx"):
            if getattr(self, name).shape != (n,):
                raise ValueError(f"Trace column {name!r} has shape "
                                 f"{getattr(self, name).shape}, want ({n},)")
        if n and (self.model_idx.min() < 0
                  or self.model_idx.max() >= len(self.models)):
            raise ValueError("Trace.model_idx out of range of models")
        if n and self.origins and (self.origin_idx.min() < 0 or
                                   self.origin_idx.max() >= len(self.origins)):
            raise ValueError("Trace.origin_idx out of range of origins")
        if n and self.tenants and (self.tenant_idx.min() < 0 or
                                   self.tenant_idx.max() >= len(self.tenants)):
            raise ValueError("Trace.tenant_idx out of range of tenants")

    # ------------------------------------------------------------ basics
    @property
    def n(self) -> int:
        return int(self.arrival.shape[0])

    def __len__(self) -> int:
        return self.n

    @property
    def duration(self) -> float:
        return float(self.arrival[-1]) if self.n else 0.0

    def sorted_by_arrival(self) -> "Trace":
        """Stable-sorted copy (no-op view reuse when already sorted)."""
        if self.n == 0 or bool(np.all(np.diff(self.arrival) >= 0)):
            return self
        order = np.argsort(self.arrival, kind="stable")
        return self.take(order)

    def take(self, idx) -> "Trace":
        return Trace(self.arrival[idx], self.prompt_len[idx],
                     self.output_len[idx], self.interactive[idx],
                     self.ttft_slo[idx], self.itl_slo[idx],
                     self.model_idx[idx], self.models,
                     self.origin_idx[idx], self.origins,
                     self.tenant_idx[idx], self.tenants,
                     None if self.attempt is None else self.attempt[idx])

    def head(self, n: int) -> "Trace":
        return self.take(slice(0, n))

    @staticmethod
    def concat(traces: Sequence["Trace"]) -> "Trace":
        """Concatenate traces, merging model (and origin) vocabularies."""
        def merge(vocabs, idx_cols):
            merged: List[str] = []
            remapped = []
            for vocab, idx in zip(vocabs, idx_cols):
                remap = np.empty(len(vocab), dtype=np.int32)
                for i, name in enumerate(vocab):
                    if name not in merged:
                        merged.append(name)
                    remap[i] = merged.index(name)
                remapped.append(remap[idx])
            return tuple(merged), remapped

        models, midx = merge([t.models for t in traces],
                             [t.model_idx for t in traces])
        # an origin-less trace (empty vocabulary) folds in as origin ""
        if any(t.origins for t in traces):
            origins, oidx = merge([t.origins or ("",) for t in traces],
                                  [t.origin_idx for t in traces])
        else:
            origins, oidx = (), [t.origin_idx for t in traces]
        # same folding rule for tenants: tenant-less traces become ""
        if any(t.tenants for t in traces):
            tenants, tidx = merge([t.tenants or ("",) for t in traces],
                                  [t.tenant_idx for t in traces])
        else:
            tenants, tidx = (), [t.tenant_idx for t in traces]
        # attempt folds in as zeros for history-less traces
        if any(t.attempt is not None for t in traces):
            attempt = np.concatenate(
                [t.attempt if t.attempt is not None
                 else np.zeros(t.n, dtype=np.int32) for t in traces])
        else:
            attempt = None
        return Trace(
            np.concatenate([t.arrival for t in traces]),
            np.concatenate([t.prompt_len for t in traces]),
            np.concatenate([t.output_len for t in traces]),
            np.concatenate([t.interactive for t in traces]),
            np.concatenate([t.ttft_slo for t in traces]),
            np.concatenate([t.itl_slo for t in traces]),
            np.concatenate(midx), models,
            np.concatenate(oidx), origins,
            np.concatenate(tidx), tenants, attempt)

    # ----------------------------------------------------- materialization
    def materialize(self, lo: int = 0, hi: Optional[int] = None, *,
                    row0: Optional[int] = None) -> List[Request]:
        """Build ``Request`` objects for rows [lo, hi) — the only place the
        columnar plane crosses into per-object land. Batched callers (the
        event core's cursor) use the slice bounds to stay lazy.

        ``row0`` stamps ``Request.row`` with ledger row ids (``row0 + i``
        for slice position i) so the event core can record outcomes
        columnar; by default rows stay unstamped (-1).

        SLO objects are interned per distinct (ttft, itl) pair — a trace
        carries a handful of SLO classes across millions of rows, and one
        shared immutable-by-convention instance per class keeps the
        per-request build cost down.
        """
        hi = self.n if hi is None else min(hi, self.n)
        arr = self.arrival[lo:hi].tolist()
        ins = self.prompt_len[lo:hi].tolist()
        outs = self.output_len[lo:hi].tolist()
        inter = self.interactive[lo:hi].tolist()
        midx = self.model_idx[lo:hi].tolist()
        models = self.models
        origins = self.origins or None
        oidx = self.origin_idx[lo:hi].tolist()
        tenants = self.tenants or None
        tidx = self.tenant_idx[lo:hi].tolist()
        it, ba = RequestType.INTERACTIVE, RequestType.BATCH
        # SLO interning columnar: one unique pass over the (ttft, itl)
        # pair column — complex128 packs both float64 exactly, so equal
        # pairs collapse to one shared SLO object, same as the old
        # per-row dict intern but without a tuple-key lookup per row
        key = self.ttft_slo[lo:hi] + self.itl_slo[lo:hi] * 1j
        uniq, inv = np.unique(key, return_inverse=True)
        slo_objs = [SLO(u.real, u.imag) for u in uniq.tolist()]
        slo_col = [slo_objs[k] for k in inv.tolist()]
        rows = range(row0, row0 + (hi - lo)) if row0 is not None \
            else _NO_ROWS
        out = []
        # bulk construction bypasses the dataclass __init__ (measured ~3x
        # per-object): a dict literal covering every Request field becomes
        # the instance __dict__ directly. test_trace_plane pins this
        # against constructor-built requests so field drift fails loudly.
        new = Request.__new__
        next_id = request_id_counter().__next__
        append = out.append
        for t, p, o, c, m, g, tn, slo, rw in zip(arr, ins, outs, inter,
                                                 midx, oidx, tidx,
                                                 slo_col, rows):
            r = new(Request)
            # fields at their dataclass defaults (state, outcome slots,
            # preemptions, ...) are deliberately absent: the dataclass
            # stores plain defaults as class attributes, so reads fall
            # through and the first write creates the instance entry.
            # Only ``itl_samples`` has a mutable factory default and must
            # be per-instance from the start.
            r.__dict__ = {
                "prompt_len": p, "output_len": o,
                "request_type": it if c else ba, "slo": slo,
                "arrival_time": t, "req_id": next_id(),
                "model": models[m],
                "itl_samples": [],
                "row": rw,
            }
            if origins:
                r.__dict__["origin"] = origins[g]
            if tenants:
                r.__dict__["tenant"] = tenants[tn]
            append(r)
        if self.attempt is not None:
            # pre-consumed retry attempts (replayed overload trace):
            # only nonzero cells need an instance entry — zero reads
            # fall through to the class default
            for r, a in zip(out, self.attempt[lo:hi].tolist()):
                if a:
                    # mirror-sync: ok(no ledger exists yet - from_trace seeds the column from this array)
                    r.retries = a
        return out

    @classmethod
    def from_requests(cls, reqs: Sequence[Request]) -> "Trace":
        """Columnarize a request list (round-trip / legacy ingestion)."""
        models: List[str] = []
        origins: List[str] = []
        tenants: List[str] = []
        midx = np.empty(len(reqs), dtype=np.int32)
        oidx = np.zeros(len(reqs), dtype=np.int32)
        tidx = np.zeros(len(reqs), dtype=np.int32)
        for i, r in enumerate(reqs):
            if r.model not in models:
                models.append(r.model)
            midx[i] = models.index(r.model)
            if r.origin is not None:
                if r.origin not in origins:
                    origins.append(r.origin)
                oidx[i] = origins.index(r.origin)
            tenant = getattr(r, "tenant", None)
            if tenant is not None:
                if tenant not in tenants:
                    tenants.append(tenant)
                tidx[i] = tenants.index(tenant)
        attempt = np.array([r.retries for r in reqs], dtype=np.int32)
        return cls(
            np.array([r.arrival_time for r in reqs], dtype=np.float64),
            np.array([r.prompt_len for r in reqs], dtype=np.int64),
            np.array([r.output_len for r in reqs], dtype=np.int64),
            np.array([r.is_interactive for r in reqs], dtype=bool),
            np.array([r.slo.ttft for r in reqs], dtype=np.float64),
            np.array([r.slo.itl for r in reqs], dtype=np.float64),
            midx, tuple(models) or (DEFAULT_MODEL,),
            oidx, tuple(origins),
            tidx, tuple(tenants),
            attempt if attempt.any() else None)


def make_trace(arrival: np.ndarray, prompt_len: np.ndarray,
               output_len: np.ndarray, interactive: np.ndarray, *,
               ttft_slo: Union[float, np.ndarray, None] = None,
               itl_slo: Union[float, np.ndarray, None] = None,
               batch_ttft_slo: float = BATCH_TTFT_SLO,
               model_idx: Optional[np.ndarray] = None,
               models: Sequence[str] = (DEFAULT_MODEL,),
               origin_idx: Optional[np.ndarray] = None,
               origins: Sequence[str] = (),
               tenant_idx: Optional[np.ndarray] = None,
               tenants: Sequence[str] = (),
               attempt: Optional[np.ndarray] = None,
               sort: bool = True) -> Trace:
    """Assemble a Trace from columns, filling SLO columns from the class
    mask (interactive -> paper defaults; batch -> ``batch_ttft_slo``)."""
    interactive = np.asarray(interactive, dtype=bool)
    n = interactive.shape[0]
    if ttft_slo is None:
        ttft_slo = np.where(interactive, INTERACTIVE_TTFT_SLO, batch_ttft_slo)
    elif np.ndim(ttft_slo) == 0:        # Python or NumPy scalar: broadcast
        ttft_slo = np.full(n, float(ttft_slo))
    if itl_slo is None:
        itl_slo = np.where(interactive, INTERACTIVE_ITL_SLO, BATCH_ITL_SLO)
    elif np.ndim(itl_slo) == 0:
        itl_slo = np.full(n, float(itl_slo))
    if model_idx is None:
        model_idx = np.zeros(n, dtype=np.int32)
    tr = Trace(arrival, prompt_len, output_len, interactive,
               ttft_slo, itl_slo, model_idx, tuple(models),
               origin_idx, tuple(origins),
               tenant_idx, tuple(tenants), attempt)
    return tr.sorted_by_arrival() if sort else tr


class TraceStream:
    """Arrival-ordered stream of :class:`Trace` chunks.

    The windowed replay path for traces too large to hold columnar in
    memory: ``repro.sim.trace_io.stream_trace`` yields file chunks, the
    event core's request cursor consumes them one at a time, and chunk
    boundaries are validated to be non-decreasing in arrival time (a
    streamed file must already be arrival-sorted — there is no global
    sort without the whole file).
    """

    def __init__(self, chunks):
        self._it = iter(chunks)
        self._last_t = -np.inf

    def __iter__(self):
        return self

    def __next__(self) -> Trace:
        chunk = next(self._it)
        while chunk.n == 0:
            chunk = next(self._it)
        chunk = chunk.sorted_by_arrival()   # sort BEFORE the boundary
        # check, or an unsorted chunk's early rows would sneak past it
        if float(chunk.arrival[0]) < self._last_t:
            raise ValueError(
                "TraceStream chunks are not globally arrival-sorted: chunk "
                f"starts at t={float(chunk.arrival[0]):.3f} after "
                f"t={self._last_t:.3f}")
        self._last_t = float(chunk.arrival[-1])
        return chunk


# ============================================================== generation
@dataclass
class WorkloadSpec:
    n_requests: int = 3500
    arrival_rate: float = 10.0        # requests/s
    interactive_frac: float = 1.0     # 1.0 = W_A; <1 adds batch requests
    process: str = "poisson"          # poisson | gamma
    cv: float = 1.0                   # Gamma coefficient of variation
    model: str = DEFAULT_MODEL
    batch_ttft_slo: float = 3600.0
    seed: int = 0
    # batch-queue mode (W_B): dump `batch_queue_size` batch requests at t=0
    batch_queue_size: int = 0


def _token_lengths(rng: np.random.Generator, n: int):
    ins = np.clip(rng.lognormal(INPUT_MU, INPUT_SIGMA, n), 4, MAX_TOKENS)
    outs = np.clip(rng.lognormal(OUTPUT_MU, OUTPUT_SIGMA, n), 4, MAX_TOKENS)
    return ins.astype(np.int64), outs.astype(np.int64)


def _interarrival(rng: np.random.Generator, spec: WorkloadSpec, n: int) -> np.ndarray:
    mean = 1.0 / max(spec.arrival_rate, 1e-9)
    if spec.process == "poisson":
        return rng.exponential(mean, n)
    # Gamma with CV: shape k = 1/cv^2, scale = mean*cv^2
    k = 1.0 / (spec.cv ** 2)
    return rng.gamma(k, mean * spec.cv ** 2, n)


def generate_trace(spec: WorkloadSpec) -> Trace:
    """Fully vectorized trace generation — no per-request Python work.

    Draw order matches the historical ``generate`` exactly (batch-queue
    token lengths, live token lengths, gaps, class coin flips) so seeds
    reproduce the same workloads they always did.
    """
    rng = np.random.default_rng(spec.seed)
    parts: List[Trace] = []

    if spec.batch_queue_size > 0:
        ins, outs = _token_lengths(rng, spec.batch_queue_size)
        parts.append(make_trace(
            np.zeros(spec.batch_queue_size), ins, outs,
            np.zeros(spec.batch_queue_size, dtype=bool),
            batch_ttft_slo=spec.batch_ttft_slo,
            models=(spec.model,), sort=False))

    n = spec.n_requests
    ins, outs = _token_lengths(rng, n)
    t = np.cumsum(_interarrival(rng, spec, n))
    classes = rng.random(n) < spec.interactive_frac
    parts.append(make_trace(t, ins, outs, classes,
                            batch_ttft_slo=spec.batch_ttft_slo,
                            models=(spec.model,), sort=False))
    out = parts[0] if len(parts) == 1 else Trace.concat(parts)
    return out.sorted_by_arrival()


def generate(spec: WorkloadSpec) -> List[Request]:
    """Historical API: generate and materialize (small/medium traces)."""
    return generate_trace(spec).materialize()


# ======================================================== arrival analysis
def _arrival_column(source) -> np.ndarray:
    """Arrival times from a Trace, an ndarray/sequence of floats, or a
    sequence of Request-likes (anything with ``.arrival_time``)."""
    if isinstance(source, Trace):
        return source.arrival
    if isinstance(source, np.ndarray):
        return source.astype(np.float64, copy=False)
    src = list(source)
    if not src:
        return np.empty(0)
    if hasattr(src[0], "arrival_time"):
        return np.fromiter((r.arrival_time for r in src), dtype=np.float64,
                           count=len(src))
    return np.asarray(src, dtype=np.float64)


def arrival_spikes(source, interval: float = 30.0) -> np.ndarray:
    """Paper §2.3: ratio of arrival rate between consecutive intervals of
    length = model load time. Used by the Theta-from-history heuristic.

    Vectorized: one ``np.bincount`` over the arrival column, a shifted
    ratio, and a mask — O(n + bins) with no per-request Python loop.
    """
    times = _arrival_column(source)
    if times.size == 0:
        return np.empty(0)
    counts = np.bincount((times / interval).astype(np.int64))
    prev, nxt = counts[:-1], counts[1:]
    mask = prev > 0
    return nxt[mask] / prev[mask]


def theta_from_history(source, interval: float = 30.0,
                       pct: float = 99.0) -> float:
    """Theta = 1 / tail-spike (paper §5.2 example: spike 3x -> Theta=1/3)."""
    spikes = arrival_spikes(source, interval)
    if spikes.size == 0:
        return 1.0 / 3.0
    tail = float(np.percentile(spikes, pct))
    return 1.0 / max(tail, 1.0)
