"""Workload generation: ShareGPT-like token distributions + arrival processes.

The paper's traces use 3,500 ShareGPT requests (Fig. 8 token distributions)
with Poisson arrivals for the main experiments and Gamma arrivals (varying
CV) for the burstiness robustness analysis (§6.3, Fig. 17).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterator, List, Optional

import numpy as np

from repro.serving.request import (Request, RequestType, SLO, make_batch,
                                   make_interactive)

# ShareGPT-ish lognormal parameters (Fig. 8: median input ~100 tokens with a
# heavy tail; outputs somewhat longer)
INPUT_MU, INPUT_SIGMA = 4.6, 1.0      # median ~100, mean ~165
OUTPUT_MU, OUTPUT_SIGMA = 5.2, 0.9    # median ~180, mean ~270
MAX_TOKENS = 2048


@dataclass
class WorkloadSpec:
    n_requests: int = 3500
    arrival_rate: float = 10.0        # requests/s
    interactive_frac: float = 1.0     # 1.0 = W_A; <1 adds batch requests
    process: str = "poisson"          # poisson | gamma
    cv: float = 1.0                   # Gamma coefficient of variation
    model: str = "llama-8b"
    batch_ttft_slo: float = 3600.0
    seed: int = 0
    # batch-queue mode (W_B): dump `batch_queue_size` batch requests at t=0
    batch_queue_size: int = 0


def _token_lengths(rng: np.random.Generator, n: int):
    ins = np.clip(rng.lognormal(INPUT_MU, INPUT_SIGMA, n), 4, MAX_TOKENS)
    outs = np.clip(rng.lognormal(OUTPUT_MU, OUTPUT_SIGMA, n), 4, MAX_TOKENS)
    return ins.astype(int), outs.astype(int)


def _interarrival(rng: np.random.Generator, spec: WorkloadSpec, n: int) -> np.ndarray:
    mean = 1.0 / max(spec.arrival_rate, 1e-9)
    if spec.process == "poisson":
        return rng.exponential(mean, n)
    # Gamma with CV: shape k = 1/cv^2, scale = mean*cv^2
    k = 1.0 / (spec.cv ** 2)
    return rng.gamma(k, mean * spec.cv ** 2, n)


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    reqs: List[Request] = []

    if spec.batch_queue_size > 0:
        ins, outs = _token_lengths(rng, spec.batch_queue_size)
        for i in range(spec.batch_queue_size):
            reqs.append(make_batch(int(ins[i]), int(outs[i]), 0.0,
                                   model=spec.model,
                                   ttft_slo=spec.batch_ttft_slo))

    n = spec.n_requests
    ins, outs = _token_lengths(rng, n)
    gaps = _interarrival(rng, spec, n)
    t = np.cumsum(gaps)
    classes = rng.random(n) < spec.interactive_frac
    for i in range(n):
        if classes[i]:
            reqs.append(make_interactive(int(ins[i]), int(outs[i]),
                                         float(t[i]), model=spec.model))
        else:
            reqs.append(make_batch(int(ins[i]), int(outs[i]), float(t[i]),
                                   model=spec.model,
                                   ttft_slo=spec.batch_ttft_slo))
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs


def arrival_spikes(reqs: List[Request], interval: float = 30.0) -> List[float]:
    """Paper §2.3: ratio of arrival rate between consecutive intervals of
    length = model load time. Used by the Theta-from-history heuristic."""
    if not reqs:
        return []
    end = max(r.arrival_time for r in reqs)
    nbins = int(end / interval) + 1
    counts = [0] * nbins
    for r in reqs:
        counts[int(r.arrival_time / interval)] += 1
    spikes = []
    for a, b in zip(counts, counts[1:]):
        if a > 0:
            spikes.append(b / a)
    return spikes


def theta_from_history(reqs: List[Request], interval: float = 30.0,
                       pct: float = 99.0) -> float:
    """Theta = 1 / tail-spike (paper §5.2 example: spike 3x -> Theta=1/3)."""
    spikes = arrival_spikes(reqs, interval)
    if not spikes:
        return 1.0 / 3.0
    tail = float(np.percentile(spikes, pct))
    return 1.0 / max(tail, 1.0)
