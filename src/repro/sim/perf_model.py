"""Analytic per-instance performance model (roofline-calibrated).

The paper measures ITL/throughput-vs-batch-size on A100s (Fig. 3); we
re-derive the same trade-off for the TPU-v5e target from first principles
(DESIGN.md §3 hardware adaptation):

  decode step time(b) = max(compute, memory) + collective + overhead
    memory   = (weight_bytes + kv_bytes(b)) / (chips * HBM_bw)
    compute  = 2 * N_active * b / (chips * peak_flops)
    collective = 2 * d_model * bytes * (tp-1)/tp * n_layers / link_bw  (TP allreduce)

  preemption: when the resident KV demand exceeds the pool, evicted
  requests must re-prefill; each re-prefill steals decode time, inflating
  ITL and bending throughput DOWN past an inflection point — the exact
  phenomenon Chiron's TBP metric detects (paper Fig. 3).

All constants are module-level and overridable for calibration tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.configs import get_config
from repro.configs.base import ModelConfig

# TPU v5e-class chip (task-given constants)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
HBM_BYTES = 16e9             # per chip
ICI_BW = 50e9                # bytes/s per link
BYTES_PER_PARAM = 2          # bf16 weights
STEP_OVERHEAD = 2e-3         # dispatch/sampling overhead per decode step
MFU_DECODE = 0.6             # achievable fraction of peak in decode GEMMs
MBU = 0.75                   # achievable HBM bandwidth fraction

# default tensor-parallel instance sizes (chips per serving instance)
INSTANCE_CHIPS: Dict[str, int] = {
    "llama-8b": 4, "llama-70b": 16,
    "olmo-1b": 1, "granite-8b": 4, "zamba2-2.7b": 2, "phi3-mini-3.8b": 2,
    "yi-34b": 8, "mamba2-1.3b": 1, "qwen2-moe-a2.7b": 4,
    "deepseek-moe-16b": 8, "whisper-base": 1, "internvl2-2b": 2,
}

# model-load times (paper: 15 s – 1 min; scaled with checkpoint size)
_LOAD_BW = 2e9               # bytes/s host->HBM per chip during model load


@dataclass
class PerfModel:
    """Latency/throughput/memory responses for one (model, instance) pair.

    Accelerator variants (heterogeneous fleets): the ``*_scale`` fields
    derate or boost the v5e-class baseline constants per chip generation —
    a fleet cluster built on a faster part passes ``flops_scale`` /
    ``hbm_bw_scale`` / ``hbm_bytes_scale`` > 1 and every latency, capacity,
    and throughput response shifts coherently (see
    ``repro.sim.fleet.ACCELERATORS``).
    """
    model_name: str
    chips: int = 0
    cfg: ModelConfig = None
    # optimization knobs that shift the optimum the local autoscaler finds
    # (paper Fig. 11): prefix caching preloads KV; spec decode adds draft work
    prefix_caching: bool = False
    speculative_decoding: bool = False
    prefix_hit_tokens: int = 512
    spec_draft_overhead: float = 0.15
    spec_accept_speedup: float = 2.0
    # accelerator-generation scaling vs the v5e-class baseline constants
    flops_scale: float = 1.0
    hbm_bw_scale: float = 1.0
    hbm_bytes_scale: float = 1.0

    def __post_init__(self):
        self.cfg = self.cfg or get_config(self.model_name)
        self.chips = self.chips or INSTANCE_CHIPS.get(self.model_name, 4)
        self.n_params = self.cfg.param_count()
        self.n_active = self.cfg.active_param_count()
        self.weight_bytes = self.n_params * BYTES_PER_PARAM
        # The hot-path responses (itl / can_admit) run millions of times per
        # simulation; fold every shape-derived constant once.
        self._kv_per_tok = self._kv_bytes_per_token()
        free = self.chips * HBM_BYTES * self.hbm_bytes_scale \
            - self.weight_bytes
        self._kv_cap = float("inf") if self._kv_per_tok <= 0 else \
            max(free, 0) * 0.9 / self._kv_per_tok   # 10% activation headroom
        mem_bw = self.chips * HBM_BW * self.hbm_bw_scale * MBU
        self._flops_per_s = self.chips * PEAK_FLOPS * self.flops_scale \
            * MFU_DECODE
        self._mem_t_base = self.weight_bytes / mem_bw
        self._mem_t_per_kvtok = self._kv_per_tok / mem_bw
        self._comp_t_per_seq = 2 * self.n_active / self._flops_per_s
        self._coll_t = 0.0
        if self.chips > 1:
            coll_bytes = 2 * self.cfg.d_model * BYTES_PER_PARAM * \
                self.cfg.n_layers * (self.chips - 1) / self.chips
            self._coll_t = coll_bytes / ICI_BW

    # ------------------------------------------------------------ memory
    def _kv_bytes_per_token(self) -> float:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if cfg.arch_type == "ssm":
            return 0.0  # O(1) state, amortized to ~0 per token
        n_attn_layers = cfg.n_layers
        if cfg.arch_type == "hybrid":
            n_attn_layers = cfg.n_layers // max(cfg.attn_every, 1)
        return 2 * n_attn_layers * cfg.n_kv_heads * hd * BYTES_PER_PARAM

    def kv_bytes_per_token(self) -> float:
        return self._kv_per_tok

    def kv_capacity_tokens(self) -> float:
        return self._kv_cap

    # ------------------------------------------------------------ latency
    def prefill_time(self, prompt_len: int) -> float:
        eff_len = prompt_len
        if self.prefix_caching:
            eff_len = max(prompt_len - self.prefix_hit_tokens, 16)
        flops = 2 * self.n_active * eff_len
        return flops / self._flops_per_s + STEP_OVERHEAD

    def itl(self, batch_size: int, mean_ctx: float = 1024.0) -> float:
        """Inter-token latency at a given running batch size."""
        b = max(batch_size, 1)
        mem_t = self._mem_t_base + b * mean_ctx * self._mem_t_per_kvtok
        comp_t = b * self._comp_t_per_seq
        t = max(mem_t, comp_t) + self._coll_t + STEP_OVERHEAD
        if self.speculative_decoding:
            t = t * (1 + self.spec_draft_overhead * math.sqrt(b)) \
                / self.spec_accept_speedup
        # preemption inflation past the KV-capacity inflection point
        t *= self.preemption_factor(b, mean_ctx)
        return t

    def preemption_factor(self, batch_size: int, mean_ctx: float) -> float:
        """ITL multiplier from eviction/re-prefill past KV capacity."""
        cap = self.kv_capacity_tokens()
        if not math.isfinite(cap):
            return 1.0
        eff_ctx = mean_ctx
        if self.prefix_caching:
            eff_ctx = mean_ctx + self.prefix_hit_tokens  # preloaded prefix KV
        demand = batch_size * eff_ctx
        if demand <= cap:
            return 1.0
        over = demand / cap - 1.0
        # each over-capacity fraction triggers re-prefills worth ~ctx tokens
        return 1.0 + 4.0 * over + 8.0 * over * over

    def throughput(self, batch_size: int, mean_ctx: float = 1024.0) -> float:
        """Aggregate decode tokens/s at a given batch size."""
        return batch_size / self.itl(batch_size, mean_ctx)

    def max_stable_batch(self, mean_ctx: float = 1024.0) -> int:
        return int(self.kv_capacity_tokens() / max(mean_ctx, 1))

    # ------------------------------------------------------------ scaling
    def model_load_time(self) -> float:
        return max(15.0, min(self.weight_bytes / (self.chips * _LOAD_BW), 60.0))

    def optimal_batch(self, itl_slo: float, mean_ctx: float = 1024.0,
                      max_batch: int = 4096) -> int:
        """Largest batch meeting the ITL SLO without throughput regression —
        the fixed point Algorithm 1 converges to (used by tests/benches)."""
        best, best_b = 0.0, 1
        for b in range(1, max_batch + 1):
            t = self.itl(b, mean_ctx)
            thr = b / t
            if t > itl_slo:
                break
            if thr <= best:
                break
            best, best_b = thr, b
        return best_b
