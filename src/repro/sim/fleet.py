"""Multi-cluster placement & routing plane: the third tier above Chiron.

The paper's hierarchy stops at one cluster with one shared chip budget.
This module scales it out the way a cloud provider runs it (SageServe,
arXiv:2502.14617): a *fleet* of regional clusters — each wrapping its own
:class:`~repro.sim.cluster.SimCluster`, its own
:class:`~repro.serving.global_queue.GlobalQueue`, and its own full Chiron
hierarchy (per-model IBP + Algorithm-2 loops on a per-cluster chip
budget) — coordinated by two fleet-level components:

- :class:`Router` — assigns every arriving request to a cluster by SLO
  headroom: interactive requests go to the lowest-latency cluster (from
  the request's origin region) that still has capacity, spilling over to
  farther clusters on saturation; batch requests go to the cheapest
  backpressure-positive cluster ($ per generated token, so heterogeneous
  accelerators rank correctly), falling back to the least-backlogged one.
- :class:`GlobalPlacer` — decides *which models are resident in which
  clusters* from windowed EWMA arrival-rate forecasts per (model, origin
  region), re-estimates per-model Theta with the existing
  ``theta_from_history`` machinery and pushes it down to every cluster
  controller, consolidates each model's batch work onto the cheapest
  capable cluster, migrates residency with explicit warm-up delay events
  (weights transfer over WAN + load), drains placements whose demand
  evaporated, and hands queued batch work back for re-routing when a
  cluster saturates.

Accelerator heterogeneity rides on :class:`~repro.sim.perf_model.PerfModel`
variants (``ACCELERATORS``): each cluster's perf factory applies its chip
generation's FLOPs/HBM scales, so ITL, KV capacity, and cost-per-token
all shift coherently.

The event loop that drives a Fleet is
:func:`repro.sim.simulator.simulate_fleet`; scenario builders in
``repro.sim.scenarios`` (``multi_region``, ``regional_spillover``,
``heterogeneous_accelerators``) construct ready-made fleets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.global_queue import GlobalQueue
from repro.serving.request import BATCH_ITL_SLO, Request
from repro.sim.cluster import InstanceType, SimCluster
from repro.sim.controllers import ChironController
from repro.sim.metrics import ClusterStats
from repro.sim.overload import BreakerConfig, CircuitBreaker
from repro.sim.simulator import default_perf_factory
from repro.sim.workload import DEFAULT_MODEL, theta_from_history

# accelerator catalogue: perf scales are applied to the v5e-class baseline
# constants in perf_model; $/chip-hour tracks the list-price ordering
# (premium part fastest and dearest, previous-gen part slow but cheap —
# the natural batch home)
ACCELERATORS: Dict[str, Dict] = {
    "v5e": dict(cost_per_chip_hour=1.20, perf_kw={}),
    "v5p": dict(cost_per_chip_hour=2.60,
                perf_kw=dict(flops_scale=2.33, hbm_bw_scale=3.35,
                             hbm_bytes_scale=5.94)),
    "v4e": dict(cost_per_chip_hour=0.55,
                perf_kw=dict(flops_scale=0.60, hbm_bw_scale=0.75,
                             hbm_bytes_scale=1.0)),
}

TOKEN_BYTES = 4          # request/response payload bytes per token (egress)


@dataclass(frozen=True)
class Region:
    """A geographic serving region — the latency and egress domain
    requests originate from and clusters live in."""
    name: str


@dataclass
class ClusterSpec:
    """Static description of one fleet cluster."""
    name: str
    region: str
    max_chips: int = 200
    accelerator: str = "v5e"
    cost_per_chip_hour: Optional[float] = None   # None -> accelerator default
    load_time: Optional[float] = None            # instance bring-up override


class FleetTopology:
    """Inter-region network model: one-way latency (seconds) and egress
    pricing. Pairs absent from ``latency`` fall back to ``inter_latency``
    (``intra_latency`` within a region); entries are symmetric."""

    def __init__(self, regions: Sequence, *,
                 latency: Optional[Dict[Tuple[str, str], float]] = None,
                 intra_latency: float = 0.002, inter_latency: float = 0.08,
                 egress_cost_per_gb: float = 0.08):
        self.regions = [r.name if isinstance(r, Region) else str(r)
                        for r in regions]
        self.intra_latency = intra_latency
        self.inter_latency = inter_latency
        self.egress_cost_per_gb = egress_cost_per_gb
        self._lat: Dict[Tuple[str, str], float] = {}
        for (a, b), v in (latency or {}).items():
            self._lat[(a, b)] = float(v)
            self._lat[(b, a)] = float(v)

    def latency(self, a: str, b: str) -> float:
        if a == b:
            return self.intra_latency
        return self._lat.get((a, b), self.inter_latency)


class FleetCluster:
    """One cluster in the fleet: SimCluster + queue + Chiron controller +
    residency set + rollup stats, under one per-cluster chip budget."""

    def __init__(self, spec: ClusterSpec, *, models: Sequence[str],
                 controller_kw: Optional[Dict] = None,
                 perf_kw: Optional[Dict] = None):
        acc = ACCELERATORS[spec.accelerator]
        kw = dict(acc["perf_kw"])
        kw.update(perf_kw or {})
        self.spec = spec
        self.perf_factory = default_perf_factory(**kw)
        self.cluster = SimCluster(self.perf_factory,
                                  max_chips=spec.max_chips,
                                  load_time=spec.load_time)
        ckw = dict(controller_kw or {})
        ckw.setdefault("models", list(models))
        self.controller = ChironController(**ckw)
        self.queue = GlobalQueue()
        # model -> "warming" (weights in flight) | "active" (serving)
        self.resident: Dict[str, str] = {}
        self.cost_per_chip_hour = spec.cost_per_chip_hour \
            if spec.cost_per_chip_hour is not None \
            else acc["cost_per_chip_hour"]
        self.stats = ClusterStats(name=spec.name, region=spec.region,
                                  accelerator=spec.accelerator,
                                  cost_per_chip_hour=self.cost_per_chip_hour)
        self._batch_cache: Dict[str, Tuple[float, int]] = {}
        self._itl_cache: Dict[str, float] = {}

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def region(self) -> str:
        return self.spec.region

    def free_chips(self) -> int:
        return self.cluster.max_chips - self.cluster.used_chips()

    # --------------------------------------------------- headroom queries
    def _batch_point(self, model: str) -> Tuple[float, int]:
        """($ per Mtoken, SLO-optimal batch size) for batch work here —
        the accelerator-aware ranking key the router and placer share."""
        c = self._batch_cache.get(model)
        if c is None:
            perf = self.perf_factory(model)
            b = perf.optimal_batch(BATCH_ITL_SLO, mean_ctx=512.0)
            thr = perf.throughput(b, mean_ctx=512.0)
            cost = self.cost_per_chip_hour * perf.chips \
                / max(thr * 3600.0, 1e-9) * 1e6
            c = self._batch_cache[model] = (cost, b)
        return c

    def batch_cost_per_mtoken(self, model: str) -> float:
        return self._batch_point(model)[0]

    def interactive_itl(self, model: str) -> float:
        """Reference decode latency (small batch) — ranks accelerator
        generations for interactive placement at equal network latency."""
        itl = self._itl_cache.get(model)
        if itl is None:
            itl = self._itl_cache[model] = \
                self.perf_factory(model).itl(8, mean_ctx=512.0)
        return itl

    def interactive_headroom(self, model: str) -> float:
        """Spare interactive capacity: free slots on healthy
        interactive/mixed instances plus room to grow in the chip budget
        (discounted — a new instance takes a model load to arrive)."""
        slots = 0
        for itype in (InstanceType.INTERACTIVE, InstanceType.MIXED):
            for i in self.cluster.by_model(model, itype):
                if i.active and not i.suspected_slow:
                    slots += max(i.max_batch_size - i.n_running, 0)
        growth = self.free_chips() // self.perf_factory(model).chips
        return slots + 8 * growth

    def batch_headroom(self, model: str) -> float:
        """Backpressure sign for batch routing: spare healthy batch/mixed
        slots plus budget growth at the SLO-optimal batch size, minus the
        work already queued here. Positive = this cluster can absorb."""
        slots = 0
        for itype in (InstanceType.BATCH, InstanceType.MIXED):
            for i in self.cluster.by_model(model, itype):
                if i.active and not i.suspected_slow:
                    slots += max(i.max_batch_size - i.n_running, 0)
        _, b = self._batch_point(model)
        growth = (self.free_chips() // self.perf_factory(model).chips) * b
        return slots + growth - self.queue.n_batch_for(model)

    def has_model_work(self, model: str) -> bool:
        return bool(self.queue.n_interactive_for(model)
                    or self.queue.n_batch_for(model)
                    or any(i.n_running
                           for i in self.cluster.instances_of(model)))


@dataclass
class Router:
    """Tier-3 request routing by SLO headroom (bound to a Fleet).

    Candidate orders are static for a fixed residency set — latency,
    reference ITL, and $/Mtoken are all static per (model, origin) — so
    they are cached and invalidated by the fleet's ``residency_epoch``
    instead of re-sorted on every arrival (the per-arrival hot path of
    ``simulate_fleet``).

    ``breaker`` arms per-cluster circuit breakers on the admission
    rejection-rate EWMA (fed by ``simulate_fleet`` when the overload
    plane is on): routing skips clusters whose breaker is open,
    deflecting to the next candidate at the price of the network hop;
    after the cooldown a half-open breaker admits trial traffic and
    closes on consecutive accepts. Transitions are stamped into the obs
    decision ledger (state code in the row's ``itype`` slot)."""

    breaker: Optional[BreakerConfig] = None

    def bind(self, fleet: "Fleet") -> None:
        self._fleet = fleet
        self._iorder: Dict[Tuple[str, str], Tuple[int, list]] = {}
        self._border: Dict[str, Tuple[int, list]] = {}
        self._breakers: Dict[str, CircuitBreaker] = {}
        if self.breaker is not None:
            self._breakers = {fc.name: CircuitBreaker(self.breaker)
                              for fc in fleet.clusters}

    # ------------------------------------------------- circuit breakers
    def breaker_for(self, fc: FleetCluster) -> Optional[CircuitBreaker]:
        return self._breakers.get(fc.name)

    def note_admission(self, fc: FleetCluster, rejected: bool,
                       now: float) -> Optional[Tuple[int, float]]:
        """Feed one admission outcome at ``fc`` into its breaker;
        returns ``(new_state_code, ewma)`` on a transition, else None."""
        brk = self._breakers.get(fc.name)
        if brk is None:
            return None
        new_state = brk.record(rejected, now)
        if new_state is None:
            return None
        return new_state, brk.ewma

    def _allowed(self, fc: FleetCluster, now: float) -> bool:
        """May traffic route to ``fc``? Stamps the open -> half-open
        cooldown transition when it happens here."""
        brk = self._breakers.get(fc.name)
        if brk is None:
            return True
        before = brk.state
        ok = brk.allows(now)
        if brk.state != before and self._fleet.obs is not None:
            self._fleet.obs.record_breaker(now, fc.name, brk.state,
                                           brk.ewma,
                                           brk.cfg.open_threshold)
        return ok

    def _actives_interactive(self, model: str, origin: str) -> list:
        fleet = self._fleet
        ep = fleet.residency_epoch
        c = self._iorder.get((model, origin))
        if c is None or c[0] != ep:
            topo = fleet.topology
            order = sorted((fc for fc in fleet.clusters
                            if fc.resident.get(model) == "active"),
                           key=lambda fc: (topo.latency(origin, fc.region),
                                           fc.interactive_itl(model),
                                           fc.name))
            self._iorder[(model, origin)] = c = (ep, order)
        return c[1]

    def _actives_batch(self, model: str) -> list:
        fleet = self._fleet
        ep = fleet.residency_epoch
        c = self._border.get(model)
        if c is None or c[0] != ep:
            order = sorted((fc for fc in fleet.clusters
                            if fc.resident.get(model) == "active"),
                           key=lambda fc: (fc.batch_cost_per_mtoken(model),
                                           fc.name))
            self._border[model] = c = (ep, order)
        return c[1]

    def route(self, req: Request, now: float) -> Tuple[FleetCluster, float]:
        """Pick the serving cluster; returns ``(cluster, network_delay)``.
        The delay is the origin->region latency — the fleet loop enqueues
        the request there only after it, so remote TTFT pays the hop."""
        fleet = self._fleet
        topo = fleet.topology
        origin = req.origin if req.origin else topo.regions[0]
        fc = self.pick(req, now)
        if fc.region != origin:
            fc.stats.remote_served += 1
            # prompt payload crosses origin -> serving region now; the
            # response is charged at completion (tokens actually made)
            fleet.add_egress(None, req.prompt_len * TOKEN_BYTES)
        return fc, topo.latency(origin, fc.region)

    def pick(self, req: Request, now: float) -> FleetCluster:
        """Destination selection only — no latency or egress accounting
        (``Fleet.drain`` re-dispatches through this and accounts the hop
        from the cluster the work actually leaves)."""
        fleet = self._fleet
        origin = req.origin if req.origin else fleet.topology.regions[0]
        model = req.model
        if req.is_interactive:
            fc = self._pick_interactive(model, origin, now)
        else:
            fc = self._pick_batch(model, now)
        if fc is None:
            # cold start: nothing resident anywhere — nearest cluster with
            # budget becomes the model's discovered (floor-less) home
            fc = fleet.closest_cluster(origin, model) or fleet.clusters[0]
            if fc.resident.setdefault(model, "active") == "active":
                fleet.residency_epoch += 1
        return fc

    def _pick_interactive(self, model: str, origin: str,
                          now: float) -> Optional[FleetCluster]:
        """Lowest latency with capacity; spill farther on saturation
        (and around open breakers — the hop is the deflection price);
        wait at the nearest routable cluster when the fleet is full."""
        order = self._actives_interactive(model, origin)
        if self._breakers:
            routable = [fc for fc in order if self._allowed(fc, now)]
            if routable:                 # every breaker open: route anyway
                order = routable
        for fc in order:
            if fc.interactive_headroom(model) > 0:
                return fc
        return order[0] if order else None

    def _pick_batch(self, model: str,
                    now: float) -> Optional[FleetCluster]:
        """Cheapest backpressure-positive cluster (placer's consolidation
        target first); least-backlogged when every cluster is saturated.
        Open breakers deflect batch work like interactive."""
        order = self._actives_batch(model)
        if not order:
            return None
        if self._breakers:
            routable = [fc for fc in order if self._allowed(fc, now)]
            if routable:
                order = routable
        tname = self._fleet.placer.batch_target.get(model)
        if tname is not None:
            tfc = self._fleet.by_name.get(tname)
            if tfc is not None and tfc in order:
                order = [tfc] + [fc for fc in order if fc is not tfc]
        for fc in order:
            if fc.batch_headroom(model) > 0:
                return fc
        return max(order, key=lambda fc: (fc.batch_headroom(model),
                                          fc.name))


@dataclass
class GlobalPlacer:
    """Forecast-driven model placement across the fleet (tier 3 control).

    Every ``interval`` seconds the placer reviews EWMA arrival-rate
    forecasts per (model, origin region): regions with real interactive
    demand get a resident copy in their closest capable cluster; each
    model's batch work is consolidated onto the cheapest cluster with
    capacity (migrating residency there when the saving clears
    ``migration_cost_margin``); placements idle for ``drain_strikes``
    consecutive reviews drain away; and saturated batch queues hand work
    back for re-routing. Residency additions are *not* instantaneous —
    weights transfer over ``wan_bw`` and load, surfaced as warm-up delay
    events on the simulator heap.
    """
    interval: float = 30.0
    ewma_alpha: float = 0.4
    place_rate_min: float = 0.5      # req/s regional demand worth a copy
    drain_strikes: int = 3
    wan_bw: float = 1.25e9           # bytes/s cross-region weight transfer
    handback_queue_min: int = 64
    migration_cost_margin: float = 0.8
    theta_refresh: float = 120.0
    theta_history: int = 4096

    def __post_init__(self):
        self._fleet: Optional["Fleet"] = None
        self._win_i: Dict[Tuple[str, str], int] = {}
        self._win_b: Dict[str, int] = {}
        self._rate_i: Dict[Tuple[str, str], float] = {}
        self._rate_b: Dict[str, float] = {}
        self._models: set = set()
        self._arrivals: Dict[str, List[float]] = {}
        self._next_theta: Dict[str, float] = {}
        self._strikes: Dict[Tuple[str, str], int] = {}
        self._last_review = 0.0
        self.batch_target: Dict[str, str] = {}

    def bind(self, fleet: "Fleet") -> None:
        self._fleet = fleet

    # ------------------------------------------------------------ intake
    def observe_arrival(self, req: Request, now: float) -> None:
        model = req.model
        self._models.add(model)
        if req.is_interactive:
            origin = req.origin if req.origin else \
                self._fleet.topology.regions[0]
            key = (model, origin)
            self._win_i[key] = self._win_i.get(key, 0) + 1
            self._arrivals.setdefault(model, []).append(now)
        else:
            self._win_b[model] = self._win_b.get(model, 0) + 1

    # ------------------------------------------------------------ review
    def review(self, now: float, emit_warm) \
            -> List[Tuple[Request, FleetCluster, float]]:
        """One placement pass; returns handed-back requests to re-dispatch
        as ``(request, destination, network_delay)``."""
        fleet = self._fleet
        dt = max(now - self._last_review, 1e-9)
        self._last_review = now
        # sorted: set-union iteration order is address-dependent, and the
        # update order decides the rate dicts' insertion order downstream
        for key in sorted(set(self._rate_i) | set(self._win_i)):
            obs = self._win_i.get(key, 0) / dt
            r = self._rate_i.get(key, 0.0)
            self._rate_i[key] = r + self.ewma_alpha * (obs - r)
        for m in sorted(set(self._rate_b) | set(self._win_b)):
            obs = self._win_b.get(m, 0) / dt
            r = self._rate_b.get(m, 0.0)
            self._rate_b[m] = r + self.ewma_alpha * (obs - r)
        self._win_i.clear()
        self._win_b.clear()

        redispatch: List[Tuple[Request, FleetCluster, float]] = []
        for model in sorted(self._models):
            self._refresh_theta(model, now)
            self._place_interactive(model, now, emit_warm)
            self._place_batch(model, now, emit_warm)
            self._drain_idle(model, now, redispatch)
            self._hand_back(model, now, redispatch)
        return redispatch

    def _refresh_theta(self, model: str, now: float) -> None:
        """The paper's Theta-from-history heuristic, fleet-wide: one
        arrival stream per model feeds every resident controller."""
        nxt = self._next_theta.get(model, 0.0)
        if now < nxt:
            return
        self._next_theta[model] = now + self.theta_refresh
        arrivals = self._arrivals.get(model, [])
        if len(arrivals) > self.theta_history:
            del arrivals[:-self.theta_history]
        if len(arrivals) < 20:
            return
        theta = theta_from_history(np.asarray(arrivals), 30.0)
        for fc in self._fleet.clusters:
            scaler = fc.controller.interactive_scalers.get(model)
            if scaler is not None:
                scaler.theta = theta

    def _place_interactive(self, model: str, now: float, emit_warm) -> None:
        for region in self._fleet.topology.regions:
            if self._rate_i.get((model, region), 0.0) < self.place_rate_min:
                continue
            fc = self._fleet.closest_cluster(region, model)
            if fc is not None:
                self.ensure_resident(model, fc, now, emit_warm)

    def _place_batch(self, model: str, now: float, emit_warm) -> None:
        fleet = self._fleet
        has_batch = self._rate_b.get(model, 0.0) > 0.0 or \
            any(fc.queue.n_batch_for(model) for fc in fleet.clusters)
        if not has_batch:
            return
        ranked = sorted(fleet.clusters, key=lambda fc:
                        (fc.batch_cost_per_mtoken(model), fc.name))
        resident = [fc for fc in ranked
                    if fc.resident.get(model) == "active"]
        if not resident:
            if ranked:
                self.ensure_resident(model, ranked[0], now, emit_warm)
            return
        best, cur = ranked[0], resident[0]
        if best is not cur and best.resident.get(model) is None \
                and best.batch_cost_per_mtoken(model) < \
                self.migration_cost_margin * cur.batch_cost_per_mtoken(model) \
                and best.free_chips() >= best.perf_factory(model).chips:
            # meaningfully cheaper home with room: start the migration —
            # the target flips once it finishes warming
            self.ensure_resident(model, best, now, emit_warm)
        target = next((fc for fc in resident
                       if fc.batch_headroom(model) > 0), resident[0])
        self.batch_target[model] = target.name

    def _drain_idle(self, model: str, now: float, redispatch) -> None:
        """Placements neither needed (demand, batch target) nor busy for
        ``drain_strikes`` consecutive reviews drain away — never the last
        active copy."""
        fleet = self._fleet
        needed = set()
        t = self.batch_target.get(model)
        if t is not None:
            needed.add(t)
        for region in fleet.topology.regions:
            if self._rate_i.get((model, region), 0.0) >= \
                    0.5 * self.place_rate_min:
                fc = fleet.closest_cluster(region, model)
                if fc is not None:
                    needed.add(fc.name)
        actives = [fc for fc in fleet.clusters
                   if fc.resident.get(model) == "active"]
        for fc in list(actives):
            key = (model, fc.name)
            if fc.name in needed or fc.has_model_work(model):
                self._strikes.pop(key, None)
                continue
            strikes = self._strikes.get(key, 0) + 1
            self._strikes[key] = strikes
            if strikes >= self.drain_strikes and len(actives) > 1:
                self._strikes.pop(key, None)
                redispatch.extend(fleet.drain(model, fc, now))
                actives.remove(fc)

    def _hand_back(self, model: str, now: float, redispatch) -> None:
        """Saturation hand-back: a budget-full cluster with a deep batch
        queue surrenders half of it to the cheapest cluster that can
        absorb the work."""
        fleet = self._fleet
        for fc in fleet.clusters:
            qn = fc.queue.n_batch_for(model)
            if qn < self.handback_queue_min:
                continue
            if fc.free_chips() >= fc.perf_factory(model).chips:
                continue                 # can still grow locally
            alts = [a for a in fleet.clusters
                    if a is not fc and a.resident.get(model) == "active"
                    and a.batch_headroom(model) > qn // 2]
            if not alts:
                continue
            alt = min(alts, key=lambda a:
                      (a.batch_cost_per_mtoken(model), a.name))
            delay = fleet.topology.latency(fc.region, alt.region)
            moved = 0
            for _ in range(qn // 2):
                r = fc.queue.pop_batch_fcfs(model)
                if r is None:
                    break
                # the work leaves this cluster: any host-saved KV stays
                # behind (the receiver must re-prefill), cross-region
                # hand-offs move the prompt payload again, and the
                # receiver tallies a cross-region assignment — same
                # accounting as a Router cross-region route
                r.saved_kv = None
                if alt.region != fc.region:
                    fleet.add_egress(fc, r.prompt_len * TOKEN_BYTES)
                if r.origin and alt.region != r.origin:
                    alt.stats.remote_served += 1
                redispatch.append((r, alt, delay))
                moved += 1
            fc.stats.handbacks += moved
            fleet.handbacks += moved
            if moved and fleet.obs is not None:
                fleet.obs.record_handback(now, fc.name, alt.name, model,
                                          moved)

    # --------------------------------------------------------- migrations
    def ensure_resident(self, model: str, fc: FleetCluster, now: float,
                        emit_warm) -> None:
        """Make ``model`` resident in ``fc`` (no-op if it already is or is
        warming). Weights come from the nearest active copy — cross-region
        transfers pay WAN time and egress — and the placement only serves
        after the warm-up event fires."""
        if fc.resident.get(model) in ("warming", "active"):
            return
        fleet = self._fleet
        perf = fc.perf_factory(model)
        delay = perf.model_load_time()
        sources = [s for s in fleet.clusters if s is not fc
                   and s.resident.get(model) == "active"]
        if sources:
            src = min(sources, key=lambda s:
                      (fleet.topology.latency(fc.region, s.region), s.name))
            if src.region != fc.region:
                delay += perf.weight_bytes / self.wan_bw
                fleet.add_egress(src, perf.weight_bytes)
        fc.resident[model] = "warming"
        fc.stats.migrations_in += 1
        fleet.migrations += 1
        if fleet.obs is not None:
            fleet.obs.record_migration(now, fc.name, model, delay)
        emit_warm(delay, (model, fc))


class Fleet:
    """The multi-cluster serving plane ``simulate_fleet`` drives.

    ``placement`` maps model -> cluster names initially resident (default:
    every model everywhere). Clusters with no initial placement idle until
    the placer or a cold-start route gives them one.
    """

    def __init__(self, specs: Sequence[ClusterSpec],
                 topology: Optional[FleetTopology] = None, *,
                 models: Sequence[str] = (DEFAULT_MODEL,),
                 placement: Optional[Dict[str, Sequence[str]]] = None,
                 controller_kw: Optional[Dict] = None,
                 perf_kw: Optional[Dict] = None,
                 placer: Optional[GlobalPlacer] = None,
                 router: Optional[Router] = None):
        specs = list(specs)
        if not specs:
            raise ValueError("a Fleet needs at least one ClusterSpec")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate cluster names: {names}")
        if topology is None:
            topology = FleetTopology(sorted({s.region for s in specs}))
        self.topology = topology
        self.models = list(models)
        if placement is None:
            placement = {m: names for m in self.models}
        self.clusters: List[FleetCluster] = []
        self.by_name: Dict[str, FleetCluster] = {}
        for s in specs:
            placed = sorted(m for m, cs in placement.items()
                            if s.name in cs)
            fc = FleetCluster(s, models=placed or [self.models[0]],
                              controller_kw=controller_kw, perf_kw=perf_kw)
            if not placed:
                # the controller needs a primary model; un-pin it so this
                # cluster holds no floor until the placer assigns work
                fc.controller.set_model_placed(self.models[0], False)
            for m in placed:
                fc.resident[m] = "active"
            self.clusters.append(fc)
            self.by_name[s.name] = fc
        self.placer = placer or GlobalPlacer()
        self.placer.bind(self)
        self.router = router or Router()
        self.router.bind(self)
        self.migrations = 0
        self.handbacks = 0
        self.egress_bytes = 0.0
        self.egress_cost_usd = 0.0
        # flight recorder (repro.obs) attached by simulate_fleet when
        # telemetry is armed; tier-3 actions land in its decision ledger
        self.obs = None
        # bumped whenever some model's set of active residencies changes;
        # the Router's cached candidate orders key on it
        self.residency_epoch = 0

    # ------------------------------------------------------------ helpers
    def add_egress(self, src: Optional[FleetCluster], nbytes: float) -> None:
        if src is not None:
            src.stats.egress_bytes += nbytes
        self.egress_bytes += nbytes
        self.egress_cost_usd += nbytes / 1e9 \
            * self.topology.egress_cost_per_gb

    def closest_cluster(self, region: str,
                        model: str) -> Optional[FleetCluster]:
        """Lowest-latency cluster from ``region`` that either already
        serves ``model`` or has budget to start."""
        order = sorted(self.clusters, key=lambda fc:
                       (self.topology.latency(region, fc.region), fc.name))
        for fc in order:
            if fc.resident.get(model) == "active" or \
                    fc.free_chips() >= fc.perf_factory(model).chips:
                return fc
        return order[0] if order else None

    # ------------------------------------------- simulate_fleet protocol
    def observe_arrival(self, req: Request, now: float) -> None:
        self.placer.observe_arrival(req, now)

    def route(self, req: Request, now: float) -> Tuple[FleetCluster, float]:
        return self.router.route(req, now)

    def review(self, now: float, emit_warm):
        return self.placer.review(now, emit_warm)

    def on_warm(self, payload, now: float) -> None:
        model, fc = payload
        if fc.resident.get(model) == "warming":
            fc.resident[model] = "active"
            fc.controller.set_model_placed(model, True)
            self.residency_epoch += 1

    def drain(self, model: str, fc: FleetCluster, now: float) \
            -> List[Tuple[Request, FleetCluster, float]]:
        """Remove a residency; queued work is handed back for re-routing
        (running work finishes where it is, then the floor-less local
        fleet scales itself away). The hop is accounted from *this*
        cluster — the work physically leaves here, not the origin — and
        any host-saved KV stays behind (another cluster's hosts never
        held it), so moved requests re-prefill at the destination."""
        fc.resident.pop(model, None)
        fc.controller.set_model_placed(model, False)
        fc.stats.migrations_out += 1
        self.residency_epoch += 1
        out = []
        for r in fc.queue.drain_model(model):
            r.saved_kv = None
            dest = self.router.pick(r, now)
            if dest.region != fc.region:
                self.add_egress(fc, r.prompt_len * TOKEN_BYTES)
            if r.origin and dest.region != r.origin:
                dest.stats.remote_served += 1
            out.append((r, dest,
                        self.topology.latency(fc.region, dest.region)))
        if self.obs is not None:
            self.obs.record_drain(now, fc.name, model, len(out))
        return out

    def observe_completion(self, req: Request, fc: FleetCluster,
                           now: float) -> None:
        st = fc.stats
        met = req.slo_met()
        if req.is_interactive:
            st.served_interactive += 1
            st.slo_met_interactive += met
        else:
            st.served_batch += 1
            st.slo_met_batch += met
        if req.origin and fc.region != req.origin:
            # response tokens travel back to the origin region
            self.add_egress(fc, req.tokens_generated * TOKEN_BYTES)

    def finalize(self) -> List[ClusterStats]:
        """Copy terminal SimCluster counters into the per-cluster stats
        (called by ``simulate_fleet`` when the run ends)."""
        for fc in self.clusters:
            st = fc.stats
            st.chip_seconds = fc.cluster.chip_seconds
            st.peak_chips = fc.cluster.peak_chips
            st.scale_ups = fc.cluster.scale_ups
            st.scale_downs = fc.cluster.scale_downs
            st.failures = fc.cluster.failures
            st.degradations = fc.cluster.degradations
        return [fc.stats for fc in self.clusters]
