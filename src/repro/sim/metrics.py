"""Experiment metrics: SLO attainment, throughput, GPU efficiency,
hysteresis — plus per-cluster/per-region rollups for fleet runs.

When a run carries a :class:`repro.sim.ledger.RequestLedger` (the event
engines always install one), every aggregate — SLO attainment, per-model
rollups, completion rate, token totals, mean ITL, TTFT percentiles — is a
vectorized reduction over the ledger columns instead of a Python loop
over a million ``Request`` objects; the object path is kept as the
reference for ledger-less runs (fixed tick, hand-built results)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.request import Request, RequestState, RequestType
from repro.sim.ledger import (EXPIRED, FINISHED, REJECTED, RequestLedger,
                              SHED)


@dataclass
class TimelinePoint:
    t: float
    n_interactive: int
    n_mixed: int
    n_batch: int
    chips: int
    q_interactive: int
    q_batch: int
    tokens_per_s: float


class Timeline:
    """Columnar run timeline: one struct-of-arrays row per sample
    (amortized-doubling backing, the :class:`RequestLedger` idiom)
    instead of a ``TimelinePoint`` object per sample, plus per-model
    queue-depth columns the flat tuple could not express.

    The object view survives for back-compat: iteration, indexing
    (negative included) and slicing materialize :class:`TimelinePoint`
    views lazily, so ``timeline[-1].t`` and every existing consumer keep
    working; vectorized consumers read :meth:`col` directly."""

    _COLUMNS = (
        ("t", np.float64, 0.0), ("n_interactive", np.int32, 0),
        ("n_mixed", np.int32, 0), ("n_batch", np.int32, 0),
        ("chips", np.int32, 0), ("q_interactive", np.int32, 0),
        ("q_batch", np.int32, 0), ("tokens_per_s", np.float64, 0.0),
    )
    __slots__ = ("n", "_cap", "_backing", "_q_int_models",
                 "_q_batch_models")

    def __init__(self):
        self.n = 0
        self._cap = 0
        self._backing: Dict[str, np.ndarray] = {}
        # model -> per-sample queue-depth column; created zero-filled on
        # a model's first nonzero depth (rows before that are correctly
        # zero — the lane did not exist yet)
        self._q_int_models: Dict[str, np.ndarray] = {}
        self._q_batch_models: Dict[str, np.ndarray] = {}

    def _reserve(self, extra: int) -> None:
        need = self.n + extra
        cap = self._cap
        if cap == 0:
            cap = max(need, 256)
            for name, dtype, fill in self._COLUMNS:
                self._backing[name] = np.full(cap, fill, dtype=dtype)
        elif need > cap:
            while cap < need:
                cap *= 2
            for name, dtype, fill in self._COLUMNS:
                back = np.full(cap, fill, dtype=dtype)
                back[:self.n] = self._backing[name][:self.n]
                self._backing[name] = back
            for store in (self._q_int_models, self._q_batch_models):
                for m, col in store.items():
                    back = np.zeros(cap, dtype=np.int32)
                    back[:self.n] = col[:self.n]
                    store[m] = back
        else:
            return
        self._cap = cap

    def append_sample(self, t: float, n_interactive: int, n_mixed: int,
                      n_batch: int, chips: int, q_interactive: int,
                      q_batch: int, tokens_per_s: float, *,
                      q_interactive_by_model=None,
                      q_batch_by_model=None) -> None:
        self._reserve(1)
        i = self.n
        b = self._backing
        b["t"][i] = t
        b["n_interactive"][i] = n_interactive
        b["n_mixed"][i] = n_mixed
        b["n_batch"][i] = n_batch
        b["chips"][i] = chips
        b["q_interactive"][i] = q_interactive
        b["q_batch"][i] = q_batch
        b["tokens_per_s"][i] = tokens_per_s
        if q_interactive_by_model:
            self._set_depths(self._q_int_models, q_interactive_by_model, i)
        if q_batch_by_model:
            self._set_depths(self._q_batch_models, q_batch_by_model, i)
        self.n = i + 1

    def _set_depths(self, store: Dict[str, np.ndarray],
                    depths: Dict[str, int], i: int) -> None:
        for m, v in depths.items():
            col = store.get(m)
            if col is None:
                col = store[m] = np.zeros(self._cap, dtype=np.int32)
            col[i] = v

    # ------------------------------------------------------- column views
    def col(self, name: str) -> np.ndarray:
        """Exact-length view of one aggregate column."""
        if self._cap == 0:
            for cname, dtype, _ in self._COLUMNS:
                if cname == name:
                    return np.empty(0, dtype=dtype)
            raise KeyError(name)
        return self._backing[name][:self.n]

    def queue_models(self) -> List[str]:
        """Models with a per-model queue-depth column, sorted."""
        return sorted(set(self._q_int_models) | set(self._q_batch_models))

    def q_interactive_for(self, model: str) -> np.ndarray:
        col = self._q_int_models.get(model)
        return np.zeros(self.n, dtype=np.int32) if col is None \
            else col[:self.n]

    def q_batch_for(self, model: str) -> np.ndarray:
        col = self._q_batch_models.get(model)
        return np.zeros(self.n, dtype=np.int32) if col is None \
            else col[:self.n]

    # ----------------------------------------------- object view (compat)
    def _point(self, i: int) -> TimelinePoint:
        b = self._backing
        return TimelinePoint(
            float(b["t"][i]), int(b["n_interactive"][i]),
            int(b["n_mixed"][i]), int(b["n_batch"][i]),
            int(b["chips"][i]), int(b["q_interactive"][i]),
            int(b["q_batch"][i]), float(b["tokens_per_s"][i]))

    def __len__(self) -> int:
        return self.n

    def __iter__(self):
        for i in range(self.n):
            yield self._point(i)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._point(j) for j in range(*i.indices(self.n))]
        if i < 0:
            i += self.n
        if not 0 <= i < self.n:
            raise IndexError("timeline index out of range")
        return self._point(i)


@dataclass(frozen=True)
class Shock:
    """One injected chaos window (zone outage / flash crowd) carried on
    ``RunResult.shocks`` — :meth:`RunResult.recovery_metrics` scores the
    run's behaviour per shock."""
    kind: str            # "outage" | "flash_crowd"
    t0: float            # injection onset
    t1: float            # end of injection (capacity restored / ramp over)
    label: str = ""      # victim cluster name or the shock model


@dataclass
class ClusterStats:
    """Per-cluster rollup of a fleet run (attributed at completion time —
    the cluster whose instance finished the request gets the credit)."""
    name: str
    region: str = ""
    accelerator: str = ""
    cost_per_chip_hour: float = 1.0
    chip_seconds: float = 0.0
    peak_chips: int = 0
    scale_ups: int = 0
    scale_downs: int = 0
    failures: int = 0
    degradations: int = 0
    served_interactive: int = 0
    served_batch: int = 0
    slo_met_interactive: int = 0
    slo_met_batch: int = 0
    # cross-region assignment events (routing, drain re-routes, and
    # saturation hand-offs each count — a re-routed request's prompt
    # crosses a region boundary again, so it may tally more than once)
    remote_served: int = 0
    migrations_in: int = 0        # model placements copied into here
    migrations_out: int = 0       # placements drained away
    handbacks: int = 0            # saturated-queue work re-routed elsewhere
    egress_bytes: float = 0.0     # bytes this cluster's region sent out

    def gpu_hours(self) -> float:
        return self.chip_seconds / 3600.0

    def cost_usd(self) -> float:
        return self.gpu_hours() * self.cost_per_chip_hour

    def slo_interactive(self) -> float:
        return self.slo_met_interactive / self.served_interactive \
            if self.served_interactive else 1.0

    def slo_batch(self) -> float:
        return self.slo_met_batch / self.served_batch \
            if self.served_batch else 1.0


@dataclass
class RunResult:
    requests: List[Request]
    timeline: List[TimelinePoint]
    chip_seconds: float
    peak_chips: int
    scale_ups: int
    scale_downs: int
    duration: float
    failures: int = 0               # injected instance crashes
    n_events: int = 0               # event-core loop events (0: fixed tick)
    degradations: int = 0           # injected slow-node events
    skipped_injections: int = 0     # chaos events with no eligible victim
    # injected chaos windows (outages / flash crowds) this run carried;
    # recovery_metrics() scores each one
    shocks: List[Shock] = field(default_factory=list)
    # columnar outcome store (event-core runs); aggregate metrics reduce
    # over it vectorized instead of walking ``requests``
    ledger: Optional[RequestLedger] = None
    # flight recorder (repro.obs.FlightRecorder) when the run was made
    # with telemetry on; None otherwise
    telemetry: Optional[object] = None
    # --- fleet runs (simulate_fleet) ---
    clusters: List[ClusterStats] = field(default_factory=list)
    migrations: int = 0             # placement copies scheduled
    handbacks: int = 0              # saturated work re-routed
    egress_bytes: float = 0.0       # cross-region bytes (weights + tokens)
    egress_cost_usd: float = 0.0

    # ------------------------------------------------------------ SLOs
    def _done(self, rtype=None, model=None) -> List[Request]:
        rs = [r for r in self.requests
              if (rtype is None or r.request_type == rtype)
              and (model is None or r.model == model)]
        return rs

    def models(self) -> List[str]:
        """Distinct request models in first-appearance order."""
        if self.ledger is not None:
            led = self.ledger
            if not led.n:
                return []
            _, first = np.unique(led.model_idx, return_index=True)
            return [led.models[int(led.model_idx[i])]
                    for i in np.sort(first)]
        seen: Dict[str, None] = {}
        for r in self.requests:
            seen.setdefault(r.model)
        return list(seen)

    def slo_by_model(self) -> Dict[str, float]:
        """Per-model SLO attainment (one vectorized pass)."""
        if self.ledger is not None:
            return self.ledger.slo_by_model()
        met: Dict[str, int] = {}
        tot: Dict[str, int] = {}
        for r in self.requests:
            tot[r.model] = tot.get(r.model, 0) + 1
            if r.slo_met():
                met[r.model] = met.get(r.model, 0) + 1
        return {m: met.get(m, 0) / n for m, n in tot.items()}

    def slo_attainment(self, rtype=None) -> float:
        if self.ledger is not None:
            return self.ledger.slo_attainment(rtype)
        rs = self._done(rtype)
        if not rs:
            return 1.0
        return sum(r.slo_met() for r in rs) / len(rs)

    def ttft_attainment(self, rtype=None) -> float:
        if self.ledger is not None:
            led = self.ledger
            mask = led.class_mask(rtype)
            ok = led.finished_mask() & led.ttft_met_mask()
            tot = led.n if mask is None else int(np.count_nonzero(mask))
            if not tot:
                return 1.0
            if mask is not None:
                ok = ok & mask
            return float(np.count_nonzero(ok)) / tot
        rs = self._done(rtype)
        if not rs:
            return 1.0
        return sum(1 for r in rs
                   if r.state == RequestState.FINISHED and r.ttft_met()) / len(rs)

    def completion_rate(self) -> float:
        if self.ledger is not None:
            led = self.ledger
            if not led.n:
                return 1.0
            return float(np.count_nonzero(led.state == FINISHED)) / led.n
        if not self.requests:
            return 1.0
        return sum(r.state == RequestState.FINISHED
                   for r in self.requests) / len(self.requests)

    # -------------------------------------------------- overload currency
    def goodput(self, rtype=None) -> float:
        """SLO-met completions per second — the overload plane's
        currency. Rejected/shed/expired requests and SLO-blown
        completions all fall out of the numerator; admission control
        earns its keep by keeping this up while the raw completion rate
        drops."""
        if not self.duration:
            return 0.0
        if self.ledger is not None:
            return self.ledger.goodput(self.duration, rtype)
        good = sum(1 for r in self._done(rtype)
                   if r.state == RequestState.FINISHED and r.slo_met())
        return good / self.duration

    def outcome_rates(self) -> Dict[str, float]:
        """Fractions of all submitted requests per terminal outcome:
        ``reject_rate`` / ``shed_rate`` / ``expired_rate``. All three are
        0.0 on runs without the overload plane, so the keys are stable
        across configurations (trend tooling diffs them directly)."""
        if self.ledger is not None and self.ledger.n:
            counts = self.ledger.state_counts()
            n = self.ledger.n
            return {"reject_rate": int(counts[REJECTED]) / n,
                    "shed_rate": int(counts[SHED]) / n,
                    "expired_rate": int(counts[EXPIRED]) / n}
        n = len(self.requests)
        if not n:
            return {"reject_rate": 0.0, "shed_rate": 0.0,
                    "expired_rate": 0.0}
        states = [r.state for r in self.requests]
        return {
            "reject_rate": states.count(RequestState.REJECTED) / n,
            "shed_rate": states.count(RequestState.SHED) / n,
            "expired_rate": states.count(RequestState.EXPIRED) / n,
        }

    # ------------------------------------------------------------ thr/eff
    def total_tokens(self) -> int:
        if self.ledger is not None:
            return int(self.ledger.tokens_generated.sum())
        return sum(r.tokens_generated for r in self.requests)

    def request_throughput(self) -> float:
        if not self.duration:
            return 0.0
        if self.ledger is not None:
            return float(np.count_nonzero(
                self.ledger.state == FINISHED)) / self.duration
        done = [r for r in self.requests if r.state == RequestState.FINISHED]
        return len(done) / self.duration

    def per_instance_throughput(self) -> float:
        """Mean tokens/s per active instance over the run."""
        if not self.timeline:
            return 0.0
        samples = [(p.tokens_per_s, p.n_interactive + p.n_mixed + p.n_batch)
                   for p in self.timeline if
                   (p.n_interactive + p.n_mixed + p.n_batch) > 0]
        if not samples:
            return 0.0
        return sum(t / n for t, n in samples) / len(samples)

    def gpu_hours(self) -> float:
        return self.chip_seconds / 3600.0

    @property
    def hysteresis(self) -> float:
        if self.scale_ups == 0:
            return 0.0
        return (self.scale_ups + self.scale_downs) / self.scale_ups

    def mean_itl(self, rtype=None) -> float:
        if self.ledger is not None:
            led = self.ledger
            mi = led.mean_itl
            mask = ~np.isnan(mi)
            cm = led.class_mask(rtype)
            if cm is not None:
                mask = mask & cm
            if not mask.any():
                return 0.0
            return float(np.mean(mi[mask]))
        rs = [r for r in self._done(rtype) if r.itl_samples]
        if not rs:
            return 0.0
        vals = [sum(r.itl_samples) / len(r.itl_samples) for r in rs]
        return sum(vals) / len(vals)

    def p99_ttft(self, rtype=None) -> float:
        if self.ledger is not None:
            led = self.ledger
            ftt = led.first_token_time
            mask = ~np.isnan(ftt)
            cm = led.class_mask(rtype)
            if cm is not None:
                mask = mask & cm
            if not mask.any():
                return 0.0
            ttfts = np.sort(ftt[mask] - led.arrival[mask])
            return float(ttfts[min(int(0.99 * ttfts.size), ttfts.size - 1)])
        ttfts = sorted(r.ttft for r in self._done(rtype) if r.ttft is not None)
        if not ttfts:
            return 0.0
        return ttfts[min(int(0.99 * len(ttfts)), len(ttfts) - 1)]

    def instance_counts_at(self, t: float) -> Tuple[int, int, int]:
        """(interactive, mixed, batch) instance counts at time ``t``
        (stepwise-left over the timeline samples)."""
        tl = self.timeline
        if isinstance(tl, Timeline):
            # columnar fast path, bit-identical to the stepwise-left scan:
            # index of the last sample with sample.t <= t
            i = int(np.searchsorted(tl.col("t"), t, side="right")) - 1
            if i < 0:
                return (0, 0, 0)
            return (int(tl.col("n_interactive")[i]),
                    int(tl.col("n_mixed")[i]),
                    int(tl.col("n_batch")[i]))
        last = (0, 0, 0)
        for p in tl:
            if p.t > t:
                break
            last = (p.n_interactive, p.n_mixed, p.n_batch)
        return last

    def recovery_metrics(self, *, bin_s: float = 30.0,
                         epsilon: float = 0.02,
                         baseline_window: float = 600.0) -> List[Dict]:
        """Per-shock recovery scorecard, vectorized off the ledger and
        timeline columns. For each :class:`Shock` in ``shocks``:

        - ``baseline_attainment``: SLO attainment over arrivals in the
          ``baseline_window`` seconds before onset.
        - ``max_attainment_dip``: baseline minus the worst ``bin_s``
          attainment bin at/after onset (0.0 when attainment held).
        - ``time_to_recover_s``: seconds from onset until binned
          attainment is back within ``epsilon`` of baseline *and stays
          there* (end of the last populated bin below the band); 0.0
          when attainment never left the band, -1.0 when it has not
          recovered by end of run.
        - ``recovered``: explicit boolean companion to the -1.0
          sentinel — ``False`` exactly when the run ended still below
          the recovery band, so scorecard consumers never have to
          compare against the sentinel.
        - ``time_to_detect_s``: seconds from onset until the control
          plane visibly reacts — the first timeline sample where the
          live-instance count rises above its running minimum since
          onset (re-provisioning after an outage) or above the onset
          count (scale-out into a flash crowd); -1.0 if it never does.
        - attainment over arrivals inside the shock window [t0, t1]:
          overall, per SLO class (interactive / batch), and per tenant
          when the trace carries a tenant column.

        Needs the columnar ledger (event-engine runs); returns ``[]``
        for ledger-less or shock-free runs."""
        led = self.ledger
        if led is None or not led.n or not self.shocks:
            return []
        arrival = led.arrival
        met = led.slo_met_mask().astype(np.float64)
        nbins = max(int(max(self.duration, float(arrival[-1])) / bin_s)
                    + 1, 1)
        bins = np.minimum((arrival / bin_s).astype(np.int64), nbins - 1)
        tot = np.bincount(bins, minlength=nbins)
        hit = np.bincount(bins, weights=met, minlength=nbins)
        have = tot > 0
        # NaN-safe division: an overload run can shed every arrival in a
        # bin (hit=0, attainment 0.0, still populated); the guarded form
        # also keeps any upstream NaN weight from poisoning the bin
        with np.errstate(invalid="ignore", divide="ignore"):
            att = np.where(have, hit / np.maximum(tot, 1), 1.0)
        att = np.nan_to_num(att, nan=0.0)
        interactive = led.interactive.astype(bool)
        tl = self.timeline
        if isinstance(tl, Timeline) and len(tl):
            tl_t = tl.col("t")
            tl_n = (tl.col("n_interactive").astype(np.int64)
                    + tl.col("n_mixed") + tl.col("n_batch"))
        else:
            tl_t = np.empty(0)
            tl_n = np.empty(0, dtype=np.int64)

        def _att(mask: np.ndarray) -> float:
            if not mask.any():
                return 1.0
            v = float(met[mask].mean())
            return v if np.isfinite(v) else 0.0

        out: List[Dict] = []
        for shock in self.shocks:
            t0, t1 = shock.t0, shock.t1
            pre = (arrival >= t0 - baseline_window) & (arrival < t0)
            baseline = _att(pre)
            b0 = min(int(t0 / bin_s), nbins)
            post_have = have.copy()
            post_have[:b0] = False
            vals = att[post_have]
            max_dip = float(max(0.0, baseline - vals.min())) \
                if vals.size else 0.0
            low = post_have & (att < baseline - epsilon)
            if not low.any():
                ttr = 0.0
            else:
                last_low = int(np.nonzero(low)[0][-1])
                last_pop = int(np.nonzero(have)[0][-1])
                # still below the band in the final populated bin: the
                # run ended before attainment came back
                ttr = -1.0 if last_low >= last_pop \
                    else float((last_low + 1) * bin_s - t0)
            ttd = -1.0
            if tl_t.size:
                i0 = int(np.searchsorted(tl_t, t0, side="right")) - 1
                n0 = int(tl_n[i0]) if i0 >= 0 else 0
                post = np.nonzero(tl_t > t0)[0]
                if post.size:
                    seg = tl_n[post]
                    runmin = np.minimum.accumulate(np.minimum(seg, n0))
                    react = np.nonzero(seg > runmin)[0]
                    if react.size:
                        ttd = float(tl_t[post[react[0]]] - t0)
            win = (arrival >= t0) & (arrival <= t1)
            by_tenant: Dict[str, float] = {}
            tenants = getattr(led, "tenants", ())
            if tenants and win.any():
                tidx = led.tenant_idx[win]
                w_tot = np.bincount(tidx, minlength=len(tenants))
                w_hit = np.bincount(tidx, weights=met[win],
                                    minlength=len(tenants))
                for ti, name in enumerate(tenants):
                    if w_tot[ti]:
                        by_tenant[name] = float(w_hit[ti] / w_tot[ti])
            out.append({
                "kind": shock.kind, "label": shock.label,
                "t0": float(t0), "t1": float(t1),
                "baseline_attainment": baseline,
                "max_attainment_dip": max_dip,
                "time_to_recover_s": ttr,
                "recovered": ttr >= 0.0,
                "time_to_detect_s": ttd,
                "window_attainment": _att(win),
                "window_interactive": _att(win & interactive),
                "window_batch": _att(win & ~interactive),
                "window_by_tenant": by_tenant,
            })
        return out

    def summary(self) -> Dict[str, float]:
        out = {
            "slo_attainment": self.slo_attainment(),
            "slo_interactive": self.slo_attainment(RequestType.INTERACTIVE),
            "slo_batch": self.slo_attainment(RequestType.BATCH),
            "completion_rate": self.completion_rate(),
            "goodput": self.goodput(),
            "goodput_interactive": self.goodput(RequestType.INTERACTIVE),
            "request_throughput": self.request_throughput(),
            "per_instance_throughput": self.per_instance_throughput(),
            "gpu_hours": self.gpu_hours(),
            "peak_chips": self.peak_chips,
            "hysteresis": self.hysteresis,
            "mean_itl": self.mean_itl(),
        }
        out.update(self.outcome_rates())
        by_model = self.slo_by_model()
        if len(by_model) > 1:           # multi-model fleet: per-model SLOs
            for m, v in by_model.items():
                out[f"slo_model:{m}"] = v
        if self.failures:
            out["failures"] = self.failures
        if self.degradations:
            out["degradations"] = self.degradations
        if self.skipped_injections:
            out["skipped_injections"] = self.skipped_injections
        if self.clusters:               # fleet run: per-cluster/region rollups
            out["migrations"] = self.migrations
            out["handbacks"] = self.handbacks
            out["egress_gb"] = self.egress_bytes / 1e9
            out["egress_cost_usd"] = self.egress_cost_usd
            out["fleet_cost_usd"] = sum(c.cost_usd() for c in self.clusters)
            total_batch = sum(c.served_batch for c in self.clusters)
            regions: Dict[str, float] = {}
            for c in self.clusters:
                out[f"cluster:{c.name}:gpu_hours"] = c.gpu_hours()
                out[f"cluster:{c.name}:peak_chips"] = c.peak_chips
                out[f"cluster:{c.name}:slo_interactive"] = c.slo_interactive()
                out[f"cluster:{c.name}:batch_share"] = \
                    c.served_batch / total_batch if total_batch else 0.0
                regions[c.region] = regions.get(c.region, 0.0) \
                    + c.gpu_hours()
            for r, gh in regions.items():
                out[f"region:{r}:gpu_hours"] = gh
        return out


def decisions_match(a: "RunResult", b: "RunResult", *,
                    interval: float = 1.0,
                    slack_intervals: int = 1) -> Tuple[float, int]:
    """Compare two runs' autoscaling decisions (per-type instance counts
    sampled every control ``interval``), tolerating a shift of
    ``slack_intervals`` — the engines may act the same way one control
    tick apart. Returns (fraction of grid points matching, max per-type
    count deviation at the unmatched points)."""
    horizon = min(a.duration, b.duration)
    n = max(int(horizon / interval), 1)
    matched = 0
    max_dev = 0
    for i in range(n + 1):
        t = i * interval
        ca = a.instance_counts_at(t)
        shifts = range(-slack_intervals, slack_intervals + 1)
        if any(ca == b.instance_counts_at(t + s * interval) for s in shifts):
            matched += 1
        else:
            cb = b.instance_counts_at(t)
            max_dev = max(max_dev, max(abs(x - y) for x, y in zip(ca, cb)))
    return matched / (n + 1), max_dev
