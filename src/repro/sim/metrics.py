"""Experiment metrics: SLO attainment, throughput, GPU efficiency,
hysteresis — plus per-cluster/per-region rollups for fleet runs.

When a run carries a :class:`repro.sim.ledger.RequestLedger` (the event
engines always install one), every aggregate — SLO attainment, per-model
rollups, completion rate, token totals, mean ITL, TTFT percentiles — is a
vectorized reduction over the ledger columns instead of a Python loop
over a million ``Request`` objects; the object path is kept as the
reference for ledger-less runs (fixed tick, hand-built results)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.request import Request, RequestState, RequestType
from repro.sim.ledger import FINISHED, RequestLedger


@dataclass
class TimelinePoint:
    t: float
    n_interactive: int
    n_mixed: int
    n_batch: int
    chips: int
    q_interactive: int
    q_batch: int
    tokens_per_s: float


class Timeline:
    """Columnar run timeline: one struct-of-arrays row per sample
    (amortized-doubling backing, the :class:`RequestLedger` idiom)
    instead of a ``TimelinePoint`` object per sample, plus per-model
    queue-depth columns the flat tuple could not express.

    The object view survives for back-compat: iteration, indexing
    (negative included) and slicing materialize :class:`TimelinePoint`
    views lazily, so ``timeline[-1].t`` and every existing consumer keep
    working; vectorized consumers read :meth:`col` directly."""

    _COLUMNS = (
        ("t", np.float64, 0.0), ("n_interactive", np.int32, 0),
        ("n_mixed", np.int32, 0), ("n_batch", np.int32, 0),
        ("chips", np.int32, 0), ("q_interactive", np.int32, 0),
        ("q_batch", np.int32, 0), ("tokens_per_s", np.float64, 0.0),
    )
    __slots__ = ("n", "_cap", "_backing", "_q_int_models",
                 "_q_batch_models")

    def __init__(self):
        self.n = 0
        self._cap = 0
        self._backing: Dict[str, np.ndarray] = {}
        # model -> per-sample queue-depth column; created zero-filled on
        # a model's first nonzero depth (rows before that are correctly
        # zero — the lane did not exist yet)
        self._q_int_models: Dict[str, np.ndarray] = {}
        self._q_batch_models: Dict[str, np.ndarray] = {}

    def _reserve(self, extra: int) -> None:
        need = self.n + extra
        cap = self._cap
        if cap == 0:
            cap = max(need, 256)
            for name, dtype, fill in self._COLUMNS:
                self._backing[name] = np.full(cap, fill, dtype=dtype)
        elif need > cap:
            while cap < need:
                cap *= 2
            for name, dtype, fill in self._COLUMNS:
                back = np.full(cap, fill, dtype=dtype)
                back[:self.n] = self._backing[name][:self.n]
                self._backing[name] = back
            for store in (self._q_int_models, self._q_batch_models):
                for m, col in store.items():
                    back = np.zeros(cap, dtype=np.int32)
                    back[:self.n] = col[:self.n]
                    store[m] = back
        else:
            return
        self._cap = cap

    def append_sample(self, t: float, n_interactive: int, n_mixed: int,
                      n_batch: int, chips: int, q_interactive: int,
                      q_batch: int, tokens_per_s: float, *,
                      q_interactive_by_model=None,
                      q_batch_by_model=None) -> None:
        self._reserve(1)
        i = self.n
        b = self._backing
        b["t"][i] = t
        b["n_interactive"][i] = n_interactive
        b["n_mixed"][i] = n_mixed
        b["n_batch"][i] = n_batch
        b["chips"][i] = chips
        b["q_interactive"][i] = q_interactive
        b["q_batch"][i] = q_batch
        b["tokens_per_s"][i] = tokens_per_s
        if q_interactive_by_model:
            self._set_depths(self._q_int_models, q_interactive_by_model, i)
        if q_batch_by_model:
            self._set_depths(self._q_batch_models, q_batch_by_model, i)
        self.n = i + 1

    def _set_depths(self, store: Dict[str, np.ndarray],
                    depths: Dict[str, int], i: int) -> None:
        for m, v in depths.items():
            col = store.get(m)
            if col is None:
                col = store[m] = np.zeros(self._cap, dtype=np.int32)
            col[i] = v

    # ------------------------------------------------------- column views
    def col(self, name: str) -> np.ndarray:
        """Exact-length view of one aggregate column."""
        if self._cap == 0:
            for cname, dtype, _ in self._COLUMNS:
                if cname == name:
                    return np.empty(0, dtype=dtype)
            raise KeyError(name)
        return self._backing[name][:self.n]

    def queue_models(self) -> List[str]:
        """Models with a per-model queue-depth column, sorted."""
        return sorted(set(self._q_int_models) | set(self._q_batch_models))

    def q_interactive_for(self, model: str) -> np.ndarray:
        col = self._q_int_models.get(model)
        return np.zeros(self.n, dtype=np.int32) if col is None \
            else col[:self.n]

    def q_batch_for(self, model: str) -> np.ndarray:
        col = self._q_batch_models.get(model)
        return np.zeros(self.n, dtype=np.int32) if col is None \
            else col[:self.n]

    # ----------------------------------------------- object view (compat)
    def _point(self, i: int) -> TimelinePoint:
        b = self._backing
        return TimelinePoint(
            float(b["t"][i]), int(b["n_interactive"][i]),
            int(b["n_mixed"][i]), int(b["n_batch"][i]),
            int(b["chips"][i]), int(b["q_interactive"][i]),
            int(b["q_batch"][i]), float(b["tokens_per_s"][i]))

    def __len__(self) -> int:
        return self.n

    def __iter__(self):
        for i in range(self.n):
            yield self._point(i)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._point(j) for j in range(*i.indices(self.n))]
        if i < 0:
            i += self.n
        if not 0 <= i < self.n:
            raise IndexError("timeline index out of range")
        return self._point(i)


@dataclass
class ClusterStats:
    """Per-cluster rollup of a fleet run (attributed at completion time —
    the cluster whose instance finished the request gets the credit)."""
    name: str
    region: str = ""
    accelerator: str = ""
    cost_per_chip_hour: float = 1.0
    chip_seconds: float = 0.0
    peak_chips: int = 0
    scale_ups: int = 0
    scale_downs: int = 0
    failures: int = 0
    degradations: int = 0
    served_interactive: int = 0
    served_batch: int = 0
    slo_met_interactive: int = 0
    slo_met_batch: int = 0
    # cross-region assignment events (routing, drain re-routes, and
    # saturation hand-offs each count — a re-routed request's prompt
    # crosses a region boundary again, so it may tally more than once)
    remote_served: int = 0
    migrations_in: int = 0        # model placements copied into here
    migrations_out: int = 0       # placements drained away
    handbacks: int = 0            # saturated-queue work re-routed elsewhere
    egress_bytes: float = 0.0     # bytes this cluster's region sent out

    def gpu_hours(self) -> float:
        return self.chip_seconds / 3600.0

    def cost_usd(self) -> float:
        return self.gpu_hours() * self.cost_per_chip_hour

    def slo_interactive(self) -> float:
        return self.slo_met_interactive / self.served_interactive \
            if self.served_interactive else 1.0

    def slo_batch(self) -> float:
        return self.slo_met_batch / self.served_batch \
            if self.served_batch else 1.0


@dataclass
class RunResult:
    requests: List[Request]
    timeline: List[TimelinePoint]
    chip_seconds: float
    peak_chips: int
    scale_ups: int
    scale_downs: int
    duration: float
    failures: int = 0               # injected instance crashes
    n_events: int = 0               # event-core loop events (0: fixed tick)
    degradations: int = 0           # injected slow-node events
    # columnar outcome store (event-core runs); aggregate metrics reduce
    # over it vectorized instead of walking ``requests``
    ledger: Optional[RequestLedger] = None
    # flight recorder (repro.obs.FlightRecorder) when the run was made
    # with telemetry on; None otherwise
    telemetry: Optional[object] = None
    # --- fleet runs (simulate_fleet) ---
    clusters: List[ClusterStats] = field(default_factory=list)
    migrations: int = 0             # placement copies scheduled
    handbacks: int = 0              # saturated work re-routed
    egress_bytes: float = 0.0       # cross-region bytes (weights + tokens)
    egress_cost_usd: float = 0.0

    # ------------------------------------------------------------ SLOs
    def _done(self, rtype=None, model=None) -> List[Request]:
        rs = [r for r in self.requests
              if (rtype is None or r.request_type == rtype)
              and (model is None or r.model == model)]
        return rs

    def models(self) -> List[str]:
        """Distinct request models in first-appearance order."""
        if self.ledger is not None:
            led = self.ledger
            if not led.n:
                return []
            _, first = np.unique(led.model_idx, return_index=True)
            return [led.models[int(led.model_idx[i])]
                    for i in np.sort(first)]
        seen: Dict[str, None] = {}
        for r in self.requests:
            seen.setdefault(r.model)
        return list(seen)

    def slo_by_model(self) -> Dict[str, float]:
        """Per-model SLO attainment (one vectorized pass)."""
        if self.ledger is not None:
            return self.ledger.slo_by_model()
        met: Dict[str, int] = {}
        tot: Dict[str, int] = {}
        for r in self.requests:
            tot[r.model] = tot.get(r.model, 0) + 1
            if r.slo_met():
                met[r.model] = met.get(r.model, 0) + 1
        return {m: met.get(m, 0) / n for m, n in tot.items()}

    def slo_attainment(self, rtype=None) -> float:
        if self.ledger is not None:
            return self.ledger.slo_attainment(rtype)
        rs = self._done(rtype)
        if not rs:
            return 1.0
        return sum(r.slo_met() for r in rs) / len(rs)

    def ttft_attainment(self, rtype=None) -> float:
        if self.ledger is not None:
            led = self.ledger
            mask = led.class_mask(rtype)
            ok = led.finished_mask() & led.ttft_met_mask()
            tot = led.n if mask is None else int(np.count_nonzero(mask))
            if not tot:
                return 1.0
            if mask is not None:
                ok = ok & mask
            return float(np.count_nonzero(ok)) / tot
        rs = self._done(rtype)
        if not rs:
            return 1.0
        return sum(1 for r in rs
                   if r.state == RequestState.FINISHED and r.ttft_met()) / len(rs)

    def completion_rate(self) -> float:
        if self.ledger is not None:
            led = self.ledger
            if not led.n:
                return 1.0
            return float(np.count_nonzero(led.state == FINISHED)) / led.n
        if not self.requests:
            return 1.0
        return sum(r.state == RequestState.FINISHED
                   for r in self.requests) / len(self.requests)

    # ------------------------------------------------------------ thr/eff
    def total_tokens(self) -> int:
        if self.ledger is not None:
            return int(self.ledger.tokens_generated.sum())
        return sum(r.tokens_generated for r in self.requests)

    def request_throughput(self) -> float:
        if not self.duration:
            return 0.0
        if self.ledger is not None:
            return float(np.count_nonzero(
                self.ledger.state == FINISHED)) / self.duration
        done = [r for r in self.requests if r.state == RequestState.FINISHED]
        return len(done) / self.duration

    def per_instance_throughput(self) -> float:
        """Mean tokens/s per active instance over the run."""
        if not self.timeline:
            return 0.0
        samples = [(p.tokens_per_s, p.n_interactive + p.n_mixed + p.n_batch)
                   for p in self.timeline if
                   (p.n_interactive + p.n_mixed + p.n_batch) > 0]
        if not samples:
            return 0.0
        return sum(t / n for t, n in samples) / len(samples)

    def gpu_hours(self) -> float:
        return self.chip_seconds / 3600.0

    @property
    def hysteresis(self) -> float:
        if self.scale_ups == 0:
            return 0.0
        return (self.scale_ups + self.scale_downs) / self.scale_ups

    def mean_itl(self, rtype=None) -> float:
        if self.ledger is not None:
            led = self.ledger
            mi = led.mean_itl
            mask = ~np.isnan(mi)
            cm = led.class_mask(rtype)
            if cm is not None:
                mask = mask & cm
            if not mask.any():
                return 0.0
            return float(np.mean(mi[mask]))
        rs = [r for r in self._done(rtype) if r.itl_samples]
        if not rs:
            return 0.0
        vals = [sum(r.itl_samples) / len(r.itl_samples) for r in rs]
        return sum(vals) / len(vals)

    def p99_ttft(self, rtype=None) -> float:
        if self.ledger is not None:
            led = self.ledger
            ftt = led.first_token_time
            mask = ~np.isnan(ftt)
            cm = led.class_mask(rtype)
            if cm is not None:
                mask = mask & cm
            if not mask.any():
                return 0.0
            ttfts = np.sort(ftt[mask] - led.arrival[mask])
            return float(ttfts[min(int(0.99 * ttfts.size), ttfts.size - 1)])
        ttfts = sorted(r.ttft for r in self._done(rtype) if r.ttft is not None)
        if not ttfts:
            return 0.0
        return ttfts[min(int(0.99 * len(ttfts)), len(ttfts) - 1)]

    def instance_counts_at(self, t: float) -> Tuple[int, int, int]:
        """(interactive, mixed, batch) instance counts at time ``t``
        (stepwise-left over the timeline samples)."""
        tl = self.timeline
        if isinstance(tl, Timeline):
            # columnar fast path, bit-identical to the stepwise-left scan:
            # index of the last sample with sample.t <= t
            i = int(np.searchsorted(tl.col("t"), t, side="right")) - 1
            if i < 0:
                return (0, 0, 0)
            return (int(tl.col("n_interactive")[i]),
                    int(tl.col("n_mixed")[i]),
                    int(tl.col("n_batch")[i]))
        last = (0, 0, 0)
        for p in tl:
            if p.t > t:
                break
            last = (p.n_interactive, p.n_mixed, p.n_batch)
        return last

    def summary(self) -> Dict[str, float]:
        out = {
            "slo_attainment": self.slo_attainment(),
            "slo_interactive": self.slo_attainment(RequestType.INTERACTIVE),
            "slo_batch": self.slo_attainment(RequestType.BATCH),
            "completion_rate": self.completion_rate(),
            "request_throughput": self.request_throughput(),
            "per_instance_throughput": self.per_instance_throughput(),
            "gpu_hours": self.gpu_hours(),
            "peak_chips": self.peak_chips,
            "hysteresis": self.hysteresis,
            "mean_itl": self.mean_itl(),
        }
        by_model = self.slo_by_model()
        if len(by_model) > 1:           # multi-model fleet: per-model SLOs
            for m, v in by_model.items():
                out[f"slo_model:{m}"] = v
        if self.failures:
            out["failures"] = self.failures
        if self.degradations:
            out["degradations"] = self.degradations
        if self.clusters:               # fleet run: per-cluster/region rollups
            out["migrations"] = self.migrations
            out["handbacks"] = self.handbacks
            out["egress_gb"] = self.egress_bytes / 1e9
            out["egress_cost_usd"] = self.egress_cost_usd
            out["fleet_cost_usd"] = sum(c.cost_usd() for c in self.clusters)
            total_batch = sum(c.served_batch for c in self.clusters)
            regions: Dict[str, float] = {}
            for c in self.clusters:
                out[f"cluster:{c.name}:gpu_hours"] = c.gpu_hours()
                out[f"cluster:{c.name}:peak_chips"] = c.peak_chips
                out[f"cluster:{c.name}:slo_interactive"] = c.slo_interactive()
                out[f"cluster:{c.name}:batch_share"] = \
                    c.served_batch / total_batch if total_batch else 0.0
                regions[c.region] = regions.get(c.region, 0.0) \
                    + c.gpu_hours()
            for r, gh in regions.items():
                out[f"region:{r}:gpu_hours"] = gh
        return out


def decisions_match(a: "RunResult", b: "RunResult", *,
                    interval: float = 1.0,
                    slack_intervals: int = 1) -> Tuple[float, int]:
    """Compare two runs' autoscaling decisions (per-type instance counts
    sampled every control ``interval``), tolerating a shift of
    ``slack_intervals`` — the engines may act the same way one control
    tick apart. Returns (fraction of grid points matching, max per-type
    count deviation at the unmatched points)."""
    horizon = min(a.duration, b.duration)
    n = max(int(horizon / interval), 1)
    matched = 0
    max_dev = 0
    for i in range(n + 1):
        t = i * interval
        ca = a.instance_counts_at(t)
        shifts = range(-slack_intervals, slack_intervals + 1)
        if any(ca == b.instance_counts_at(t + s * interval) for s in shifts):
            matched += 1
        else:
            cb = b.instance_counts_at(t)
            max_dev = max(max_dev, max(abs(x - y) for x, y in zip(ca, cb)))
    return matched / (n + 1), max_dev
