"""Cluster simulation: event-driven core + fixed-tick reference loop.

The event-driven core (``simulate_events``) drives the cluster off a
time-ordered event heap — request arrivals, instance-ready transitions,
per-instance completion estimates, control ticks, injected instance
failures, and timeline samples — so idle spans cost zero work and
million-request traces run in seconds. The identical ``repro.core``
autoscaler code used by the real engine runs in the control loop — only
the data plane is simulated (DESIGN.md §4), as a fluid model whose
composition changes happen exactly at event times.

Both engines accept either a materialized ``List[Request]`` or a columnar
:class:`~repro.sim.workload.Trace`. The event core walks a Trace through a
chunked cursor that materializes ``Request`` objects lazily in arrival
order, so a 1M-request replay never builds a million objects up front.

Failure injection: pass ``failures=FailurePlan(times, seed=...)`` and the
event core crashes a uniformly-drawn active instance at each time — the
instance is removed (chips freed, ``cluster.failures`` counted separately
from autoscaling actions), its in-flight requests lose their KV and
re-queue, and the control hierarchy heals the fleet on its next tick.

``simulate_fixed_tick`` is the original discrete-time loop (default tick
0.25 s), kept as the equivalence reference and quantization baseline.
``simulate`` keeps the historical signature and dispatches to either
engine (event-driven by default).
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from repro.serving.global_queue import GlobalQueue
from repro.serving.request import Request
from repro.sim.cluster import InstanceState, InstanceType, SimCluster
from repro.sim.controllers import BaseController
from repro.sim.metrics import RunResult, TimelinePoint
from repro.sim.perf_model import PerfModel
from repro.sim.workload import Trace

# heap-event kinds; the tuple position makes READY sort before COMPLETION
# and COMPLETION before FAILURE at equal timestamps (an instance activates
# before its estimates fire; finishes land before the crash takes them)
_READY, _COMPLETION, _FAIL = 0, 1, 2

RequestSource = Union[Sequence[Request], Trace]


@dataclass
class FailurePlan:
    """Crash schedule for failure injection: at each time in ``times`` one
    uniformly-drawn *active* instance crashes (no-op when none is active).
    Victim draws come from ``default_rng(seed)`` over the id-sorted active
    list, so a plan is fully deterministic for a given run."""
    times: Sequence[float]
    seed: int = 0

    def sorted_times(self) -> List[float]:
        return sorted(float(t) for t in self.times)


class _RequestCursor:
    """Arrival-ordered request source over a list or a columnar Trace.

    Trace mode materializes ``Request`` objects in chunks as the arrival
    loop consumes them — peeking the next arrival time reads the float
    column directly, so unarrived requests cost no Python objects.
    """

    def __init__(self, source: RequestSource, chunk: int = 16384):
        self._chunk = chunk
        if isinstance(source, Trace):
            self._trace = source.sorted_by_arrival()
            self._times = self._trace.arrival
            self.n = self._trace.n
            self.all: List[Request] = []
        else:
            self._trace = None
            self.all = sorted(source, key=lambda r: r.arrival_time)
            self.n = len(self.all)
        self._i = 0

    @property
    def exhausted(self) -> bool:
        return self._i >= self.n

    def peek_time(self) -> float:
        if self._i >= self.n:
            return float("inf")
        if self._trace is not None:
            return float(self._times[self._i])
        return self.all[self._i].arrival_time

    def pop(self) -> Request:
        if self._trace is not None and self._i >= len(self.all):
            lo = len(self.all)
            self.all.extend(self._trace.materialize(lo, lo + self._chunk))
        req = self.all[self._i]
        self._i += 1
        return req

    def all_requests(self) -> List[Request]:
        """Every request (materializing any unserved tail) for RunResult."""
        if self._trace is not None and len(self.all) < self.n:
            self.all.extend(self._trace.materialize(len(self.all), self.n))
        return self.all


def _warm_start(controller, cluster: SimCluster, t: float, n: int) -> None:
    """Pre-provision ``n`` instances, instantly active (shared by engines);
    multi-model controllers get them round-robin across their fleet."""
    models = getattr(controller, "model_list", None)
    for k in range(n):
        model = models[k % len(models)] if models else \
            getattr(controller, "model", "llama-8b")
        inst = controller._provision(cluster, InstanceType.MIXED, t, model) \
            if hasattr(controller, "_provision") else \
            cluster.provision(model, InstanceType.MIXED, t,
                              static_batch=getattr(controller, "static_batch",
                                                   64))
        if inst is not None:
            inst.ready_time = t
            inst.activate_if_ready(t)


def simulate_events(requests: RequestSource, controller: BaseController,
                    cluster: SimCluster, *, control_interval: float = 1.0,
                    max_time: float = 7200.0, warm_start: int = 0,
                    timeline_every: float = 1.0,
                    completion_grain: float = 0.25,
                    quantize: float = 0.0,
                    failures: Optional[FailurePlan] = None) -> RunResult:
    """Event-driven simulation. ``quantize > 0`` snaps every event time up
    to that grid, making the run a *sparse fixed-tick*: it touches only
    non-empty ticks yet batches arrivals/completions exactly like a
    ``simulate_fixed_tick`` run at ``dt=quantize`` — the mode the
    engine-equivalence comparison uses."""
    queue = GlobalQueue()
    cursor = _RequestCursor(requests)
    t = 0.0
    cluster.event_mode = True
    cluster.now = 0.0
    cluster.completion_grain = completion_grain
    cluster.quantize = quantize

    _warm_start(controller, cluster, t, warm_start)

    heap: list = []                  # (time, kind, seq, instance, epoch)
    ev_seq = itertools.count()
    ready_scheduled: set = set()     # instance ids with a READY event pushed
    timeline: List[TimelinePoint] = []
    next_control = 0.0
    control_parked = False
    next_timeline = 0.0
    last_sample_t = 0.0
    n_events = 0
    eps = 1e-12

    fail_rng = None
    if failures is not None:
        fail_rng = np.random.default_rng(failures.seed)
        for tf in failures.sorted_times():
            heapq.heappush(heap, (tf, _FAIL, next(ev_seq), None, 0))

    def _sample(now: float) -> None:
        nonlocal last_sample_t, next_timeline
        rate = cluster.take_tokens() / max(now - last_sample_t, 1e-9)
        timeline.append(TimelinePoint(
            now,
            len(cluster.by_type(InstanceType.INTERACTIVE)),
            len(cluster.by_type(InstanceType.MIXED)),
            len(cluster.by_type(InstanceType.BATCH)),
            cluster.used_chips(),
            queue.n_interactive, queue.n_batch, rate))
        last_sample_t = now
        next_timeline = now + timeline_every

    while True:
        # ---- termination: all requests arrived, none queued or running
        if cursor.exhausted and len(queue) == 0 and \
                cluster.total_running == 0:
            break

        # ---- next event time across all sources
        t_next = cursor.peek_time()
        if heap and heap[0][0] < t_next:
            t_next = heap[0][0]
        if next_control < t_next:
            t_next = next_control
        if not control_parked and next_timeline < t_next:
            t_next = next_timeline
        if quantize > 0:                 # sparse fixed-tick alignment
            t_next = math.ceil(t_next / quantize - 1e-9) * quantize
        if t_next > max_time or t_next == float("inf"):
            cluster.advance_time(max_time)   # idle chip-time to the horizon
            t = max_time
            break
        t = t_next
        cluster.advance_time(t)
        changed = False

        # 1. arrivals due at t
        while cursor.peek_time() <= t + eps:
            req = cursor.pop()
            queue.push(req)
            if hasattr(controller, "observe_arrival"):
                controller.observe_arrival(req, t)
            changed = True
            n_events += 1

        # 2. instance events due at t (ready transitions, completion
        #    estimates, injected crashes; stale estimates are skipped via
        #    the epoch stamp). Instances that gained capacity are
        #    backfilled directly below.
        freed = []
        while heap and heap[0][0] <= t + eps:
            _, kind, _, inst, epoch = heapq.heappop(heap)
            n_events += 1
            if kind == _READY:
                if inst.state == InstanceState.LOADING:
                    inst.activate_if_ready(t)
                    inst.mark_dirty()
                    freed.append(inst)
                    changed = True
            elif kind == _FAIL:
                # crash a uniformly-drawn active instance (id-sorted list
                # + seeded rng -> deterministic victim per run)
                active = [i for i in cluster.instances if i.active]
                if active:
                    active.sort(key=lambda i: i.id)
                    victim = active[int(fail_rng.integers(len(active)))]
                    if victim in freed:
                        freed.remove(victim)
                    displaced = cluster.fail_instance(victim)
                    # fluid state settled at the crash instant: finishes
                    # that beat the crash still count, the rest requeue
                    for r in victim.drain_finished():
                        controller.observe_completion(r)
                    for r in displaced:
                        queue.requeue(r)
                    cluster.dirty.discard(victim)
                    changed = True
            elif epoch == inst._epoch and inst.state == InstanceState.ACTIVE:
                inst.advance(t)
                freed.append(inst)
                changed = True

        # a parked control loop resumes as soon as anything happens
        if control_parked and changed:
            next_control = t
            control_parked = False

        # 3. control tick: align every instance's fluid state with ``t``,
        #    then run the identical production control path
        ran_control = t >= next_control - eps
        if ran_control:
            n_events += 1
            for inst in cluster.instances:
                inst.advance(t)
            pre = (len(cluster.instances), cluster.scale_ups,
                   cluster.scale_downs)
            controller.control(cluster, queue, t)
            # schedule ready events for instances the controller provisioned
            for inst in cluster.instances:
                if inst.state == InstanceState.LOADING and \
                        inst.id not in ready_scheduled:
                    heapq.heappush(heap, (inst.ready_time, _READY,
                                          next(ev_seq), inst, 0))
                    ready_scheduled.add(inst.id)
            post = (len(cluster.instances), cluster.scale_ups,
                    cluster.scale_downs)
            quiescent = (pre == post and len(queue) == 0
                         and cluster.total_running == 0
                         and all(i.state != InstanceState.LOADING
                                 for i in cluster.instances))
            if quiescent:
                # deterministic controller + unchanged inputs -> nothing can
                # change before the next arrival; park the control loop
                next_control = cursor.peek_time()
                control_parked = True
            else:
                next_control = t + control_interval

        # 4. routing: the full preferential pass runs at control ticks; in
        #    between, interactive dispatch stays zero-queuing on every event
        #    and only just-freed instances are backfilled from the batch
        #    queue — the hot path never rescans the whole cluster
        if ran_control or not hasattr(controller, "route_interactive"):
            controller.route(cluster, queue, t)
        else:
            controller.route_interactive(cluster, queue, t)
            if freed and queue.n_batch:
                if len(freed) > 1:
                    # preserve pool preference: batch instances first
                    freed.sort(key=lambda i:
                               i.itype != InstanceType.BATCH)
                controller.backfill(freed, queue, t)

        # 5. sweep instances touched this batch: surface completions to the
        #    controller and (re)schedule their next completion estimate
        for inst in cluster.drain_dirty():
            for r in inst.drain_finished():
                controller.observe_completion(r)
            if inst.state == InstanceState.ACTIVE:
                eta = inst.next_event_in()
                if eta != float("inf"):
                    inst._epoch += 1
                    heapq.heappush(heap, (t + eta, _COMPLETION,
                                          next(ev_seq), inst, inst._epoch))

        # 6. timeline sample (suppressed while parked — state is frozen)
        if t >= next_timeline - eps:
            _sample(t)

    if timeline and t > timeline[-1].t:
        _sample(t)
    return RunResult(requests=cursor.all_requests(), timeline=timeline,
                     chip_seconds=cluster.chip_seconds,
                     peak_chips=cluster.peak_chips,
                     scale_ups=cluster.scale_ups,
                     scale_downs=cluster.scale_downs,
                     duration=t, failures=cluster.failures,
                     n_events=n_events)


def simulate_fixed_tick(requests: RequestSource, controller: BaseController,
                        cluster: SimCluster, *, dt: float = 0.25,
                        control_interval: float = 1.0,
                        max_time: float = 7200.0, warm_start: int = 0,
                        timeline_every: float = 1.0) -> RunResult:
    """The original discrete-time loop (reference/quantization baseline).
    A Trace input is materialized up front — the reference loop walks
    every tick anyway, so laziness buys nothing here."""
    queue = GlobalQueue()
    if isinstance(requests, Trace):
        requests = requests.sorted_by_arrival().materialize()
    pending = sorted(requests, key=lambda r: r.arrival_time)
    pi = 0
    t = 0.0
    next_control = 0.0
    next_timeline = 0.0
    timeline: List[TimelinePoint] = []

    _warm_start(controller, cluster, t, warm_start)

    while t < max_time:
        # 1. arrivals
        while pi < len(pending) and pending[pi].arrival_time <= t:
            queue.push(pending[pi])
            if hasattr(controller, "observe_arrival"):
                controller.observe_arrival(pending[pi], t)
            pi += 1

        # 2. instance state transitions
        for inst in cluster.instances:
            inst.activate_if_ready(t)

        # 3. control (scaling) then routing
        if t >= next_control:
            controller.control(cluster, queue, t)
            next_control = t + control_interval
        controller.route(cluster, queue, t)

        # 4. data-plane step
        tok_this_tick = 0
        for inst in cluster.active_instances():
            finished, toks = inst.step(dt, t)
            tok_this_tick += toks
            for r in finished:
                controller.observe_completion(r)

        cluster.tick_accounting(dt)

        # 5. timeline sample
        if t >= next_timeline:
            timeline.append(TimelinePoint(
                t,
                len(cluster.by_type(InstanceType.INTERACTIVE)),
                len(cluster.by_type(InstanceType.MIXED)),
                len(cluster.by_type(InstanceType.BATCH)),
                cluster.used_chips(),
                queue.n_interactive, queue.n_batch,
                tok_this_tick / dt))
            next_timeline = t + timeline_every

        t += dt

        # 6. termination: all requests arrived and none outstanding
        if pi >= len(pending) and len(queue) == 0 and \
                all(not i.running for i in cluster.instances):
            break

    return RunResult(requests=pending, timeline=timeline,
                     chip_seconds=cluster.chip_seconds,
                     peak_chips=cluster.peak_chips,
                     scale_ups=cluster.scale_ups,
                     scale_downs=cluster.scale_downs,
                     duration=t, failures=cluster.failures)


def simulate(requests: RequestSource, controller: BaseController,
             cluster: SimCluster, *, dt: float = 0.25,
             control_interval: float = 1.0, max_time: float = 7200.0,
             warm_start: int = 0, timeline_every: float = 1.0,
             engine: str = "event",
             failures: Optional[FailurePlan] = None) -> RunResult:
    """Compatibility wrapper: dispatch to the event-driven core (default)
    or the fixed-tick reference (``engine="fixed"``, where ``dt`` applies;
    failure injection needs the event core).
    """
    if engine == "event":
        return simulate_events(requests, controller, cluster,
                               control_interval=control_interval,
                               max_time=max_time, warm_start=warm_start,
                               timeline_every=timeline_every,
                               failures=failures)
    if engine == "fixed":
        if failures is not None:
            raise ValueError("failure injection requires engine='event'")
        return simulate_fixed_tick(requests, controller, cluster, dt=dt,
                                   control_interval=control_interval,
                                   max_time=max_time, warm_start=warm_start,
                                   timeline_every=timeline_every)
    raise ValueError(f"unknown engine {engine!r} (want 'event' or 'fixed')")


def default_perf_factory(**perf_kw) -> Callable[[str], PerfModel]:
    cache = {}

    def factory(model: str) -> PerfModel:
        if model not in cache:
            cache[model] = PerfModel(model, **perf_kw)
        return cache[model]
    return factory
