"""Cluster simulation: event-driven core + fixed-tick reference loop.

The event-driven core (``simulate_events``) drives the cluster off a
time-ordered event heap — request arrivals, instance-ready transitions,
per-instance completion estimates, control ticks, injected instance
failures, and timeline samples — so idle spans cost zero work and
million-request traces run in seconds. The identical ``repro.core``
autoscaler code used by the real engine runs in the control loop — only
the data plane is simulated (DESIGN.md §4), as a fluid model whose
composition changes happen exactly at event times.

Both engines accept either a materialized ``List[Request]`` or a columnar
:class:`~repro.sim.workload.Trace`. The event core walks a Trace through a
chunked cursor that materializes ``Request`` objects lazily in arrival
order, so a 1M-request replay never builds a million objects up front.

The hot path is columnar end to end: the cursor installs a
:class:`~repro.sim.ledger.RequestLedger` (outcomes recorded by integer
row id alongside the ``Request`` view; metrics reduce over arrays), and
the control-tick catch-up runs through the cluster's vectorized
:class:`~repro.sim.cluster.InstancePlane` — one array pass over every
instance's fluid state instead of O(instances) Python calls, with
identical arithmetic to the per-object path so scaling decisions are
bit-for-bit equivalent.

Failure injection: pass ``failures=FailurePlan(times, seed=...)`` and the
event core crashes a uniformly-drawn active instance at each time — the
instance is removed (chips freed, ``cluster.failures`` counted separately
from autoscaling actions), its in-flight requests lose their KV and
re-queue, and the control hierarchy heals the fleet on its next tick.
``degradations=DegradationPlan(...)`` is the partial-failure sibling: the
victim stays up but its ITL inflates by a factor for a while; the control
plane detects it through the health EWMA and routes around it.

Multi-cluster fleets: ``simulate_fleet`` drives a ``repro.sim.fleet``
Fleet — several clusters, each with its own queue and Chiron hierarchy —
off one shared event heap, adding cross-region network-delay events for
routed arrivals and placement warm-up events for model migrations.

``simulate_fixed_tick`` is the original discrete-time loop (default tick
0.25 s), kept as the equivalence reference and quantization baseline.
``simulate`` keeps the historical signature and dispatches to either
engine (event-driven by default).
"""
from __future__ import annotations

import functools
import gc
import heapq
import itertools
import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.obs.recorder import (R_INJECTED as _R_INJECTED,
                                R_OUTAGE as _R_OUTAGE)
from repro.serving.global_queue import (GlobalQueue, ReferenceGlobalQueue,
                                        make_queue)
from repro.serving.request import Request
from repro.sim.cluster import InstanceState, InstanceType, SimCluster
from repro.sim.controllers import BaseController
from repro.sim.ledger import RequestLedger
from repro.sim.metrics import RunResult, Shock, Timeline
from repro.sim.overload import (BrownoutState, OverloadConfig, WaitGauge,
                                is_overloaded)
from repro.sim.perf_model import PerfModel
from repro.sim.workload import Trace, TraceStream

# heap-event kinds; the tuple position makes READY sort before COMPLETION
# and COMPLETION before FAILURE at equal timestamps (an instance activates
# before its estimates fire; finishes land before the crash takes them).
# _NET (cross-region arrival) and _WARM (placement warm-up) are fleet-only.
# _OUTAGE/_RESTORE drive correlated zone failures with staged capacity
# return; _BURST marks a flash-crowd onset in the decision ledger.
# _RETRY is a client re-arrival of a rejected/shed request after its
# deterministic jittered backoff (payload: the Request itself).
(_READY, _COMPLETION, _FAIL, _DEGRADE, _RECOVER, _NET, _WARM,
 _OUTAGE, _RESTORE, _BURST, _RETRY) = range(11)

_INF = float("inf")

RequestSource = Union[Sequence[Request], Trace, TraceStream]


def _gc_paused(fn):
    """Run ``fn`` with the cyclic garbage collector paused (restored on
    exit). The event core's churn — event tuples, SimSeqs, per-request
    dicts — is entirely reference-counted; the only cycles are the
    handful of long-lived instance/cluster backrefs. Leaving the
    generational collector armed makes it sweep a multi-million-object
    heap thousands of times per 1M-request run for nothing (~8% of
    wall). No-op when the caller already disabled collection."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        was_enabled = gc.isenabled()
        if was_enabled:
            gc.disable()
        try:
            return fn(*args, **kwargs)
        finally:
            if was_enabled:
                gc.enable()
    return wrapper


@dataclass
class FailurePlan:
    """Crash schedule for failure injection: at each time in ``times``,
    ``victims`` uniformly-drawn *active* instances crash (a correlated
    multi-victim burst when > 1). Victim draws come from
    ``default_rng(seed)`` over the id-sorted active list; exactly one
    draw is consumed per scheduled victim whether or not an eligible
    instance exists at event time (ineligible slots are counted in
    ``RunResult.skipped_injections`` instead of silently shifting every
    later draw), so a plan is fully deterministic for a given run."""
    times: Sequence[float]
    seed: int = 0
    victims: int = 1

    def sorted_times(self) -> List[float]:
        return sorted(float(t) for t in self.times)


@dataclass
class DegradationPlan:
    """Slow-node schedule: at each time in ``times`` one uniformly-drawn
    *healthy* active instance has its ITL inflated by ``factor`` for
    ``duration`` seconds (then it recovers). Unlike a crash the instance
    keeps its work — the failure mode is silent throughput loss, which the
    control plane must *detect* (health EWMA) rather than observe as a
    membership change. Victim draws are seeded like :class:`FailurePlan`."""
    times: Sequence[float]
    factor: float = 4.0
    duration: float = 300.0
    seed: int = 0

    def sorted_times(self) -> List[float]:
        return sorted(float(t) for t in self.times)


@dataclass
class OutagePlan:
    """Correlated zone outage: at ``start`` every live instance (or the
    seeded ``fraction`` of them) in the target cluster crashes *at once*
    and that share of the zone's chip budget is withheld; capacity
    returns in ``recovery_stages`` equal steps every ``stage_interval``
    seconds starting at ``start + duration``. Displaced requests lose
    their KV and requeue; the control hierarchy must re-provision into
    the staged budget as it comes back.

    ``cluster`` names the victim zone for :func:`simulate_fleet`
    (``Fleet.by_name``); the single-cluster engine ignores it (the only
    cluster *is* the zone). Partial outages (``fraction`` < 1) draw the
    victim subset with ``default_rng(seed)`` over the id-sorted live
    list — fully deterministic per run."""
    start: float
    duration: float = 300.0
    cluster: Optional[str] = None
    fraction: float = 1.0
    recovery_stages: int = 1
    stage_interval: float = 60.0
    seed: int = 0

    def end_time(self) -> float:
        """Time the last withheld capacity stage is restored."""
        stages = max(1, int(self.recovery_stages))
        return self.start + self.duration \
            + (stages - 1) * self.stage_interval


@dataclass
class FlashCrowdPlan:
    """Flash-crowd demand shock: ``model`` goes from zero to a dominant
    arrival share within minutes, exercising on-the-fly model discovery,
    placement warm-up, and (in fleet mode) the Router's spillover.

    The shock *arrivals* are a seeded trace merged into the run's input
    at build time (:func:`arrival_times` generates the ramp; the
    ``flash_crowd`` scenario wraps it) — arrivals must flow through the
    normal cursor/ledger plumbing to stay columnar. The plan passed to
    the engines marks the shock window on ``RunResult.shocks`` for the
    recovery metrics and fires a ``_BURST`` heap event at onset so the
    decision ledger carries the term that fired."""
    start: float
    ramp: float = 120.0         # seconds from zero to peak rate
    duration: float = 600.0     # total elevated-arrival window
    model: str = "llama-70b"
    peak_rate: float = 20.0     # arrivals/s at the top of the ramp
    seed: int = 0

    def end_time(self) -> float:
        return self.start + self.duration

    def arrival_times(self) -> np.ndarray:
        """Seeded arrival offsets for the shock (absolute times): the
        expected count for a linear zero-to-peak ramp followed by a
        plateau, placed by inverse-CDF sampling of that rate profile —
        deterministic for a given seed."""
        rng = np.random.default_rng(self.seed)
        span = max(float(self.duration), 1e-9)
        ramp = min(max(float(self.ramp), 1e-9), span)
        area = 0.5 * ramp + (span - ramp)    # rate units of peak_rate
        n = max(1, int(round(self.peak_rate * area)))
        u = np.sort(rng.random(n)) * area
        cut = 0.5 * ramp
        times = np.where(u < cut,
                         np.sqrt(np.maximum(2.0 * u * ramp, 0.0)),
                         ramp + (u - cut))
        return self.start + times


def _as_plans(value, klass) -> List:
    """Normalize an engine chaos-plan kwarg: None, a single plan, or a
    sequence of plans -> list."""
    if value is None:
        return []
    if isinstance(value, klass):
        return [value]
    return list(value)


class _RequestCursor:
    """Arrival-ordered request source over a list, a columnar Trace, or a
    chunked :class:`TraceStream` — and the owner of the run's
    :class:`RequestLedger`.

    Trace mode materializes ``Request`` objects in chunks as the arrival
    loop consumes them — peeking the next arrival time reads the float
    column directly, so unarrived requests cost no Python objects. Stream
    mode pulls the next file chunk only when the previous one is consumed,
    so a multi-day replay never holds the whole file columnar. In every
    mode the ledger rows line up with arrival order and each materialized
    ``Request`` carries its row id.
    """

    def __init__(self, source: RequestSource, chunk: int = 16384):
        self._chunk = chunk
        self._trace = None
        self._stream = None
        if isinstance(source, Trace):
            self._trace = source.sorted_by_arrival()
            self._times = self._trace.arrival
            # plain-float shadow of the arrival column: the per-event
            # peek (`times[j] <= limit`, and the returned next-arrival)
            # costs a C-double compare instead of a NumPy scalar
            # box/unbox round-trip on every single arrival
            self._times_l = self._times.tolist()
            self.n = self._trace.n
            self.all: List[Request] = []
            self.ledger = RequestLedger.from_trace(self._trace)
        elif isinstance(source, TraceStream):
            self._stream = source
            self.n = 0                   # grows as chunks are pulled
            self.all = []
            self.ledger = RequestLedger(0)
        else:
            self.all = sorted(source, key=lambda r: r.arrival_time)
            self.n = len(self.all)
            self.ledger = RequestLedger.from_requests(self.all)
        self._i = 0

    def _pull_chunk(self) -> bool:
        """Stream mode: materialize the next chunk; False at EOF."""
        try:
            tr = next(self._stream)
        except StopIteration:
            self._stream = None
            return False
        base = self.ledger.extend_from_trace(tr)
        self.all.extend(tr.materialize(row0=base))
        self.n += tr.n
        return True

    @property
    def exhausted(self) -> bool:
        if self._i >= self.n and self._stream is not None:
            self._pull_chunk()
        return self._i >= self.n

    def peek_time(self) -> float:
        if self.exhausted:
            return _INF
        if self._trace is not None:
            return self._times_l[self._i]
        return self.all[self._i].arrival_time

    def pop(self) -> Request:
        if self._trace is not None and self._i >= len(self.all):
            lo = len(self.all)
            self.all.extend(self._trace.materialize(lo, lo + self._chunk,
                                                    row0=lo))
        req = self.all[self._i]
        self._i += 1
        return req

    def pop_next(self):
        """Fused ``(pop(), peek_time())`` — one call on the arrival hot
        path instead of two."""
        i = self._i
        all_ = self.all
        if self._trace is not None:
            if i >= len(all_):
                self.all.extend(self._trace.materialize(
                    i, i + self._chunk, row0=i))
                all_ = self.all
            req = all_[i]
            i += 1
            self._i = i
            return req, (self._times_l[i] if i < self.n else _INF)
        req = all_[i]
        self._i = i + 1
        return req, self.peek_time()

    def pop_until(self, limit: float):
        """``(cohort, next_time)``: every request with
        ``arrival_time <= limit`` — the exact set the per-arrival loop
        would pop — as one cohort, plus the arrival time of the first
        request *past* the cohort (``inf`` at EOF), fused so the hot loop
        pays one call. Trace mode checks the next arrival scalar first
        (cohorts of one dominate sparse traces) and only falls back to a
        ``searchsorted`` over the arrival column for true bursts, then
        materializes the whole cohort in one slice (the NumPy-batched
        arrival path); list/stream modes fall back to the scalar walk."""
        i = self._i
        if self._trace is not None:
            times = self._times_l
            n = self.n
            j = i + 1
            if j < n and times[j] <= limit:
                j = int(self._times.searchsorted(limit, side="right"))
            all_ = self.all
            if j > len(all_):
                lo = len(all_)
                all_.extend(self._trace.materialize(
                    lo, max(j, lo + self._chunk), row0=lo))
            self._i = j
            return all_[i:j], (times[j] if j < n else _INF)
        out = []
        while not self.exhausted and self.all[self._i].arrival_time <= limit:
            out.append(self.all[self._i])
            self._i += 1
        return out, self.peek_time()

    def all_requests(self) -> List[Request]:
        """Every request (materializing any unserved tail) for RunResult."""
        if self._trace is not None and len(self.all) < self.n:
            lo = len(self.all)
            self.all.extend(self._trace.materialize(lo, self.n, row0=lo))
        while self._stream is not None:
            self._pull_chunk()
        return self.all


def _warm_start(controller, cluster: SimCluster, t: float, n: int) -> None:
    """Pre-provision ``n`` instances, instantly active (shared by engines);
    multi-model controllers get them round-robin across their fleet."""
    models = getattr(controller, "model_list", None)
    for k in range(n):
        model = models[k % len(models)] if models else \
            getattr(controller, "model", "llama-8b")
        inst = controller._provision(cluster, InstanceType.MIXED, t, model) \
            if hasattr(controller, "_provision") else \
            cluster.provision(model, InstanceType.MIXED, t,
                              static_batch=getattr(controller, "static_batch",
                                                   64))
        if inst is not None:
            inst.ready_time = t
            inst.activate_if_ready(t)


@_gc_paused
def simulate_events(requests: RequestSource, controller: BaseController,
                    cluster: SimCluster, *, control_interval: float = 1.0,
                    max_time: float = 7200.0, warm_start: int = 0,
                    timeline_every: float = 1.0,
                    completion_grain: float = 0.25,
                    quantize: float = 0.0,
                    failures: Optional[FailurePlan] = None,
                    degradations: Optional[DegradationPlan] = None,
                    outages=None,
                    flash_crowds=None,
                    detector=None,
                    reference: bool = False,
                    shadow_verify=None,
                    telemetry=None,
                    overload: Optional[OverloadConfig] = None,
                    phase_timers=None) -> RunResult:
    """Event-driven simulation. ``quantize > 0`` snaps every event time up
    to that grid, making the run a *sparse fixed-tick*: it touches only
    non-empty ticks yet batches arrivals/completions exactly like a
    ``simulate_fixed_tick`` run at ``dt=quantize`` — the mode the
    engine-equivalence comparison uses.

    ``reference=True`` runs the pre-columnar-refactor control flow — no
    arrival fast path, no saturation memo, per-object (never vectorized)
    control-tick catch-up — as the equivalence baseline the columnar hot
    path is tested against. Results must be identical either way.

    ``shadow_verify`` enables the runtime mirror auditor: pass a
    :class:`repro.analysis.shadow.ShadowVerifier` (or any truthy value,
    or set ``CHIRON_SHADOW_VERIFY=1``) to rebuild the ledger/plane
    columns from the objects at control ticks and completion sweeps and
    assert exact agreement. Raises ``ShadowVerifyError`` on desync.

    ``telemetry`` arms the flight recorder (``repro.obs``): pass a
    :class:`repro.obs.FlightRecorder` (or any truthy value, or set
    ``CHIRON_TELEMETRY=1``) to record control-plane signal/tick columns,
    the decision ledger, and sampled request-lifecycle spans. The
    recorder rides on the result as ``RunResult.telemetry``; decisions
    are bit-identical either way.

    ``overload`` arms the overload control plane
    (:class:`repro.sim.overload.OverloadConfig`): SLO-aware admission,
    deadline shedding, deterministic client retries, and brownout mode.
    ``None`` (or an all-``None`` config) is bit-identical to the
    pre-overload engine; requires the columnar path (``reference=False``).

    ``phase_timers`` (``scripts/profile_sim.py --phases``) is an injected
    accumulator with ``clock()``/``lap(name, t0)`` — the loop brackets
    its six numbered phases with it; ``None`` (the default) costs one
    predicted branch per phase."""
    from repro.analysis.shadow import resolve as _shadow_resolve
    from repro.obs.recorder import resolve as _obs_resolve
    shadow = _shadow_resolve(shadow_verify)
    rec = _obs_resolve(telemetry)
    ov = overload if overload is not None and overload.active else None
    if ov is not None and reference:
        raise ValueError("overload control requires the columnar engine "
                         "(reference=True is the pre-overload baseline)")
    queue = make_queue(reference)
    cursor = _RequestCursor(requests)
    t = 0.0
    cluster.event_mode = True
    cluster.now = 0.0
    cluster.completion_grain = completion_grain
    cluster.quantize = quantize
    cluster.ledger = cursor.ledger
    if detector is not None:
        cluster.detector = detector
    if rec is not None:
        # attach before the warm start so bootstrap provisions land in
        # the decision ledger too (replay() then matches scale_ups)
        rec.register_cluster(cluster, "cluster")
        cluster.obs = rec
        controller.obs = rec

    _warm_start(controller, cluster, t, warm_start)
    # instances provisioned before this call (still LOADING) also need
    # READY events — fold them into the new-loading drain
    cluster.new_loading = [i for i in cluster.instances
                           if i.state == InstanceState.LOADING]

    heap: list = []                  # (time, kind, seq, instance, epoch)
    ev_seq = itertools.count()
    timeline = Timeline()
    next_control = 0.0
    control_parked = False
    next_timeline = 0.0
    last_sample_t = 0.0
    n_events = 0
    batch_seq = 0                    # event-batch stamp (ETA-cache key)
    eps = 1e-12
    # One-slot completion staging: the single-dirty sweep (one admit or
    # one completion per event, the steady-state shape) parks its fresh
    # estimate here instead of heap-pushing it. When the *same* instance
    # sweeps again before the estimate fires, the epoch bump that
    # schedules the replacement has already made the staged tuple stale —
    # it is overwritten in place, saving both the push and the later
    # stale pop. A staged event otherwise behaves exactly like the heap
    # head: it joins the t_next min, disarms the arrival fast path at its
    # timestamp, and is heap-pushed (firing in exact tuple order) the
    # moment it comes due or a different instance sweeps.
    pend = None
    use_pend = quantize == 0         # sparse fixed-tick keeps plain pushes

    # hot-path locals (attribute lookups hoisted out of the loop)
    observe_arrival = getattr(controller, "observe_arrival", None)
    observe_completion = controller.observe_completion
    route_interactive = getattr(controller, "route_interactive", None)
    route_arrival = getattr(controller, "route_arrival", None) \
        if quantize == 0 and not reference else None
    route_burst = getattr(controller, "route_arrival_burst", None) \
        if route_arrival is not None else None
    use_memo = not reference
    if reference:
        cluster.vec_min = 1 << 30        # scalar catch-up only

    # ---- overload control plane (all off when ov is None) ----
    ov_adm = ov.admission if ov is not None else None
    ov_shed = ov.shedding if ov is not None else None
    ov_retry = ov.retry if ov is not None else None
    ov_brown = ov.brownout if ov is not None else None
    gauge = None
    brownout = None
    pending_retry = 0                # scheduled _RETRY events outstanding
    led = cursor.ledger
    if ov is not None:
        gauge = WaitGauge(controller, cluster)
        if not gauge.supported:
            # admission/brownout need the controller's QLM estimators;
            # shedding and retries still work without them
            ov_adm = None
            ov_brown = None
        if ov_brown is not None:
            brownout = BrownoutState()
    if ov_adm is not None:
        # the admission gate must see every arrival before placement —
        # disable the zero-queuing arrival fast path (routing still
        # drains the queue on the same event)
        route_arrival = None
        route_burst = None

    queue_push = queue.push
    heappush = heapq.heappush
    heappop = heapq.heappop
    heapify = heapq.heapify
    pop_until = cursor.pop_until
    ACTIVE = InstanceState.ACTIVE
    cdirty = cluster.dirty               # stable set object, never rebound
    timers = phase_timers
    timing = timers is not None
    # steady-state arrival micro-loop eligibility (see loop tail): only
    # the plain event mode qualifies — shadow audits, phase timing, and
    # sparse fixed-tick all need the full per-phase scan
    # (the flight recorder does not disqualify: its hooks live on the
    # state mutations — admit/evict/provision — which the micro-loop
    # reaches through the same routing calls as the full scan)
    inner_on = (route_burst is not None and route_interactive is not None
                and shadow is None and not timing and quantize == 0
                and ov is None)

    fail_rng = None
    if failures is not None:
        fail_rng = np.random.default_rng(failures.seed)
        for tf in failures.sorted_times():
            heappush(heap, (tf, _FAIL, next(ev_seq), None, 0))
    deg_rng = None
    if degradations is not None:
        deg_rng = np.random.default_rng(degradations.seed)
        for td in degradations.sorted_times():
            heappush(heap, (td, _DEGRADE, next(ev_seq), None, 0))
    skipped_injections = 0
    shocks: List[Shock] = []
    for plan in _as_plans(outages, OutagePlan):
        heappush(heap, (float(plan.start), _OUTAGE, next(ev_seq), plan, 0))
        shocks.append(Shock("outage", float(plan.start), plan.end_time(),
                            plan.cluster or ""))
    for plan in _as_plans(flash_crowds, FlashCrowdPlan):
        heappush(heap, (float(plan.start), _BURST, next(ev_seq), plan, 0))
        shocks.append(Shock("flash_crowd", float(plan.start),
                            plan.end_time(), plan.model))

    def _sample(now: float) -> None:
        nonlocal last_sample_t, next_timeline
        rate = cluster.take_tokens() / max(now - last_sample_t, 1e-9)
        n_i, n_m, n_b = cluster.counts_by_type()
        timeline.append_sample(
            now, n_i, n_m, n_b, cluster.used_chips(),
            queue.n_interactive, queue.n_batch, rate,
            q_interactive_by_model={m: queue.n_interactive_for(m)
                                    for m in queue.interactive_models()},
            q_batch_by_model={m: queue.n_batch_for(m)
                              for m in queue.batch_models()})
        last_sample_t = now
        next_timeline = now + timeline_every

    def _maybe_retry(req: Request, now: float) -> None:
        """Schedule the client's next attempt for a rejected/shed request
        (jittered exponential backoff, abandoned past the retry budget).
        The object/ledger row stays terminal until the attempt lands."""
        nonlocal pending_retry
        if ov_retry is None:
            return
        attempt = req.retries + 1
        if attempt > ov_retry.max_retries:
            return
        key = req.row if req.row >= 0 else req.req_id
        when = now + ov_retry.backoff(key, attempt)
        if when > req.arrival_time + ov_retry.budget:
            return
        led.bump_retry(req)
        pending_retry += 1
        heappush(heap, (when, _RETRY, next(ev_seq), req, 0))

    def _admit(req: Request, now: float) -> bool:
        """Admission gate (arrivals and retry re-arrivals): queue the
        request, or refuse it as REJECTED when its estimated wait at max
        budget already blows the TTFT SLO — no autoscaling decision could
        save it (QLM-style infeasibility)."""
        if req.is_interactive:
            budget_w = ov_adm.slack * req.slo.ttft
            wait = gauge.wait(queue, req.model)
            if wait > budget_w:
                led.mark_rejected(req)
                if rec is not None:
                    rec.record_reject(cluster, now, req.model, wait,
                                      budget_w)
                _maybe_retry(req, now)
                return False
        queue_push(req)
        return True

    def _overload_tick(now: float) -> None:
        """Control-tick overload pass: brownout hysteresis first (an
        entering tick sheds proactively below), then the vectorized
        deadline sweep over the interactive lanes. Batch lanes are never
        touched — batch work defers, it does not drop."""
        if brownout is not None:
            flip = brownout.update(
                is_overloaded(cluster, queue, gauge, ov_brown), ov_brown)
            if flip is not None:
                controller.brownout_active = flip
                if rec is not None:
                    rec.record_brownout(cluster, now, flip,
                                        queue.n_interactive,
                                        ov_brown.queue_min)
                if flip:
                    controller.brownout_preempt_batch(cluster, queue, now)
        if ov_shed is not None and queue._icount:
            wbm = None
            if brownout is not None and brownout.engaged \
                    and gauge is not None and gauge.supported:
                # brownout sheds proactively: entries that cannot reach
                # service before their deadline at the estimated
                # per-request drain rate are dropped now, not at expiry
                wbm = {m: gauge.per_request_wait(m)
                       for m in queue.interactive_models()}
            expired, shed = queue.sweep_interactive(
                now, grace=ov_shed.grace, wait_by_model=wbm)
            for req in expired:
                led.mark_expired(req)
            for req in shed:
                led.mark_shed(req)
                _maybe_retry(req, now)
            if rec is not None:
                for reqs, hook in ((expired, rec.record_expire),
                                   (shed, rec.record_shed)):
                    counts: Dict[str, int] = {}
                    for req in reqs:
                        counts[req.model] = counts.get(req.model, 0) + 1
                    for m in sorted(counts):
                        hook(cluster, now, m, counts[m])

    t_arr = cursor.peek_time()

    predrain = quantize == 0

    while True:
        # ---- termination: all requests arrived, none queued or running,
        # and no client retry is still in backoff
        if t_arr == _INF and cluster.total_running == 0 \
                and len(queue) == 0 and pending_retry == 0:
            break

        # ---- stale completion estimates (superseded by a newer epoch, or
        # on a retired instance) that land strictly before every other
        # event source would each burn a full loop iteration doing
        # provably nothing: no state change, no routing work (queue
        # empty), no control tick, no timeline sample. Drain them in one
        # tight pass, replicating the per-event chip-second accumulation
        # exactly (it is NOT float-associative across segments), so
        # results stay bit-identical to the one-iteration-per-pop flow.
        if predrain and heap and not (queue._icount or queue._bcount):
            pt = pend[0] if pend is not None else _INF
            while heap:
                ev = heap[0]
                th = ev[0]
                if th >= t_arr - eps or th >= next_control - eps \
                        or th >= next_timeline - eps or th >= pt - eps \
                        or th > max_time or ev[1] != _COMPLETION:
                    break
                inst = ev[3]
                if ev[4] == inst._epoch \
                        and inst.state == InstanceState.ACTIVE:
                    break                    # live estimate — a real event
                heappop(heap)
                n_events += 1
                if th > cluster.now:         # inline advance_time
                    cluster.chip_seconds += \
                        cluster._used_chips * (th - cluster.now)
                    cluster.now = th

        # ---- next event time across all sources
        t_next = t_arr
        if heap and heap[0][0] < t_next:
            t_next = heap[0][0]
        if pend is not None and pend[0] < t_next:
            t_next = pend[0]
        if next_control < t_next:
            t_next = next_control
        if not control_parked and next_timeline < t_next:
            t_next = next_timeline
        if quantize > 0:                 # sparse fixed-tick alignment
            t_next = math.ceil(t_next / quantize - 1e-9) * quantize
        if t_next > max_time or t_next == _INF:
            cluster.advance_time(max_time)   # idle chip-time to the horizon
            t = max_time
            break
        t = t_next
        if t > cluster.now:                  # inline advance_time
            cluster.chip_seconds += cluster._used_chips * (t - cluster.now)
            cluster.now = t
        batch_seq += 1
        cluster.batch_seq = batch_seq
        changed = False

        if timing:
            _t0 = timers.clock()

        # 1. arrivals due at t, popped as one cohort (Trace mode finds
        #    the extent with one searchsorted and materializes one
        #    slice). When nothing else shares the timestamp (no heap
        #    event, no control tick — so steps 2-4 would change nothing
        #    before routing) interactive arrivals into empty lanes take
        #    the zero-queuing fast path: the whole burst routes through
        #    one ``route_arrival_burst`` call, placed directly and
        #    skipping the queue round-trip the full pass would undo.
        if t_arr <= t + eps:
            fast = route_arrival is not None \
                and not (heap and heap[0][0] <= t + eps) \
                and not (pend is not None and pend[0] <= t + eps) \
                and next_control > t + eps
            cohort, t_arr = cursor.pop_until(t + eps)
            n_events += len(cohort)
            changed = True
            if fast and route_burst is not None:
                route_burst(cluster, queue, cohort, t, observe_arrival)
            elif ov_adm is not None:
                for req in cohort:
                    if observe_arrival is not None:
                        observe_arrival(req, t)
                    _admit(req, t)
            else:
                for req in cohort:
                    if observe_arrival is not None:
                        observe_arrival(req, t)
                    if not (fast and queue._icount == 0
                            and route_arrival(cluster, queue, req, t)):
                        queue_push(req)

        if timing:
            _t0 = timers.lap("arrivals", _t0)

        # 2. instance events due at t (ready transitions, completion
        #    estimates, injected crashes; stale estimates are skipped via
        #    the epoch stamp). Instances that gained capacity are
        #    backfilled directly below.
        freed = ()                       # lazily a list once events fire
        if pend is not None and pend[0] <= t + eps:
            # repro-lint: ok(DET204, staged 5-tuple built inline)
            heappush(heap, pend)         # due: fire in exact tuple order
            pend = None
        if heap and heap[0][0] <= t + eps:
            freed = []
        while heap and heap[0][0] <= t + eps:
            _, kind, _, inst, epoch = heappop(heap)
            n_events += 1
            if kind == _READY:
                if inst.state == InstanceState.LOADING:
                    # the event was scheduled at ready_time exactly; t may
                    # sit an epsilon below it (accumulated control-clock
                    # float error) and the event must not be lost
                    inst.activate_if_ready(max(t, inst.ready_time))
                    inst.mark_dirty()
                    freed.append(inst)
                    changed = True
            elif kind == _FAIL:
                # crash ``victims`` uniformly-drawn active instances
                # (id-ordered registry + seeded rng -> deterministic
                # victims per run). Exactly one draw per victim slot,
                # eligible or not: an empty fleet skips the slot and
                # counts it instead of shifting every later draw.
                for _ in range(max(1, failures.victims)):
                    draw = int(fail_rng.integers(1 << 30))
                    active = cluster.active_sorted()
                    if not active:
                        skipped_injections += 1
                        continue
                    victim = active[draw % len(active)]
                    if victim in freed:
                        freed.remove(victim)
                    displaced = cluster.fail_instance(victim)
                    # fluid state settled at the crash instant: finishes
                    # that beat the crash still count, the rest requeue
                    for r in victim.drain_finished():
                        observe_completion(r)
                    for r in displaced:
                        queue.requeue(r)
                    cluster.dirty.discard(victim)
                    changed = True
            elif kind == _DEGRADE:
                # slow a uniformly-drawn healthy active instance (one
                # draw per event whether or not a candidate exists — see
                # _FAIL); recovery is scheduled as its own event
                draw = int(deg_rng.integers(1 << 30))
                cands = [i for i in cluster.active_sorted()
                         if i.slow_factor == 1.0]
                if cands:
                    victim = cands[draw % len(cands)]
                    cluster.degrade_instance(victim, degradations.factor, t)
                    heappush(heap, (t + degradations.duration,
                                    _RECOVER, next(ev_seq), victim, 0))
                    changed = True
                else:
                    skipped_injections += 1
            elif kind == _RECOVER:
                if inst.state != InstanceState.RETIRED \
                        and inst.slow_factor != 1.0:
                    cluster.recover_instance(inst, t)
                    changed = True
            elif kind == _OUTAGE:
                # correlated zone outage: every live instance (or the
                # seeded fraction) crashes at once and the zone's chip
                # budget is withheld; staged _RESTORE events return it
                plan = inst                     # payload: the OutagePlan
                victims = sorted(cluster.instances, key=lambda i: i.id)
                if plan.fraction < 1.0 and victims:
                    k = min(len(victims), max(1, math.ceil(
                        plan.fraction * len(victims))))
                    sel = np.random.default_rng(plan.seed).permutation(
                        len(victims))[:k]
                    sel.sort()
                    victims = [victims[int(i)] for i in sel]
                if not victims:
                    skipped_injections += 1
                withhold = int(round(min(plan.fraction, 1.0)
                                     * cluster.max_chips))
                if rec is not None:
                    rec.record_outage(cluster, t, len(victims), withhold)
                    rec.inj_reason = _R_OUTAGE
                for victim in victims:
                    if victim in freed:
                        freed.remove(victim)
                    displaced = cluster.fail_instance(victim)
                    for r in victim.drain_finished():
                        observe_completion(r)
                    for r in displaced:
                        queue.requeue(r)
                    cluster.dirty.discard(victim)
                if rec is not None:
                    rec.inj_reason = _R_INJECTED
                stages = max(1, int(plan.recovery_stages))
                base_amt, rem = divmod(withhold, stages)
                for k2 in range(stages):
                    amt = base_amt + (1 if k2 < rem else 0)
                    heappush(heap, (plan.start + plan.duration
                                    + k2 * plan.stage_interval,
                                    _RESTORE, next(ev_seq), amt, 0))
                cluster.max_chips -= withhold
                cluster.route_version += 1
                changed = True
            elif kind == _RESTORE:
                # one staged tranche of withheld outage capacity returns
                cluster.max_chips += inst       # payload: chip count
                cluster.route_version += 1
                if rec is not None:
                    rec.record_restore(cluster, t, inst)
                changed = True
            elif kind == _BURST:
                # flash-crowd onset: the shock arrivals ride the trace;
                # this marks the term that fired in the decision ledger
                if rec is not None:
                    rec.record_flash_crowd(cluster, t, inst.model)
                changed = True
            elif kind == _RETRY:
                # client retry re-arrival (payload: the Request): the
                # attempt re-enters the lifecycle with a fresh per-attempt
                # deadline, counts as observed demand (retry storms
                # inflate the forecast — that is the point), and faces
                # the admission gate again
                req = inst
                pending_retry -= 1
                if observe_arrival is not None:
                    observe_arrival(req, t)
                req.deadline_at = t + req.slo.ttft
                led.mark_queued(req)
                if ov_adm is not None:
                    _admit(req, t)
                else:
                    queue_push(req)
                changed = True
            elif epoch == inst._epoch and inst.state == InstanceState.ACTIVE:
                inst.advance(t)
                freed.append(inst)
                changed = True

        if timing:
            _t0 = timers.lap("heap_drain", _t0)

        # a parked control loop resumes as soon as anything happens
        if control_parked and changed:
            next_control = t
            control_parked = False

        # 3. control tick: align every instance's fluid state with ``t``
        #    (vectorized instance-plane pass above the scalar cut-over),
        #    then run the identical production control path
        ran_control = t >= next_control - eps
        if ran_control:
            n_events += 1
            cluster.catch_up(t, batch_seq)
            if shadow is not None:
                shadow.verify_cluster(cluster)
                shadow.verify_queue(queue)
                shadow.maybe_verify_ledger(cursor.ledger, cursor.all, t)
            if ov is not None:
                _overload_tick(t)
            pre = (len(cluster.instances), cluster.scale_ups,
                   cluster.scale_downs)
            controller.control(cluster, queue, t)
            # schedule ready events for instances the controller provisioned
            for inst in cluster.drain_new_loading():
                heappush(heap, (inst.ready_time, _READY,
                                next(ev_seq), inst, 0))
            post = (len(cluster.instances), cluster.scale_ups,
                    cluster.scale_downs)
            if rec is not None:
                rec.record_cluster_tick(t, cluster, queue)
            quiescent = (pre == post and len(queue) == 0
                         and cluster.total_running == 0
                         and cluster.n_loading == 0)
            if quiescent:
                # deterministic controller + unchanged inputs -> nothing can
                # change before the next arrival; park the control loop
                next_control = t_arr
                control_parked = True
            else:
                next_control = t + control_interval

        if timing:
            _t0 = timers.lap("control", _t0)

        # 4. routing: the full preferential pass runs at control ticks; in
        #    between, interactive dispatch stays zero-queuing on every event
        #    and only just-freed instances are backfilled from the batch
        #    queue — the hot path never rescans the whole cluster
        if ran_control or route_interactive is None:
            controller.route(cluster, queue, t)
        else:
            if queue._icount:
                route_interactive(cluster, queue, t, use_memo)
            if freed and queue._bcount:
                if len(freed) > 1:
                    # preserve pool preference: batch instances first
                    freed.sort(key=lambda i:
                               i.itype != InstanceType.BATCH)
                controller.backfill(freed, queue, t)

        if timing:
            _t0 = timers.lap("routing", _t0)

        # 5. sweep instances touched this batch: surface completions to
        #    the controller, then one vectorized ETA recompute over the
        #    plane columns (``sweep_etas``: cached catch-up ETAs reused,
        #    the rest batch-recomputed) feeds a single bulk heap refill.
        #    Epochs still advance per instance, so stale estimates cancel
        #    exactly as the per-instance re-push did.
        if cdirty:
            if len(cdirty) == 1:
                # single-dirty fast path (the common shape: one admit or
                # one completion per event) — same operations as the
                # general branch below, minus the list plumbing
                inst = cdirty.pop()
                pf = inst._pending_finished
                if pf:
                    inst._pending_finished = []
                    for r in pf:
                        observe_completion(r)
                if inst.state == InstanceState.ACTIVE:
                    if inst._eta_stamp != batch_seq:
                        inst._eta_val = inst.next_event_in()
                        inst._eta_stamp = batch_seq
                    eta = inst._eta_val
                    if eta != _INF:
                        inst._epoch += 1
                        ev = (t + eta, _COMPLETION,
                              next(ev_seq), inst, inst._epoch)
                        if use_pend:
                            if pend is not None and pend[3] is not inst:
                                # repro-lint: ok(DET204, staged 5-tuple)
                                heappush(heap, pend)
                            # a same-instance staged tuple was superseded
                            # by the epoch bump above — dropped here
                            # instead of lingering as a stale heap pop
                            pend = ev
                        else:
                            # repro-lint: ok(DET204, ev built inline above)
                            heappush(heap, ev)
            else:
                dirty = cluster.drain_dirty()
                if pend is not None:
                    # repro-lint: ok(DET204, staged 5-tuple)
                    heappush(heap, pend)
                    pend = None
                for inst in dirty:
                    pf = inst._pending_finished
                    if pf:
                        inst._pending_finished = []
                        for r in pf:
                            observe_completion(r)
                refill = cluster.sweep_etas(dirty, batch_seq)
                if refill:
                    # bulk refill: extend+heapify beats k sifts once the
                    # batch is a decent fraction of the heap; pop order
                    # is identical either way (event seqs total-order)
                    if 8 * len(refill) < len(heap):
                        for inst, eta in refill:
                            inst._epoch += 1
                            heappush(heap, (t + eta, _COMPLETION,
                                            next(ev_seq), inst,
                                            inst._epoch))
                    else:
                        for inst, eta in refill:
                            inst._epoch += 1
                            heap.append((t + eta, _COMPLETION,
                                         next(ev_seq), inst, inst._epoch))
                        heapify(heap)
            if shadow is not None:
                shadow.verify_cluster(cluster)

        if timing:
            _t0 = timers.lap("sweep", _t0)

        # 6. timeline sample (suppressed while parked — state is frozen)
        if t >= next_timeline - eps:
            _sample(t)

        if timing:
            timers.lap("sampling", _t0)

        # ---- steady-state arrival micro-loop: while the next cohort
        # lands strictly before every other event source (no heap event
        # or staged completion due, no control tick, no timeline sample),
        # phases 2/3/6 above are provably no-ops and phase 4 reduces to
        # the zero-queuing retry — so the full scan degenerates to
        # arrival → route → sweep. Run exactly those, with the phase
        # bodies replicated verbatim (same float and tie-break order, so
        # results are bit-identical); the win is the per-event fixed
        # overhead of the outer loop, paid once per burst instead of
        # once per arrival.
        if inner_on:
            while (next_control > t_arr + eps
                   and next_timeline > t_arr + eps
                   and t_arr <= max_time
                   and not (heap and heap[0][0] <= t_arr + eps)
                   and not (pend is not None and pend[0] <= t_arr + eps)):
                t = t_arr
                if t > cluster.now:          # inline advance_time
                    cluster.chip_seconds += \
                        cluster._used_chips * (t - cluster.now)
                    cluster.now = t
                batch_seq += 1
                cluster.batch_seq = batch_seq
                cohort, t_arr = pop_until(t + eps)
                n_events += len(cohort)
                route_burst(cluster, queue, cohort, t, observe_arrival)
                if queue._icount:            # zero-queuing retry (phase 4)
                    route_interactive(cluster, queue, t, use_memo)
                if not cdirty:
                    continue
                if len(cdirty) == 1:
                    inst = cdirty.pop()
                    pf = inst._pending_finished
                    if pf:
                        inst._pending_finished = []
                        for r in pf:
                            observe_completion(r)
                    if inst.state == ACTIVE:
                        if inst._eta_stamp != batch_seq:
                            inst._eta_val = inst.next_event_in()
                            inst._eta_stamp = batch_seq
                        eta = inst._eta_val
                        if eta != _INF:
                            inst._epoch += 1
                            ev = (t + eta, _COMPLETION,
                                  next(ev_seq), inst, inst._epoch)
                            if pend is not None and pend[3] is not inst:
                                # repro-lint: ok(DET204, staged 5-tuple)
                                heappush(heap, pend)
                            pend = ev
                else:
                    dirty = cluster.drain_dirty()
                    if pend is not None:
                        # repro-lint: ok(DET204, staged 5-tuple)
                        heappush(heap, pend)
                        pend = None
                    for inst in dirty:
                        pf = inst._pending_finished
                        if pf:
                            inst._pending_finished = []
                            for r in pf:
                                observe_completion(r)
                    refill = cluster.sweep_etas(dirty, batch_seq)
                    if refill:
                        if 8 * len(refill) < len(heap):
                            for inst, eta in refill:
                                inst._epoch += 1
                                heappush(heap, (t + eta, _COMPLETION,
                                                next(ev_seq), inst,
                                                inst._epoch))
                        else:
                            for inst, eta in refill:
                                inst._epoch += 1
                                heap.append((t + eta, _COMPLETION,
                                             next(ev_seq), inst,
                                             inst._epoch))
                            heapify(heap)

    if timeline and t > timeline[-1].t:
        _sample(t)
    if shadow is not None:
        shadow.verify_cluster(cluster)
        shadow.verify_queue(queue)
        shadow.verify_ledger(cursor.ledger, cursor.all)
    if rec is not None:
        cluster.obs = None
        controller.obs = None
    return RunResult(requests=cursor.all_requests(), timeline=timeline,
                     chip_seconds=cluster.chip_seconds,
                     peak_chips=cluster.peak_chips,
                     scale_ups=cluster.scale_ups,
                     scale_downs=cluster.scale_downs,
                     duration=t, failures=cluster.failures,
                     n_events=n_events,
                     degradations=cluster.degradations,
                     skipped_injections=skipped_injections,
                     shocks=shocks,
                     ledger=cursor.ledger, telemetry=rec)


def simulate_fixed_tick(requests: RequestSource, controller: BaseController,
                        cluster: SimCluster, *, dt: float = 0.25,
                        control_interval: float = 1.0,
                        max_time: float = 7200.0, warm_start: int = 0,
                        timeline_every: float = 1.0) -> RunResult:
    """The original discrete-time loop (reference/quantization baseline).
    A Trace input is materialized up front — the reference loop walks
    every tick anyway, so laziness buys nothing here."""
    queue = GlobalQueue()
    if isinstance(requests, Trace):
        requests = requests.sorted_by_arrival().materialize()
    pending = sorted(requests, key=lambda r: r.arrival_time)
    pi = 0
    t = 0.0
    next_control = 0.0
    next_timeline = 0.0
    timeline = Timeline()

    _warm_start(controller, cluster, t, warm_start)

    while t < max_time:
        # 1. arrivals
        while pi < len(pending) and pending[pi].arrival_time <= t:
            queue.push(pending[pi])
            if hasattr(controller, "observe_arrival"):
                controller.observe_arrival(pending[pi], t)
            pi += 1

        # 2. instance state transitions
        for inst in cluster.instances:
            inst.activate_if_ready(t)

        # 3. control (scaling) then routing
        if t >= next_control:
            controller.control(cluster, queue, t)
            next_control = t + control_interval
        controller.route(cluster, queue, t)

        # 4. data-plane step
        tok_this_tick = 0
        for inst in cluster.active_instances():
            finished, toks = inst.step(dt, t)
            tok_this_tick += toks
            for r in finished:
                controller.observe_completion(r)

        cluster.tick_accounting(dt)

        # 5. timeline sample
        if t >= next_timeline:
            n_i, n_m, n_b = cluster.counts_by_type()
            timeline.append_sample(
                t, n_i, n_m, n_b, cluster.used_chips(),
                queue.n_interactive, queue.n_batch,
                tok_this_tick / dt)
            next_timeline = t + timeline_every

        t += dt

        # 6. termination: all requests arrived and none outstanding
        if pi >= len(pending) and len(queue) == 0 and \
                all(not i.running for i in cluster.instances):
            break

    return RunResult(requests=pending, timeline=timeline,
                     chip_seconds=cluster.chip_seconds,
                     peak_chips=cluster.peak_chips,
                     scale_ups=cluster.scale_ups,
                     scale_downs=cluster.scale_downs,
                     duration=t, failures=cluster.failures)


def simulate(requests: RequestSource, controller: BaseController,
             cluster: SimCluster, *, dt: float = 0.25,
             control_interval: float = 1.0, max_time: float = 7200.0,
             warm_start: int = 0, timeline_every: float = 1.0,
             engine: str = "event",
             failures: Optional[FailurePlan] = None,
             degradations: Optional[DegradationPlan] = None,
             outages=None,
             flash_crowds=None,
             telemetry=None,
             overload: Optional[OverloadConfig] = None) -> RunResult:
    """Compatibility wrapper: dispatch to the event-driven core (default)
    or the fixed-tick reference (``engine="fixed"``, where ``dt`` applies;
    failure/degradation/outage injection, flight-recorder telemetry, and
    the overload control plane need the event core).
    """
    if engine == "event":
        return simulate_events(requests, controller, cluster,
                               control_interval=control_interval,
                               max_time=max_time, warm_start=warm_start,
                               timeline_every=timeline_every,
                               failures=failures, degradations=degradations,
                               outages=outages, flash_crowds=flash_crowds,
                               telemetry=telemetry, overload=overload)
    if engine == "fixed":
        if failures is not None or degradations is not None \
                or outages is not None or flash_crowds is not None:
            raise ValueError("failure injection requires engine='event'")
        if telemetry:
            raise ValueError("telemetry requires engine='event'")
        if overload is not None and overload.active:
            raise ValueError("overload control requires engine='event'")
        return simulate_fixed_tick(requests, controller, cluster, dt=dt,
                                   control_interval=control_interval,
                                   max_time=max_time, warm_start=warm_start,
                                   timeline_every=timeline_every)
    raise ValueError(f"unknown engine {engine!r} (want 'event' or 'fixed')")


@_gc_paused
def simulate_fleet(requests: RequestSource, fleet, *,
                   control_interval: float = 1.0, max_time: float = 7200.0,
                   warm_start: int = 0, timeline_every: float = 5.0,
                   completion_grain: float = 0.25,
                   failures: Optional[FailurePlan] = None,
                   degradations: Optional[DegradationPlan] = None,
                   outages=None,
                   flash_crowds=None,
                   detector=None,
                   reference: bool = False,
                   shadow_verify=None,
                   telemetry=None,
                   overload: Optional[OverloadConfig] = None,
                   phase_timers=None) -> RunResult:
    """Multi-cluster event loop: one shared heap drives every cluster in a
    :class:`repro.sim.fleet.Fleet`, each with its own queue and Chiron
    hierarchy (the paper's two tiers), under the fleet's Router/GlobalPlacer
    (the third tier).

    Beyond the single-cluster event kinds, the heap carries cross-region
    network-delay events (a routed arrival reaches a remote cluster's
    queue only after the origin->region latency — TTFT accounting then
    includes the hop for free) and placement warm-up events (a migrated
    model serves only after its weights transferred and loaded).
    ``warm_start`` pre-provisions that many instances *per cluster* over
    the models initially resident there. Failure/degradation victims are
    drawn uniformly over the whole fleet's active instances.

    Reported ``peak_chips`` is the sum of per-cluster peaks (budgets are
    disjoint, so coincident peaks are what capacity planning needs).

    ``shadow_verify`` mirrors :func:`simulate_events`: a truthy value (or
    ``CHIRON_SHADOW_VERIFY=1``) audits every cluster's plane and the
    shared ledger at control ticks and completion sweeps.

    ``telemetry`` mirrors :func:`simulate_events` too: one shared
    :class:`repro.obs.FlightRecorder` spans the fleet — clusters are
    registered under their fleet names, and tier-3 placement actions
    (migrations, hand-backs, drains) land in the decision ledger
    alongside every cluster's own Chiron actions.

    ``overload`` arms the per-cluster overload plane (admission at each
    destination queue, deadline sweeps, client retries re-routed through
    the Router, per-cluster brownout) — and, when the fleet's Router
    carries a :class:`repro.sim.overload.BreakerConfig`, feeds each
    cluster's admission outcomes into its circuit breaker so routing
    deflects around clusters whose rejection-rate EWMA tripped."""
    from repro.analysis.shadow import resolve as _shadow_resolve
    from repro.obs.recorder import resolve as _obs_resolve
    shadow = _shadow_resolve(shadow_verify)
    rec = _obs_resolve(telemetry)
    ov = overload if overload is not None and overload.active else None
    if ov is not None and reference:
        raise ValueError("overload control requires the columnar engine "
                         "(reference=True is the pre-overload baseline)")
    cursor = _RequestCursor(requests)
    clusters = list(fleet.clusters)
    by_sim = {id(fc.cluster): fc for fc in clusters}
    t = 0.0
    use_memo = not reference

    # ---- overload control plane (all off when ov is None) ----
    ov_adm = ov.admission if ov is not None else None
    ov_shed = ov.shedding if ov is not None else None
    ov_retry = ov.retry if ov is not None else None
    ov_brown = ov.brownout if ov is not None else None
    pending_retry = 0
    led = cursor.ledger
    gauges: Dict[int, WaitGauge] = {}
    brownouts: Dict[int, BrownoutState] = {}
    if ov is not None:
        for fc in clusters:
            g = WaitGauge(fc.controller, fc.cluster)
            if g.supported:
                gauges[id(fc)] = g
        if not gauges:
            ov_adm = None
            ov_brown = None
        if ov_brown is not None:
            for fc in clusters:
                if id(fc) in gauges:
                    brownouts[id(fc)] = BrownoutState()
    if rec is not None:
        fleet.obs = rec
    for fc in clusters:
        fc.cluster.event_mode = True
        fc.cluster.now = 0.0
        fc.cluster.completion_grain = completion_grain
        fc.cluster.ledger = cursor.ledger
        if detector is not None:
            fc.cluster.detector = detector
        if reference:
            fc.cluster.vec_min = 1 << 30
            fc.queue = ReferenceGlobalQueue()   # object-queue baseline
        if rec is not None:
            rec.register_cluster(fc.cluster, fc.name)
            fc.cluster.obs = rec
            fc.controller.obs = rec
        _warm_start(fc.controller, fc.cluster, t, warm_start)
        fc.cluster.new_loading = [i for i in fc.cluster.instances
                                  if i.state == InstanceState.LOADING]

    heap: list = []                  # (time, kind, seq, payload, epoch)
    ev_seq = itertools.count()
    timeline = Timeline()
    next_control = 0.0
    next_place = fleet.placer.interval
    control_parked = False
    next_timeline = 0.0
    last_sample_t = 0.0
    n_events = 0
    batch_seq = 0
    pending_net = 0                  # in-flight cross-region arrivals
    eps = 1e-12
    heappush = heapq.heappush
    heappop = heapq.heappop
    heapify = heapq.heapify
    timers = phase_timers
    timing = timers is not None

    fail_rng = None
    if failures is not None:
        fail_rng = np.random.default_rng(failures.seed)
        for tf in failures.sorted_times():
            heappush(heap, (tf, _FAIL, next(ev_seq), None, 0))
    deg_rng = None
    if degradations is not None:
        deg_rng = np.random.default_rng(degradations.seed)
        for td in degradations.sorted_times():
            heappush(heap, (td, _DEGRADE, next(ev_seq), None, 0))
    skipped_injections = 0
    shocks: List[Shock] = []
    for plan in _as_plans(outages, OutagePlan):
        if plan.cluster is not None and plan.cluster not in fleet.by_name:
            raise ValueError(f"OutagePlan: unknown cluster {plan.cluster!r}")
        heappush(heap, (float(plan.start), _OUTAGE, next(ev_seq), plan, 0))
        shocks.append(Shock("outage", float(plan.start), plan.end_time(),
                            plan.cluster or clusters[0].name))
    for plan in _as_plans(flash_crowds, FlashCrowdPlan):
        heappush(heap, (float(plan.start), _BURST, next(ev_seq), plan, 0))
        shocks.append(Shock("flash_crowd", float(plan.start),
                            plan.end_time(), plan.model))

    def emit_warm(delay: float, payload) -> None:
        heappush(heap, (t + max(delay, 0.0), _WARM,
                        next(ev_seq), payload, 0))

    def _maybe_retry(req: Request, now: float) -> None:
        """Schedule the client's next attempt (fleet flavour: the retry
        re-routes through the Router, so an open breaker deflects it)."""
        nonlocal pending_retry
        if ov_retry is None:
            return
        attempt = req.retries + 1
        if attempt > ov_retry.max_retries:
            return
        key = req.row if req.row >= 0 else req.req_id
        when = now + ov_retry.backoff(key, attempt)
        if when > req.arrival_time + ov_retry.budget:
            return
        led.bump_retry(req)
        pending_retry += 1
        heappush(heap, (when, _RETRY, next(ev_seq), req, 0))

    def _enqueue(fc, req: Request, now: float) -> None:
        if ov_adm is not None and req.is_interactive:
            g = gauges.get(id(fc))
            if g is not None:
                budget_w = ov_adm.slack * req.slo.ttft
                wait = g.wait(fc.queue, req.model)
                rejected = wait > budget_w
                trans = fleet.router.note_admission(fc, rejected, now)
                if trans is not None and rec is not None:
                    rec.record_breaker(now, fc.name, trans[0], trans[1],
                                      fleet.router.breaker.open_threshold)
                if rejected:
                    led.mark_rejected(req)
                    if rec is not None:
                        rec.record_reject(fc.cluster, now, req.model,
                                          wait, budget_w)
                    _maybe_retry(req, now)
                    return
        fc.queue.push(req)
        fc.controller.observe_arrival(req, now)

    def _dispatch(req: Request, now: float) -> None:
        nonlocal pending_net
        fc, delay = fleet.route(req, now)
        if delay > eps:
            heappush(heap, (now + delay, _NET, next(ev_seq),
                            (req, fc), 0))
            pending_net += 1
        else:
            _enqueue(fc, req, now)

    def _overload_tick_fc(fc, now: float) -> None:
        """Per-cluster control-tick overload pass (brownout hysteresis,
        then the vectorized interactive deadline sweep)."""
        g = gauges.get(id(fc))
        bstate = brownouts.get(id(fc))
        if bstate is not None and g is not None:
            flip = bstate.update(
                is_overloaded(fc.cluster, fc.queue, g, ov_brown), ov_brown)
            if flip is not None:
                fc.controller.brownout_active = flip
                if rec is not None:
                    rec.record_brownout(fc.cluster, now, flip,
                                        fc.queue.n_interactive,
                                        ov_brown.queue_min)
                if flip:
                    fc.controller.brownout_preempt_batch(fc.cluster,
                                                         fc.queue, now)
        if ov_shed is not None and fc.queue._icount:
            wbm = None
            if bstate is not None and bstate.engaged and g is not None:
                wbm = {m: g.per_request_wait(m)
                       for m in fc.queue.interactive_models()}
            expired, shed = fc.queue.sweep_interactive(
                now, grace=ov_shed.grace, wait_by_model=wbm)
            for req in expired:
                led.mark_expired(req)
            for req in shed:
                led.mark_shed(req)
                _maybe_retry(req, now)
            if rec is not None:
                for reqs, hook in ((expired, rec.record_expire),
                                   (shed, rec.record_shed)):
                    counts: Dict[str, int] = {}
                    for req in reqs:
                        counts[req.model] = counts.get(req.model, 0) + 1
                    for m in sorted(counts):
                        hook(fc.cluster, now, m, counts[m])

    def _all_active():
        # merged per-cluster active registries, id-ordered (deterministic
        # victim draws without scanning every instance per event)
        out = []
        for fc in clusters:
            out.extend(fc.cluster._active.values())
        out.sort(key=lambda i: i.id)
        return out

    def _sample(now: float) -> None:
        nonlocal last_sample_t, next_timeline
        toks = n_i = n_m = n_b = chips = q_i = q_b = 0
        qi_m: Dict[str, int] = {}
        qb_m: Dict[str, int] = {}
        for fc in clusters:
            toks += fc.cluster.take_tokens()
            i, m, b = fc.cluster.counts_by_type()
            n_i += i
            n_m += m
            n_b += b
            chips += fc.cluster.used_chips()
            q = fc.queue
            q_i += q.n_interactive
            q_b += q.n_batch
            for mdl in q.interactive_models():
                qi_m[mdl] = qi_m.get(mdl, 0) + q.n_interactive_for(mdl)
            for mdl in q.batch_models():
                qb_m[mdl] = qb_m.get(mdl, 0) + q.n_batch_for(mdl)
        rate = toks / max(now - last_sample_t, 1e-9)
        timeline.append_sample(now, n_i, n_m, n_b, chips, q_i, q_b, rate,
                               q_interactive_by_model=qi_m,
                               q_batch_by_model=qb_m)
        last_sample_t = now
        next_timeline = now + timeline_every

    t_arr = cursor.peek_time()

    while True:
        # ---- termination: everything arrived, landed, and finished,
        # and no client retry is still in backoff
        if t_arr == _INF and pending_net == 0 and pending_retry == 0 and \
                all(len(fc.queue) == 0 and fc.cluster.total_running == 0
                    for fc in clusters):
            break

        # ---- stale completion estimates landing strictly before every
        # other event source: drain without a loop iteration (see the
        # single-cluster loop for the full argument; chip-time advances
        # per event so accumulation stays bit-identical)
        if heap and not any(fc.queue._icount or fc.queue._bcount
                            for fc in clusters):
            while heap:
                ev = heap[0]
                th = ev[0]
                if th >= t_arr - eps or th >= next_control - eps \
                        or th >= next_place - eps \
                        or th >= next_timeline - eps or th > max_time \
                        or ev[1] != _COMPLETION:
                    break
                inst = ev[3]
                if ev[4] == inst._epoch \
                        and inst.state == InstanceState.ACTIVE:
                    break                    # live estimate — a real event
                heappop(heap)
                n_events += 1
                for fc in clusters:
                    fc.cluster.advance_time(th)

        # ---- next event time across all sources
        t_next = t_arr
        if heap and heap[0][0] < t_next:
            t_next = heap[0][0]
        if next_control < t_next:
            t_next = next_control
        if not control_parked:
            if next_place < t_next:
                t_next = next_place
            if next_timeline < t_next:
                t_next = next_timeline
        if t_next > max_time or t_next == _INF:
            for fc in clusters:
                fc.cluster.advance_time(max_time)
            t = max_time
            break
        t = t_next
        batch_seq += 1
        for fc in clusters:
            fc.cluster.advance_time(t)
            fc.cluster.batch_seq = batch_seq
        changed = False
        freed: Dict[int, List] = {}      # id(fc) -> instances w/ capacity

        if timing:
            _t0 = timers.clock()

        # 1. arrivals due at t, popped as one cohort (one searchsorted +
        #    one materialize slice): forecast observation, then route —
        #    local arrivals enqueue now, cross-region after the hop
        if t_arr <= t + eps:
            cohort, t_arr = cursor.pop_until(t + eps)
            n_events += len(cohort)
            changed = True
            for req in cohort:
                fleet.observe_arrival(req, t)
                _dispatch(req, t)

        if timing:
            _t0 = timers.lap("arrivals", _t0)

        # 2. heap events due at t
        while heap and heap[0][0] <= t + eps:
            _, kind, _, payload, epoch = heappop(heap)
            n_events += 1
            if kind == _NET:
                req, fc = payload
                pending_net -= 1
                _enqueue(fc, req, t)
                changed = True
            elif kind == _WARM:
                fleet.on_warm(payload, t)
                changed = True
            elif kind == _READY:
                inst = payload
                if inst.state == InstanceState.LOADING:
                    # scheduled at ready_time exactly; t may sit an epsilon
                    # below it (see simulate_events) — never lose the event
                    inst.activate_if_ready(max(t, inst.ready_time))
                    inst.mark_dirty()
                    freed.setdefault(id(by_sim[id(inst._cluster)]),
                                     []).append(inst)
                    changed = True
            elif kind == _FAIL:
                # one draw per victim slot, eligible or not (see the
                # single-cluster loop) — seeded victim sequences never
                # shift when the fleet happens to be empty
                for _ in range(max(1, failures.victims)):
                    draw = int(fail_rng.integers(1 << 30))
                    active = _all_active()
                    if not active:
                        skipped_injections += 1
                        continue
                    victim = active[draw % len(active)]
                    fc = by_sim[id(victim._cluster)]
                    flist = freed.get(id(fc))
                    if flist and victim in flist:
                        flist.remove(victim)
                    displaced = fc.cluster.fail_instance(victim)
                    for r in victim.drain_finished():
                        fc.controller.observe_completion(r)
                        fleet.observe_completion(r, fc, t)
                    for r in displaced:
                        fc.queue.requeue(r)
                    fc.cluster.dirty.discard(victim)
                    changed = True
            elif kind == _DEGRADE:
                draw = int(deg_rng.integers(1 << 30))
                cands = [i for i in _all_active() if i.slow_factor == 1.0]
                if cands:
                    victim = cands[draw % len(cands)]
                    victim._cluster.degrade_instance(
                        victim, degradations.factor, t)
                    heappush(heap, (t + degradations.duration,
                                    _RECOVER, next(ev_seq), victim, 0))
                    changed = True
                else:
                    skipped_injections += 1
            elif kind == _RECOVER:
                inst = payload
                if inst.state != InstanceState.RETIRED \
                        and inst.slow_factor != 1.0:
                    inst._cluster.recover_instance(inst, t)
                    changed = True
            elif kind == _OUTAGE:
                # correlated zone outage against one named fleet cluster
                plan = payload
                fc = fleet.by_name[plan.cluster] \
                    if plan.cluster is not None else clusters[0]
                victims = sorted(fc.cluster.instances, key=lambda i: i.id)
                if plan.fraction < 1.0 and victims:
                    k = min(len(victims), max(1, math.ceil(
                        plan.fraction * len(victims))))
                    sel = np.random.default_rng(plan.seed).permutation(
                        len(victims))[:k]
                    sel.sort()
                    victims = [victims[int(i)] for i in sel]
                if not victims:
                    skipped_injections += 1
                withhold = int(round(min(plan.fraction, 1.0)
                                     * fc.cluster.max_chips))
                if rec is not None:
                    rec.record_outage(fc.cluster, t, len(victims),
                                      withhold)
                    rec.inj_reason = _R_OUTAGE
                flist = freed.get(id(fc))
                for victim in victims:
                    if flist and victim in flist:
                        flist.remove(victim)
                    displaced = fc.cluster.fail_instance(victim)
                    for r in victim.drain_finished():
                        fc.controller.observe_completion(r)
                        fleet.observe_completion(r, fc, t)
                    for r in displaced:
                        fc.queue.requeue(r)
                    fc.cluster.dirty.discard(victim)
                if rec is not None:
                    rec.inj_reason = _R_INJECTED
                stages = max(1, int(plan.recovery_stages))
                base_amt, rem = divmod(withhold, stages)
                for k2 in range(stages):
                    amt = base_amt + (1 if k2 < rem else 0)
                    heappush(heap, (plan.start + plan.duration
                                    + k2 * plan.stage_interval,
                                    _RESTORE, next(ev_seq), (fc, amt), 0))
                fc.cluster.max_chips -= withhold
                fc.cluster.route_version += 1
                changed = True
            elif kind == _RESTORE:
                fc, amt = payload
                fc.cluster.max_chips += amt
                fc.cluster.route_version += 1
                if rec is not None:
                    rec.record_restore(fc.cluster, t, amt)
                changed = True
            elif kind == _BURST:
                if rec is not None:
                    rec.record_flash_crowd(clusters[0].cluster, t,
                                           payload.model)
                changed = True
            elif kind == _RETRY:
                # client retry re-arrival: fresh per-attempt deadline,
                # observed as demand, re-routed through the Router (an
                # open breaker deflects it to a healthy cluster at the
                # price of the network hop)
                req = payload
                pending_retry -= 1
                fleet.observe_arrival(req, t)
                req.deadline_at = t + req.slo.ttft
                led.mark_queued(req)
                _dispatch(req, t)
                changed = True
            else:                        # completion estimate
                inst = payload
                if epoch == inst._epoch \
                        and inst.state == InstanceState.ACTIVE:
                    inst.advance(t)
                    freed.setdefault(id(by_sim[id(inst._cluster)]),
                                     []).append(inst)
                    changed = True

        if timing:
            _t0 = timers.lap("heap_drain", _t0)

        # a parked control loop resumes as soon as anything happens
        if control_parked and changed:
            next_control = t
            control_parked = False

        # 3. control tick: every cluster runs its own Chiron hierarchy on
        #    its own queue against its own chip budget
        ran_control = t >= next_control - eps
        if ran_control:
            n_events += 1
            pre = post = 0
            for fc in clusters:
                fc.cluster.catch_up(t, batch_seq)
                if shadow is not None:
                    shadow.verify_cluster(fc.cluster)
                    shadow.verify_queue(fc.queue)
                if ov is not None:
                    _overload_tick_fc(fc, t)
                pre += len(fc.cluster.instances) + fc.cluster.scale_ups \
                    + fc.cluster.scale_downs
                fc.controller.control(fc.cluster, fc.queue, t)
                for inst in fc.cluster.drain_new_loading():
                    heappush(heap, (inst.ready_time, _READY,
                                    next(ev_seq), inst, 0))
                post += len(fc.cluster.instances) + fc.cluster.scale_ups \
                    + fc.cluster.scale_downs
                if rec is not None:
                    rec.record_cluster_tick(t, fc.cluster, fc.queue)
            quiescent = (pre == post and pending_net == 0
                         and all(len(fc.queue) == 0
                                 and fc.cluster.total_running == 0
                                 and fc.cluster.n_loading == 0
                                 for fc in clusters))
            if quiescent:
                # nothing can change before the next arrival (warm-up
                # events still fire off the heap); park the control and
                # placer clocks
                next_control = t_arr
                control_parked = True
            else:
                next_control = t + control_interval

        if timing:
            _t0 = timers.lap("control", _t0)

        # 4. placement review (tier 3): forecast-driven residency changes,
        #    batch-target selection, saturation hand-back
        if not control_parked and t >= next_place - eps:
            n_events += 1
            for req, fc, delay in fleet.review(t, emit_warm):
                if delay > eps:
                    heappush(heap, (t + delay, _NET, next(ev_seq),
                                    (req, fc), 0))
                    pending_net += 1
                else:
                    _enqueue(fc, req, t)
                changed = True
            next_place = t + fleet.placer.interval

        # 5. routing per cluster (full pass at control ticks, incremental
        #    zero-queuing + freed-instance backfill in between)
        for fc in clusters:
            if ran_control:
                fc.controller.route(fc.cluster, fc.queue, t)
            else:
                fc.controller.route_interactive(fc.cluster, fc.queue, t,
                                                use_memo)
                flist = freed.get(id(fc))
                if flist and fc.queue.n_batch:
                    if len(flist) > 1:
                        flist.sort(key=lambda i:
                                   i.itype != InstanceType.BATCH)
                    fc.controller.backfill(flist, fc.queue, t)

        if timing:
            _t0 = timers.lap("routing", _t0)

        # 6. sweep dirty instances: completions surface to the owning
        #    cluster's controller and the fleet rollup, then each
        #    cluster's vectorized ``sweep_etas`` pass bulk-refills the
        #    shared heap (epochs still advance per instance)
        for fc in clusters:
            if not fc.cluster.dirty:
                continue
            dirty = fc.cluster.drain_dirty()
            for inst in dirty:
                pf = inst._pending_finished
                if pf:
                    inst._pending_finished = []
                    for r in pf:
                        fc.controller.observe_completion(r)
                        fleet.observe_completion(r, fc, t)
            refill = fc.cluster.sweep_etas(dirty, batch_seq)
            if refill:
                if 8 * len(refill) < len(heap):
                    for inst, eta in refill:
                        inst._epoch += 1
                        heappush(heap, (t + eta, _COMPLETION,
                                        next(ev_seq), inst,
                                        inst._epoch))
                else:
                    for inst, eta in refill:
                        inst._epoch += 1
                        heap.append((t + eta, _COMPLETION,
                                     next(ev_seq), inst, inst._epoch))
                    heapify(heap)
            if shadow is not None:
                shadow.verify_cluster(fc.cluster)
        if shadow is not None and ran_control:
            shadow.maybe_verify_ledger(cursor.ledger, cursor.all, t)

        if timing:
            _t0 = timers.lap("sweep", _t0)

        # 7. timeline sample (suppressed while parked — state is frozen)
        if not control_parked and t >= next_timeline - eps:
            _sample(t)

        if timing:
            timers.lap("sampling", _t0)

    if timeline and t > timeline[-1].t:
        _sample(t)
    if shadow is not None:
        for fc in clusters:
            shadow.verify_cluster(fc.cluster)
            shadow.verify_queue(fc.queue)
        shadow.verify_ledger(cursor.ledger, cursor.all)
    if rec is not None:
        fleet.obs = None
        for fc in clusters:
            fc.cluster.obs = None
            fc.controller.obs = None
    stats = fleet.finalize()
    return RunResult(
        requests=cursor.all_requests(), timeline=timeline,
        chip_seconds=sum(fc.cluster.chip_seconds for fc in clusters),
        peak_chips=sum(fc.cluster.peak_chips for fc in clusters),
        scale_ups=sum(fc.cluster.scale_ups for fc in clusters),
        scale_downs=sum(fc.cluster.scale_downs for fc in clusters),
        duration=t,
        failures=sum(fc.cluster.failures for fc in clusters),
        degradations=sum(fc.cluster.degradations for fc in clusters),
        skipped_injections=skipped_injections, shocks=shocks,
        n_events=n_events, clusters=stats,
        migrations=fleet.migrations, handbacks=fleet.handbacks,
        egress_bytes=fleet.egress_bytes,
        egress_cost_usd=fleet.egress_cost_usd,
        ledger=cursor.ledger, telemetry=rec)


def default_perf_factory(**perf_kw) -> Callable[[str], PerfModel]:
    cache = {}

    def factory(model: str) -> PerfModel:
        if model not in cache:
            cache[model] = PerfModel(model, **perf_kw)
        return cache[model]
    return factory
