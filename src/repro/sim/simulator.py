"""Discrete-time cluster simulation loop.

Drives arrivals -> global queue -> controller routing -> instance fluid
steps -> completions, at a fixed tick (default 0.25 s), with the controller
invoked every ``control_interval``. The identical ``repro.core`` autoscaler
code used by the real engine runs here — only the data plane is simulated
(DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

from repro.serving.global_queue import GlobalQueue
from repro.serving.request import Request, RequestState
from repro.sim.cluster import InstanceType, SimCluster
from repro.sim.controllers import BaseController
from repro.sim.metrics import RunResult, TimelinePoint
from repro.sim.perf_model import PerfModel


def simulate(requests: List[Request], controller: BaseController,
             cluster: SimCluster, *, dt: float = 0.25,
             control_interval: float = 1.0, max_time: float = 7200.0,
             warm_start: int = 0, timeline_every: float = 1.0) -> RunResult:
    queue = GlobalQueue()
    pending = sorted(requests, key=lambda r: r.arrival_time)
    pi = 0
    t = 0.0
    next_control = 0.0
    next_timeline = 0.0
    timeline: List[TimelinePoint] = []

    # optional warm start: instances pre-provisioned and instantly active
    for _ in range(warm_start):
        inst = controller._provision(cluster, InstanceType.MIXED, t) \
            if hasattr(controller, "_provision") else \
            cluster.provision(controller.model, InstanceType.MIXED, t,
                              static_batch=getattr(controller, "static_batch", 64))
        if inst is not None:
            inst.ready_time = t

    while t < max_time:
        # 1. arrivals
        while pi < len(pending) and pending[pi].arrival_time <= t:
            queue.push(pending[pi])
            if hasattr(controller, "observe_arrival"):
                controller.observe_arrival(pending[pi], t)
            pi += 1

        # 2. instance state transitions
        for inst in cluster.instances:
            inst.activate_if_ready(t)

        # 3. control (scaling) then routing
        if t >= next_control:
            controller.control(cluster, queue, t)
            next_control = t + control_interval
        controller.route(cluster, queue, t)

        # 4. data-plane step
        tok_this_tick = 0
        for inst in cluster.active_instances():
            finished, toks = inst.step(dt, t)
            tok_this_tick += toks
            for r in finished:
                controller.observe_completion(r)

        cluster.tick_accounting(dt)

        # 5. timeline sample
        if t >= next_timeline:
            timeline.append(TimelinePoint(
                t,
                len(cluster.by_type(InstanceType.INTERACTIVE)),
                len(cluster.by_type(InstanceType.MIXED)),
                len(cluster.by_type(InstanceType.BATCH)),
                cluster.used_chips(),
                queue.n_interactive, queue.n_batch,
                tok_this_tick / dt))
            next_timeline = t + timeline_every

        t += dt

        # 6. termination: all requests arrived and none outstanding
        if pi >= len(pending) and len(queue) == 0 and \
                all(not i.running for i in cluster.instances):
            break

    return RunResult(requests=requests, timeline=timeline,
                     chip_seconds=cluster.chip_seconds,
                     peak_chips=cluster.peak_chips,
                     scale_ups=cluster.scale_ups,
                     scale_downs=cluster.scale_downs,
                     duration=t)


def default_perf_factory(**perf_kw) -> Callable[[str], PerfModel]:
    cache = {}

    def factory(model: str) -> PerfModel:
        if model not in cache:
            cache[model] = PerfModel(model, **perf_kw)
        return cache[model]
    return factory
