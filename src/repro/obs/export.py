"""Flight-recorder exporters: JSONL (the ``python -m repro.obs`` CLI
input), Chrome-trace/Perfetto JSON, and Prometheus text exposition.

All three operate on a :class:`~repro.sim.metrics.RunResult` that
carries a telemetry recorder (``telemetry=True`` on the engine call);
request-lifecycle anchors (queued/first-token/finish) are joined from
the run's request ledger against the recorder's sampled span rows, so
the simulation hot path never writes them twice.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from repro.obs.recorder import (KIND_NAMES, REASON_NAMES, SPAN_NAMES,
                                FlightRecorder)


def _require(result) -> FlightRecorder:
    rec = getattr(result, "telemetry", None)
    if rec is None:
        raise ValueError("run carries no telemetry — pass telemetry=True "
                         "(or CHIRON_TELEMETRY=1) to the engine")
    return rec


def _meta(result, rec: FlightRecorder) -> Dict:
    import time
    return {
        "kind": "meta",
        "clusters": list(rec.cluster_names),
        "models": list(rec.model_names),
        "itypes": list(rec.itype_names),
        "duration": result.duration,
        "scale_ups": result.scale_ups,
        "scale_downs": result.scale_downs,
        "failures": result.failures,
        "degradations": result.degradations,
        "span_sample": rec.span_sample,
        "span_seed": rec.span_seed,
        # overload-plane currency: goodput + terminal outcome rates ride
        # in the header so dashboards need no second pass over the rows
        "goodput": result.goodput(),
        **result.outcome_rates(),
        # repro-lint: ok(DET202, export stamp only - never read back into simulation state)
        "generated_unix": time.time(),
    }


def _name(vocab: List[str], code: int) -> Optional[str]:
    return vocab[code] if 0 <= code < len(vocab) else None


def sampled_requests(result, rec: FlightRecorder) -> List[Dict]:
    """One lifecycle record per sampled request row: ledger anchors
    (arrival, first token, finish) plus the recorded admit/preempt
    transitions in time order."""
    led = result.ledger
    spans = rec.spans
    if led is None or not spans.n:
        return []
    rows = np.unique(spans.col("row"))
    t_col = spans.col("t")
    r_col = spans.col("row")
    e_col = spans.col("event")
    i_col = spans.col("instance")
    out = []
    for row in rows:
        if row < 0 or row >= led.n:
            continue
        sel = np.flatnonzero(r_col == row)
        ftt = float(led.first_token_time[row])
        fin = float(led.finish_time[row])
        out.append({
            "kind": "request",
            "row": int(row),
            "model": _name(list(led.models), int(led.model_idx[row])),
            "interactive": bool(led.interactive[row]),
            "arrival": float(led.arrival[row]),
            "first_token": None if np.isnan(ftt) else ftt,
            "finish": None if np.isnan(fin) else fin,
            "state": int(led.state[row]),
            "transitions": [
                {"t": float(t_col[j]), "event": SPAN_NAMES[int(e_col[j])],
                 "instance": int(i_col[j])} for j in sel],
        })
    return out


def to_jsonl(result, path) -> int:
    """Write the full telemetry of a run as JSON lines (one meta header,
    then timeline/signal/cluster/decision/request rows). Returns the
    number of lines written."""
    rec = _require(result)
    n = 0
    with open(path, "w") as fh:
        def emit(obj):
            nonlocal n
            fh.write(json.dumps(obj) + "\n")
            n += 1

        emit(_meta(result, rec))
        tl = result.timeline
        if hasattr(tl, "col"):
            models = tl.queue_models()
            for i in range(len(tl)):
                row = {"kind": "timeline"}
                for name in ("t", "n_interactive", "n_mixed", "n_batch",
                             "chips", "q_interactive", "q_batch",
                             "tokens_per_s"):
                    row[name] = tl.col(name)[i].item()
                row["q_by_model"] = {
                    m: [int(tl.q_interactive_for(m)[i]),
                        int(tl.q_batch_for(m)[i])] for m in models}
                emit(row)
        for row in rec.signals.rows():
            row["kind"] = "signal"
            row["cluster"] = _name(rec.cluster_names, row["cluster"])
            row["model"] = _name(rec.model_names, row["model"])
            emit(row)
        for row in rec.cticks.rows():
            row["kind"] = "cluster"
            row["cluster"] = _name(rec.cluster_names, row["cluster"])
            emit(row)
        for row in rec.decisions.rows():
            row["action"] = KIND_NAMES[row.pop("kind")]
            row["kind"] = "decision"
            row["reason"] = REASON_NAMES[row["reason"]]
            row["cluster"] = _name(rec.cluster_names, row["cluster"])
            row["model"] = _name(rec.model_names, row["model"])
            row["itype"] = _name(rec.itype_names, row["itype"])
            row["peer"] = _name(rec.cluster_names, row["peer"])
            emit(row)
        for row in sampled_requests(result, rec):
            emit(row)
    return n


def to_perfetto(result, path=None) -> Dict:
    """Chrome-trace/Perfetto JSON: counter tracks for queue depth and
    chips (``ph: "C"``) plus complete-event spans (``ph: "X"``) for every
    sampled request — queued, then prefill/decode split at the first
    token when known, with preempt gaps honoured. Times are microseconds
    of simulated time. Writes to ``path`` when given; returns the
    document either way."""
    rec = _require(result)
    us = 1e6
    events = [{"name": "process_name", "ph": "M", "pid": 0,
               "args": {"name": "control-plane"}}]
    tl = result.timeline
    if hasattr(tl, "col"):
        ts = tl.col("t")
        qi = tl.col("q_interactive")
        qb = tl.col("q_batch")
        chips = tl.col("chips")
        for i in range(len(tl)):
            t = float(ts[i]) * us
            events.append({"name": "queue_depth", "ph": "C", "pid": 0,
                           "ts": t, "args": {"interactive": int(qi[i]),
                                             "batch": int(qb[i])}})
            events.append({"name": "chips", "ph": "C", "pid": 0,
                           "ts": t, "args": {"used": int(chips[i])}})
    for req in sampled_requests(result, rec):
        pid = 1
        tid = req["row"]
        end = req["finish"]
        if end is None:
            end = result.duration
        trans = req["transitions"]
        admits = [tr for tr in trans if tr["event"] == "admit"]
        first_admit = admits[0]["t"] if admits else end
        events.append({"name": "queued", "ph": "X", "pid": pid,
                       "tid": tid, "ts": req["arrival"] * us,
                       "dur": max(first_admit - req["arrival"], 0.0) * us,
                       "args": {"model": req["model"]}})
        for k, tr in enumerate(admits):
            nxt = end
            for tr2 in trans:
                if tr2["event"] == "preempt" and tr2["t"] >= tr["t"]:
                    nxt = min(nxt, tr2["t"])
                    break
            ftt = req["first_token"]
            if ftt is not None and tr["t"] <= ftt <= nxt:
                events.append({"name": "prefill", "ph": "X", "pid": pid,
                               "tid": tid, "ts": tr["t"] * us,
                               "dur": max(ftt - tr["t"], 0.0) * us,
                               "args": {"instance": tr["instance"]}})
                events.append({"name": "decode", "ph": "X", "pid": pid,
                               "tid": tid, "ts": ftt * us,
                               "dur": max(nxt - ftt, 0.0) * us,
                               "args": {"instance": tr["instance"]}})
            else:
                events.append({"name": "exec", "ph": "X", "pid": pid,
                               "tid": tid, "ts": tr["t"] * us,
                               "dur": max(nxt - tr["t"], 0.0) * us,
                               "args": {"instance": tr["instance"]}})
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as fh:
            json.dump(doc, fh)
    return doc


def to_prometheus(result, path=None) -> str:
    """Prometheus text exposition of the run's terminal state: scale
    action counters by kind, final queue depths/chips per cluster, SLO
    attainment gauges. Writes to ``path`` when given; returns the text
    either way."""
    rec = _require(result)
    lines = []

    def metric(name, mtype, help_text, samples):
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {mtype}")
        for labels, value in samples:
            lab = "{" + ",".join(f'{k}="{v}"'
                                 for k, v in labels.items()) + "}" \
                if labels else ""
            lines.append(f"{name}{lab} {value}")

    rep = rec.replay()
    metric("chiron_scale_actions_total", "counter",
           "Control-plane actions by kind over the run",
           [({"action": k}, v) for k, v in rep.items()])
    metric("chiron_slo_attainment", "gauge",
           "Fraction of requests meeting their SLO",
           [({}, result.slo_attainment())])
    metric("chiron_completion_rate", "gauge",
           "Fraction of requests finished",
           [({}, result.completion_rate())])
    metric("chiron_goodput", "gauge",
           "SLO-met completions per second of simulated time",
           [({}, result.goodput())])
    rates = result.outcome_rates()
    metric("chiron_overload_outcome_rate", "gauge",
           "Fraction of submitted requests per overload terminal state",
           [({"outcome": "rejected"}, rates["reject_rate"]),
            ({"outcome": "shed"}, rates["shed_rate"]),
            ({"outcome": "expired"}, rates["expired_rate"])])
    metric("chiron_chip_seconds_total", "counter",
           "Chip-seconds consumed over the run",
           [({}, result.chip_seconds)])
    metric("chiron_peak_chips", "gauge", "Peak chips in use",
           [({}, result.peak_chips)])
    ct = rec.cticks
    if ct.n:
        t_col = ct.col("t")
        c_col = ct.col("cluster")
        final = []
        chips_f = []
        for code, name in enumerate(rec.cluster_names):
            sel = np.flatnonzero(c_col == code)
            if not sel.size:
                continue
            i = int(sel[np.argmax(t_col[sel])])
            final.append(({"cluster": name, "class": "interactive"},
                          int(ct.col("q_interactive")[i])))
            final.append(({"cluster": name, "class": "batch"},
                          int(ct.col("q_batch")[i])))
            chips_f.append(({"cluster": name}, int(ct.col("chips")[i])))
        metric("chiron_queue_depth", "gauge",
               "Queue depth at the final control tick", final)
        metric("chiron_chips_in_use", "gauge",
               "Chips in use at the final control tick", chips_f)
    text = "\n".join(lines) + "\n"
    if path is not None:
        with open(path, "w") as fh:
            fh.write(text)
    return text
