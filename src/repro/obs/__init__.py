"""Observability plane: columnar flight recorder, decision ledger,
request-lifecycle tracing and exporters (see ``repro.obs.recorder``).

Engines gate on :func:`resolve` (``telemetry=`` argument or the
``CHIRON_TELEMETRY`` environment variable); exports live in
``repro.obs.export`` and the terminal dashboard CLI runs as
``python -m repro.obs <run.jsonl>``.
"""
from repro.obs.recorder import FlightRecorder, resolve

__all__ = ["FlightRecorder", "resolve"]
