"""Flight recorder: struct-of-arrays telemetry plane for the simulators.

Three coordinated layers, all preallocated amortized-doubling columns
(the :class:`~repro.sim.ledger.RequestLedger` growth idiom):

* **Control-plane time series** — one row per control tick per cluster
  (chips, per-type instance counts, loading/active registries, queue
  depths, KV aggregates, chip utilization) plus one row per (tick,
  cluster, model) with the Chiron signals exactly as the controller
  computed them: IBP, Theta, BBP, the QLM waiting-time estimate, and the
  per-model queue depths the decision read.

* **Decision ledger** — every scale-up/down, crash, degradation,
  recovery, batch eviction, model migration, saturation hand-back and
  residency drain, recorded with its inputs: which Algorithm 1/2 term
  fired (``reason``), the backpressure value and the threshold it
  crossed, chips before/after, model, cluster, instance type. The
  sequence is replayable — :meth:`FlightRecorder.replay` reconstructs
  ``RunResult`` scale counts exactly and
  :meth:`FlightRecorder.replay_instance_counts` rebuilds the per-type
  instance timeline the PR 4 decision-equivalence tests pin.

* **Request-lifecycle spans** — sampled admit/preempt transitions with
  timestamps and instance ids. Sampling is a deterministic integer hash
  of the request row (no RNG, so runs are reproducible and the
  determinism auditor stays quiet); queued/prefill/decode/finish
  boundaries are joined from the request ledger at export time, so the
  hot path pays exactly two optional appends per request.

Gating mirrors ``repro.analysis.shadow``: engines call :func:`resolve`
on their ``telemetry`` argument — a :class:`FlightRecorder` passes
through, ``True`` builds one, ``None`` consults ``CHIRON_TELEMETRY``.
When off every hook site costs one predicted ``obs is not None`` branch
and results are bit-identical to a build without the recorder.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

_NAN = float("nan")
_INF = float("inf")

# ---------------------------------------------------------------- codes
# int8 decision kinds (stable: rows round-trip through JSONL exports;
# new kinds append at the end so existing codes never shift)
(PROVISION, RETIRE, FAIL, DEGRADE, RECOVER, EVICT, MIGRATE, HANDBACK,
 DRAIN, OUTAGE, RESTORE, FLASH, REJECT, SHED, EXPIRE, BREAKER,
 BROWNOUT) = range(17)
KIND_NAMES = ("provision", "retire", "fail", "degrade", "recover",
              "evict", "migrate", "handback", "drain", "outage",
              "restore", "flash", "reject", "shed", "expire", "breaker",
              "brownout")

# int8 decision reasons: which control-law term fired. BOOTSTRAP covers
# warm starts and the controller's keep-a-foothold provisions (step 0);
# IBP_* are Algorithm 1's band exits, BBP_* Algorithm 2's branches;
# PREEMPT is interactive-over-batch eviction; INJECTED marks plan-driven
# failures/degradations; PLACEMENT marks fleet-tier residency moves;
# OUTAGE marks correlated zone-outage crashes and their staged restores;
# FLASH marks a flash-crowd onset. The overload plane's terms:
# INFEASIBLE (admission estimated the TTFT unreachable), DEADLINE (the
# queued request's deadline passed), RETRY_EXHAUSTED (client gave up),
# BREAKER (circuit-breaker transition), OVERLOAD (brownout hysteresis).
(R_BOOTSTRAP, R_IBP_HIGH, R_IBP_LOW, R_BBP_ADD, R_BBP_IDLE, R_BBP_TRIM,
 R_PREEMPT, R_INJECTED, R_PLACEMENT, R_OUTAGE, R_FLASH, R_INFEASIBLE,
 R_DEADLINE, R_RETRY_EXHAUSTED, R_BREAKER, R_OVERLOAD) = range(16)
REASON_NAMES = ("bootstrap", "ibp_high", "ibp_low", "bbp_add",
                "bbp_idle", "bbp_trim", "preempt", "injected",
                "placement", "outage", "flash", "infeasible", "deadline",
                "retry_exhausted", "breaker", "overload")

# int8 span events
SPAN_ADMIT, SPAN_PREEMPT = 0, 1
SPAN_NAMES = ("admit", "preempt")


class _Columns:
    """Amortized-doubling struct-of-arrays row store. Subclasses declare
    ``_COLUMNS`` as ``(name, dtype, fill)`` triples; ``append`` takes the
    values in declaration order.

    Writes are combined: ``append`` stages the row as a plain tuple and
    any read (``col``/``rows``) flushes the staging list into the numpy
    backing with one bulk slice assignment per column. Per-row hot-path
    cost is one tuple build + one list append; backing arrays at least
    double on overflow so N rows cost O(N) total copying."""

    __slots__ = ("_n", "_backing", "_cap", "_stage")
    _COLUMNS: tuple = ()

    def __init__(self):
        self._n = 0
        self._cap = 0
        self._backing: Dict[str, np.ndarray] = {}
        self._stage: list = []

    @property
    def n(self) -> int:
        return self._n + len(self._stage)

    def _reserve(self, extra: int) -> None:
        need = self._n + extra
        cap = self._cap
        if cap == 0:
            cap = max(need, 256)
            for name, dtype, fill in self._COLUMNS:
                self._backing[name] = np.full(cap, fill, dtype=dtype)
        elif need > cap:
            while cap < need:
                cap *= 2
            for name, dtype, fill in self._COLUMNS:
                back = np.full(cap, fill, dtype=dtype)
                back[:self._n] = self._backing[name][:self._n]
                self._backing[name] = back
        else:
            return
        self._cap = cap

    def append(self, *values) -> None:
        self._stage.append(values)

    def _flush(self) -> None:
        st = self._stage
        if not st:
            return
        k = len(st)
        self._reserve(k)
        i = self._n
        b = self._backing
        for j, (name, _, _) in enumerate(self._COLUMNS):
            b[name][i:i + k] = [row[j] for row in st]
        self._n = i + k
        st.clear()

    def col(self, name: str) -> np.ndarray:
        """Exact-length view of one column (flushes staged writes)."""
        self._flush()
        if self._cap == 0:
            for cname, dtype, _ in self._COLUMNS:
                if cname == name:
                    return np.empty(0, dtype=dtype)
            raise KeyError(name)
        return self._backing[name][:self._n]

    def column_names(self) -> List[str]:
        return [name for name, _, _ in self._COLUMNS]

    def rows(self):
        """Row dicts with plain Python scalars (export/CLI path — not for
        the hot loop)."""
        names = self.column_names()
        cols = [self.col(name) for name in names]
        for i in range(self.n):
            yield {name: col[i].item() for name, col in zip(names, cols)}


class SignalColumns(_Columns):
    """One row per (control tick, cluster, model): the Chiron inputs as
    the controller computed them. Instance counts are post-decision (the
    state the tick left behind); queue depths are what the decision
    read."""
    _COLUMNS = (
        ("t", np.float64, 0.0), ("cluster", np.int32, 0),
        ("model", np.int32, 0),
        ("q_interactive", np.int32, 0), ("q_batch", np.int32, 0),
        ("ibp", np.float64, _NAN), ("theta", np.float64, _NAN),
        ("bbp", np.int32, 0), ("wait_est", np.float64, _NAN),
        ("n_interactive", np.int32, 0), ("n_mixed", np.int32, 0),
        ("n_batch", np.int32, 0),
    )


class ClusterTickColumns(_Columns):
    """One row per (control tick, cluster): post-decision cluster-wide
    aggregates."""
    _COLUMNS = (
        ("t", np.float64, 0.0), ("cluster", np.int32, 0),
        ("chips", np.int32, 0),
        ("n_interactive", np.int32, 0), ("n_mixed", np.int32, 0),
        ("n_batch", np.int32, 0), ("n_loading", np.int32, 0),
        ("n_active", np.int32, 0),
        ("q_interactive", np.int32, 0), ("q_batch", np.int32, 0),
        ("kv_tokens", np.float64, 0.0),
        ("kv_utilization", np.float64, 0.0),
        ("utilization", np.float64, 0.0),
    )


class DecisionColumns(_Columns):
    """One row per control-plane action. ``value``/``threshold`` carry
    the fired term's backpressure reading and band edge (NaN when the
    action has no scalar input — e.g. injected failures); ``peer`` is
    the destination cluster of a hand-back (-1 otherwise); ``count`` is
    the multiplicity of aggregate actions (hand-back moves, drained
    requests)."""
    _COLUMNS = (
        ("t", np.float64, 0.0), ("cluster", np.int32, 0),
        ("kind", np.int8, 0), ("reason", np.int8, 0),
        ("model", np.int32, -1), ("itype", np.int8, -1),
        ("value", np.float64, _NAN), ("threshold", np.float64, _NAN),
        ("chips_before", np.int32, 0), ("chips_after", np.int32, 0),
        ("peer", np.int32, -1), ("count", np.int32, 1),
    )


class SpanColumns(_Columns):
    """Sampled request-lifecycle transitions (admit/preempt) by ledger
    row id; queued/first-token/finish anchors join from the request
    ledger at export time."""
    _COLUMNS = (
        ("t", np.float64, 0.0), ("row", np.int64, -1),
        ("event", np.int8, 0), ("instance", np.int32, -1),
    )


class FlightRecorder:
    """The run-scoped telemetry sink the engines attach to clusters,
    controllers and fleets (as their ``obs`` attribute) for the run's
    duration. All methods append O(1) rows; nothing here feeds back into
    simulation state.

    All column stores write-combine (see :class:`_Columns`), so the one
    per-request hot hook — ``record_span`` — costs an inlined sampling
    hash plus a single staged tuple append; the numpy columns
    materialize lazily on first read.

    ``span_sample`` defaults to head-based sampling at 25% — lifecycle
    spans are the only per-request (rather than per-tick) stream, and
    sampling them is what keeps full telemetry inside the <5% overhead
    budget the benchmark pins. Pass ``span_sample=1.0`` to trace every
    request (tests and small runs); the signal/tick/decision layers are
    always complete regardless."""

    __slots__ = ("signals", "cticks", "decisions", "spans", "_sp_stage",
                 "span_sample", "span_seed", "_span_limit", "_span_mix",
                 "cluster_names", "_cluster_codes",
                 "model_names", "_model_codes",
                 "itype_names", "_itype_codes",
                 "_ctx_reason", "_ctx_value", "_ctx_threshold",
                 "inj_reason")

    def __init__(self, *, span_sample: float = 0.25, span_seed: int = 0):
        self.signals = SignalColumns()
        self.cticks = ClusterTickColumns()
        self.decisions = DecisionColumns()
        self.spans = SpanColumns()
        # record_span bypasses the append() call; _flush clears this
        # list in place so the cached reference stays valid
        self._sp_stage = self.spans._stage
        self.span_sample = float(span_sample)
        self.span_seed = int(span_seed)
        # deterministic sampling: keep row iff a 32-bit multiplicative
        # hash of (row, seed) lands under sample_rate * 2^32 — no RNG,
        # so identical runs sample identical rows
        self._span_limit = int(min(max(self.span_sample, 0.0), 1.0)
                               * 2.0 ** 32)
        self._span_mix = (self.span_seed * 0x9E3779B9) & 0xFFFFFFFF
        self.cluster_names: List[str] = []
        self._cluster_codes: Dict[int, int] = {}
        self.model_names: List[str] = []
        self._model_codes: Dict[str, int] = {}
        self.itype_names: List[str] = []
        self._itype_codes: Dict[object, int] = {}
        self._ctx_reason = R_BOOTSTRAP
        self._ctx_value = _NAN
        self._ctx_threshold = _NAN
        # injection-reason context: FAIL rows default to plan-driven
        # crashes; the engines set R_OUTAGE around a correlated zone
        # outage so each victim's row carries the term that fired
        self.inj_reason = R_INJECTED

    # ------------------------------------------------------- vocabularies
    def register_cluster(self, cluster, name: str) -> int:
        """Bind a cluster object to a stable name/index (the engines call
        this at attach time; unknown clusters auto-register as ``c<i>``)."""
        code = self._cluster_codes.get(id(cluster))
        if code is None:
            code = self._cluster_codes[id(cluster)] = \
                len(self.cluster_names)
            self.cluster_names.append(name)
        return code

    def _cluster_code(self, cluster) -> int:
        code = self._cluster_codes.get(id(cluster))
        if code is None:
            code = self.register_cluster(
                cluster, f"c{len(self.cluster_names)}")
        return code

    def cluster_code_by_name(self, name: str) -> int:
        try:
            return self.cluster_names.index(name)
        except ValueError:
            self.cluster_names.append(name)
            return len(self.cluster_names) - 1

    def _model_code(self, model: Optional[str]) -> int:
        if model is None:
            return -1
        code = self._model_codes.get(model)
        if code is None:
            code = self._model_codes[model] = len(self.model_names)
            self.model_names.append(model)
        return code

    def _itype_code(self, itype) -> int:
        if itype is None:
            return -1
        code = self._itype_codes.get(itype)
        if code is None:
            code = self._itype_codes[itype] = len(self.itype_names)
            self.itype_names.append(
                getattr(itype, "name", str(itype)).lower())
        return code

    # ---------------------------------------------------- decision context
    # The controller sets which Algorithm 1/2 term is about to act (and
    # its backpressure/threshold reading) before a provision/retire loop;
    # the cluster-level hooks stamp the pending rows with it. Outside any
    # explicit context, actions are bootstrap/foothold provisions.
    def set_context(self, reason: int, value: float = _NAN,
                    threshold: float = _NAN) -> None:
        self._ctx_reason = reason
        self._ctx_value = value
        self._ctx_threshold = threshold

    def clear_context(self) -> None:
        self._ctx_reason = R_BOOTSTRAP
        self._ctx_value = _NAN
        self._ctx_threshold = _NAN

    # ------------------------------------------------------ decision hooks
    def record_provision(self, cluster, now: float, model: str, itype,
                         chips_before: int, chips_after: int) -> None:
        self.decisions.append(now, self._cluster_code(cluster), PROVISION,
                              self._ctx_reason, self._model_code(model),
                              self._itype_code(itype), self._ctx_value,
                              self._ctx_threshold, chips_before,
                              chips_after, -1, 1)

    def record_retire(self, cluster, now: float, inst,
                      chips_before: int, chips_after: int) -> None:
        self.decisions.append(now, self._cluster_code(cluster), RETIRE,
                              self._ctx_reason,
                              self._model_code(inst.model),
                              self._itype_code(inst.itype),
                              self._ctx_value, self._ctx_threshold,
                              chips_before, chips_after, -1, 1)

    def record_fail(self, cluster, now: float, inst,
                    chips_before: int, chips_after: int) -> None:
        self.decisions.append(now, self._cluster_code(cluster), FAIL,
                              self.inj_reason,
                              self._model_code(inst.model),
                              self._itype_code(inst.itype), _NAN, _NAN,
                              chips_before, chips_after, -1, 1)

    def record_outage(self, cluster, now: float, victims: int,
                      withheld_chips: int) -> None:
        """Correlated zone-outage onset: one row with the victim count
        (``count``) and the chip budget withheld (``value``); each
        victim's crash still lands as its own FAIL row (stamped
        ``R_OUTAGE`` via ``inj_reason``)."""
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), OUTAGE,
                              R_OUTAGE, -1, -1, float(withheld_chips),
                              _NAN, chips, chips, -1, victims)

    def record_restore(self, cluster, now: float, chips_back: int) -> None:
        """One staged tranche of withheld outage capacity returning."""
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), RESTORE,
                              R_OUTAGE, -1, -1, float(chips_back), _NAN,
                              chips, chips, -1, 1)

    def record_flash_crowd(self, cluster, now: float, model: str) -> None:
        """Flash-crowd onset marker (the shock arrivals ride the trace)."""
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), FLASH,
                              R_FLASH, self._model_code(model), -1, _NAN,
                              _NAN, chips, chips, -1, 1)

    def record_degrade(self, cluster, now: float, inst,
                       factor: float) -> None:
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), DEGRADE,
                              R_INJECTED, self._model_code(inst.model),
                              self._itype_code(inst.itype), factor, _NAN,
                              chips, chips, -1, 1)

    def record_recover(self, cluster, now: float, inst) -> None:
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), RECOVER,
                              R_INJECTED, self._model_code(inst.model),
                              self._itype_code(inst.itype), _NAN, _NAN,
                              chips, chips, -1, 1)

    def record_evict(self, cluster, now: float, req, inst) -> None:
        """Interactive-over-batch preemption: one decision row (the saved
        KV size as ``value``) plus a sampled preempt span."""
        chips = cluster.used_chips()
        saved = req.saved_kv[1] if req.saved_kv is not None else _NAN
        self.decisions.append(now, self._cluster_code(cluster), EVICT,
                              R_PREEMPT, self._model_code(req.model),
                              self._itype_code(inst.itype), saved, _NAN,
                              chips, chips, -1, 1)
        self.record_span(now, req.row, SPAN_PREEMPT, inst.id)

    def record_migration(self, now: float, cluster_name: str, model: str,
                         delay: float) -> None:
        self.decisions.append(now, self.cluster_code_by_name(cluster_name),
                              MIGRATE, R_PLACEMENT,
                              self._model_code(model), -1, delay, _NAN,
                              0, 0, -1, 1)

    def record_handback(self, now: float, src_name: str, dst_name: str,
                        model: str, moved: int) -> None:
        self.decisions.append(now, self.cluster_code_by_name(src_name),
                              HANDBACK, R_PLACEMENT,
                              self._model_code(model), -1, _NAN, _NAN,
                              0, 0, self.cluster_code_by_name(dst_name),
                              moved)

    def record_drain(self, now: float, cluster_name: str, model: str,
                     moved: int) -> None:
        self.decisions.append(now, self.cluster_code_by_name(cluster_name),
                              DRAIN, R_PLACEMENT, self._model_code(model),
                              -1, _NAN, _NAN, 0, 0, -1, moved)

    # ------------------------------------------------------ overload hooks
    def record_reject(self, cluster, now: float, model: str,
                      wait_est: float, budget: float,
                      reason: int = R_INFEASIBLE) -> None:
        """Admission refusal: the estimated wait (``value``) against the
        TTFT budget it blew (``threshold``); ``reason`` carries the term
        that fired (INFEASIBLE at admission, RETRY_EXHAUSTED when the
        client abandoned after its last attempt)."""
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), REJECT,
                              reason, self._model_code(model), -1,
                              wait_est, budget, chips, chips, -1, 1)

    def record_shed(self, cluster, now: float, model: str,
                    count: int) -> None:
        """Brownout shed sweep: ``count`` queued interactive requests of
        ``model`` dropped as infeasible."""
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), SHED,
                              R_OVERLOAD, self._model_code(model), -1,
                              _NAN, _NAN, chips, chips, -1, count)

    def record_expire(self, cluster, now: float, model: str,
                      count: int) -> None:
        """Deadline sweep: ``count`` queued interactive requests whose
        deadline passed before service."""
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), EXPIRE,
                              R_DEADLINE, self._model_code(model), -1,
                              _NAN, _NAN, chips, chips, -1, count)

    def record_breaker(self, now: float, cluster_name: str,
                       state_code: int, ewma: float,
                       threshold: float) -> None:
        """Circuit-breaker transition: the new state lands in ``itype``
        (0 closed / 1 half-open / 2 open — breaker rows carry no
        instance type) with the rejection EWMA and trip threshold."""
        self.decisions.append(now, self.cluster_code_by_name(cluster_name),
                              BREAKER, R_BREAKER, -1, state_code, ewma,
                              threshold, 0, 0, -1, 1)

    def record_brownout(self, cluster, now: float, entered: bool,
                        depth: int, threshold: float) -> None:
        """Brownout enter (``itype`` 1) / exit (``itype`` 0) with the
        interactive backlog that tripped the hysteresis."""
        chips = cluster.used_chips()
        self.decisions.append(now, self._cluster_code(cluster), BROWNOUT,
                              R_OVERLOAD, -1, 1 if entered else 0,
                              float(depth), threshold, chips, chips,
                              -1, 1)

    # ---------------------------------------------------------- tick hooks
    def record_signals(self, now: float, cluster, model: str,
                       ibp: float, theta: float, bbp: int,
                       wait_est: float, q_interactive: int, q_batch: int,
                       n_interactive: int, n_mixed: int,
                       n_batch: int) -> None:
        # staged directly (bypassing append()) — per (tick, cluster,
        # model) hot site; also closes the tick's decision context (the
        # signals row is the last thing a scale pass records)
        self.signals._stage.append(
            (now, self._cluster_code(cluster), self._model_code(model),
             q_interactive, q_batch, ibp, theta, bbp, wait_est,
             n_interactive, n_mixed, n_batch))
        self._ctx_reason = R_BOOTSTRAP
        self._ctx_value = _NAN
        self._ctx_threshold = _NAN

    def record_cluster_tick(self, now: float, cluster, queue) -> None:
        kv = 0.0
        kv_util = 0.0
        act = cluster._active
        n_act = len(act)
        inf = _INF
        # inlined SimInstance.kv_tokens / kv_utilization (per control
        # tick x per active instance — the recorder's second-hottest
        # site); instances inherit the cluster's mode at provision so
        # the branch hoists out of the loop
        if cluster.event_mode:
            for inst in act.values():
                k = inst._kv_prefill + inst._kv_dec_base \
                    + inst._n_dec * inst.vclock
                kv += k
                cap = inst._c_cap
                kv_util += k / cap if cap != inf \
                    else len(inst.running) / (inst.max_batch_size or 1)
        else:
            for inst in act.values():
                k = inst._kv_tokens
                kv += k
                cap = inst._c_cap
                kv_util += k / cap if cap != inf \
                    else len(inst.running) / (inst.max_batch_size or 1)
        n_i, n_m, n_b = cluster.counts_by_type()
        chips = cluster._used_chips
        self.cticks._stage.append(
            (now, self._cluster_code(cluster), chips,
             n_i, n_m, n_b, cluster.n_loading, n_act,
             queue.n_interactive, queue.n_batch, kv,
             kv_util / n_act if n_act else 0.0,
             chips / cluster.max_chips if cluster.max_chips else 0.0))

    # --------------------------------------------------------------- spans
    def sampled(self, row: int) -> bool:
        """Deterministic per-row sampling verdict (Knuth multiplicative
        hash over the 32-bit ring; seed shifts the subset)."""
        if row < 0:
            return False
        h = ((row + 1) * 2654435761 + self._span_mix) & 0xFFFFFFFF
        return h < self._span_limit

    def record_span(self, now: float, row: int, event: int,
                    inst_id: int) -> None:
        # the one per-request hot hook (once per admit/preempt): inlined
        # sampling hash, then one staged tuple append
        if row < 0 or ((row + 1) * 2654435761 + self._span_mix) \
                & 0xFFFFFFFF >= self._span_limit:
            return
        self._sp_stage.append((now, row, event, inst_id))

    def record_admit(self, now: float, row: int, inst_id: int) -> None:
        self.record_span(now, row, SPAN_ADMIT, inst_id)

    # -------------------------------------------------------------- replay
    def replay(self) -> Dict[str, int]:
        """Reconstruct the run's scale-action totals from the decision
        ledger alone. Matches ``RunResult`` exactly: every provision (warm
        start, bootstrap, IBP/BBP) and every retire/fail/degrade goes
        through the recorded cluster hooks."""
        kinds = self.decisions.col("kind")
        counts = np.bincount(kinds, minlength=len(KIND_NAMES))
        weights = self.decisions.col("count")
        return {
            "scale_ups": int(counts[PROVISION]),
            "scale_downs": int(counts[RETIRE]),
            "failures": int(counts[FAIL]),
            "degradations": int(counts[DEGRADE]),
            "evictions": int(counts[EVICT]),
            "migrations": int(counts[MIGRATE]),
            "handbacks": int(weights[kinds == HANDBACK].sum()),
            "drains": int(counts[DRAIN]),
            "outages": int(counts[OUTAGE]),
            "restores": int(counts[RESTORE]),
            "flash_crowds": int(counts[FLASH]),
            "rejections": int(counts[REJECT]),
            "sheds": int(weights[kinds == SHED].sum()),
            "expirations": int(weights[kinds == EXPIRE].sum()),
            "breaker_trips": int(np.count_nonzero(
                (kinds == BREAKER)
                & (self.decisions.col("itype") == 2))),
            "brownouts": int(np.count_nonzero(
                (kinds == BROWNOUT)
                & (self.decisions.col("itype") == 1))),
        }

    def replay_instance_counts(self, times) -> np.ndarray:
        """Rebuild the fleet-wide per-type instance timeline from the
        decision ledger: (len(times), 3) array of (interactive, mixed,
        batch) counts at each query time — provisions count immediately
        (``counts_by_type`` includes LOADING instances), retires and
        crashes subtract at their decision time. Equals the recorded
        ``RunResult.timeline`` columns when evaluated at the sample
        times."""
        times = np.asarray(times, dtype=np.float64)
        out = np.zeros((times.size, 3), dtype=np.int64)
        kinds = self.decisions.col("kind")
        t_dec = self.decisions.col("t")
        itypes = self.decisions.col("itype")
        class_of = {name: i for i, name in
                    enumerate(("interactive", "mixed", "batch"))}
        for code, name in enumerate(self.itype_names):
            cls = class_of.get(name)
            if cls is None:
                continue
            sel = itypes == code
            adds = t_dec[sel & (kinds == PROVISION)]
            subs = t_dec[sel & ((kinds == RETIRE) | (kinds == FAIL))]
            out[:, cls] = (np.searchsorted(adds, times, side="right")
                           - np.searchsorted(subs, times, side="right"))
        return out


def resolve(telemetry) -> Optional[FlightRecorder]:
    """Normalize the engines' ``telemetry`` argument: a recorder passes
    through, ``True`` builds one, ``None`` consults the
    ``CHIRON_TELEMETRY`` environment variable."""
    if isinstance(telemetry, FlightRecorder):
        return telemetry
    if telemetry is None:
        import os
        telemetry = os.environ.get("CHIRON_TELEMETRY", "") \
            not in ("", "0", "false", "no")
    return FlightRecorder() if telemetry else None
