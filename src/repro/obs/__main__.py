"""Terminal dashboards over a flight-recorder JSONL export.

Usage::

    python -m repro.obs run.jsonl                 # all models
    python -m repro.obs run.jsonl --model llama-8b
    python -m repro.obs run.jsonl --waterfalls 12 --width 100

Renders, per model, the control-plane time series (queue depth vs.
chips vs. IBP/BBP backpressure as unicode sparklines over the run), the
decision ledger (one line per scale action with the term that fired),
and per-request lifecycle waterfalls for the sampled spans
(``.`` queued, ``=`` prefill, ``#`` decode, ``x`` preempted).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

_BLOCKS = " ▁▂▃▄▅▆▇█"


def _spark(values: List[float], width: int) -> str:
    """Downsample ``values`` to ``width`` buckets (max-pooled) and render
    as a block-character sparkline."""
    if not values:
        return ""
    if len(values) > width:
        per = len(values) / width
        values = [max(values[int(i * per):max(int((i + 1) * per),
                                              int(i * per) + 1)])
                  for i in range(width)]
    top = max(values)
    if top <= 0:
        return _BLOCKS[0] * len(values)
    return "".join(_BLOCKS[min(int(v / top * (len(_BLOCKS) - 1) + 0.5),
                               len(_BLOCKS) - 1)] for v in values)


def _load(path: str) -> Dict[str, list]:
    groups: Dict[str, list] = {"meta": [], "timeline": [], "signal": [],
                               "cluster": [], "decision": [],
                               "request": []}
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            groups.setdefault(row.get("kind", "?"), []).append(row)
    return groups


def _series(rows: List[dict], key: str) -> List[float]:
    return [float(r[key]) for r in rows]


def _dashboard(groups: Dict[str, list], model: Optional[str],
               width: int, out) -> None:
    signals = groups["signal"]
    models = []
    for r in signals:
        if r["model"] not in models:
            models.append(r["model"])
    if model is not None:
        models = [m for m in models if m == model]
    chips = _series(groups["timeline"], "chips") \
        if groups["timeline"] else _series(groups["cluster"], "chips")
    print("== control plane ==", file=out)
    if chips:
        print(f"  chips      {_spark(chips, width)}  "
              f"(peak {max(chips):.0f})", file=out)
    for m in models:
        rows = [r for r in signals if r["model"] == m]
        if not rows:
            continue
        print(f"  model {m}", file=out)
        for key, label in (("q_interactive", "q_inter "),
                           ("q_batch", "q_batch "),
                           ("ibp", "ibp     "),
                           ("bbp", "bbp     ")):
            vals = [v for v in _series(rows, key) if v == v]  # drop NaN
            if vals:
                print(f"    {label} {_spark(vals, width)}  "
                      f"(max {max(vals):.2f})", file=out)


_OVERLOAD_ACTIONS = ("reject", "shed", "expire", "breaker", "brownout")


def _overload(groups: Dict[str, list], meta: dict, width: int,
              out) -> None:
    """Shed/reject/breaker panel: only rendered when the run carried the
    overload plane (any overload-kind decision rows, or nonzero outcome
    rates in the meta header)."""
    rows = [r for r in groups["decision"]
            if r.get("action") in _OVERLOAD_ACTIONS]
    rates = {k: meta.get(k, 0.0) or 0.0
             for k in ("reject_rate", "shed_rate", "expired_rate")}
    if not rows and not any(rates.values()):
        return
    print("== overload plane ==", file=out)
    print(f"  goodput {meta.get('goodput', 0.0):.2f} req/s   "
          f"rejected {rates['reject_rate']:.1%}   "
          f"shed {rates['shed_rate']:.1%}   "
          f"expired {rates['expired_rate']:.1%}", file=out)
    counts: Dict[str, int] = {}
    for r in rows:
        # shed/expire sweeps are aggregate rows: `count` requests each
        counts[r["action"]] = counts.get(r["action"], 0) \
            + int(r.get("count", 1))
    if counts:
        print("  events     " + "  ".join(
            f"{k}={counts[k]}" for k in _OVERLOAD_ACTIONS if k in counts),
            file=out)
    # per-action activity over time (event counts per time bucket)
    t1 = max((r["t"] for r in rows), default=0.0)
    for action in _OVERLOAD_ACTIONS:
        ts = [r["t"] for r in rows if r["action"] == action]
        if not ts or t1 <= 0:
            continue
        buckets = [0.0] * width
        for t in ts:
            buckets[min(int(t / t1 * (width - 1)), width - 1)] += 1
        print(f"    {action:<8} {_spark(buckets, width)}", file=out)
    trans = [r for r in rows if r["action"] in ("breaker", "brownout")]
    for r in trans[:12]:
        val = r.get("value")
        vs = f" value={val:.3g}" if isinstance(val, float) \
            and val == val else ""
        print(f"  t={r['t']:9.2f}  {r['action']:<8} "
              f"{r.get('reason'):<10} cluster={r.get('cluster')}{vs}",
              file=out)


def _decisions(groups: Dict[str, list], model: Optional[str],
               out, limit: int = 40) -> None:
    rows = groups["decision"]
    if model is not None:
        rows = [r for r in rows if r.get("model") == model]
    print(f"== decision ledger ({len(rows)} actions) ==", file=out)
    shown = rows if len(rows) <= limit else rows[:limit // 2] \
        + rows[-limit // 2:]
    skipped = len(rows) - len(shown)
    for i, r in enumerate(shown):
        if skipped and i == limit // 2:
            print(f"  ... {skipped} more ...", file=out)
        val = r.get("value")
        vs = f" value={val:.3g}" if isinstance(val, float) \
            and val == val else ""
        thr = r.get("threshold")
        ts = f" thr={thr:.3g}" if isinstance(thr, float) \
            and thr == thr else ""
        print(f"  t={r['t']:9.2f}  {r['action']:<9} {r['reason']:<10} "
              f"model={r.get('model')} itype={r.get('itype')} "
              f"chips {r['chips_before']}->{r['chips_after']}{vs}{ts}",
              file=out)


def _waterfalls(groups: Dict[str, list], model: Optional[str],
                n: int, width: int, out) -> None:
    reqs = groups["request"]
    if model is not None:
        reqs = [r for r in reqs if r.get("model") == model]
    print(f"== request waterfalls ({min(n, len(reqs))} of {len(reqs)} "
          f"sampled) ==", file=out)
    for r in reqs[:n]:
        t0 = r["arrival"]
        t1 = r["finish"]
        if t1 is None:
            ends = [tr["t"] for tr in r["transitions"]]
            t1 = max(ends) if ends else t0
        span = max(t1 - t0, 1e-9)

        def x(t: float) -> int:
            return min(int((t - t0) / span * (width - 1)), width - 1)

        bar = ["."] * width                       # queued by default
        ftt = r["first_token"]
        for tr in r["transitions"]:
            if tr["event"] == "admit":
                for i in range(x(tr["t"]), width):
                    bar[i] = "="
                if ftt is not None and ftt >= tr["t"]:
                    for i in range(x(max(ftt, tr["t"])), width):
                        bar[i] = "#"
            else:                                 # preempt: back to queued
                for i in range(x(tr["t"]), width):
                    bar[i] = "."
                bar[x(tr["t"])] = "x"
        ttft = "-" if ftt is None else f"{ftt - t0:7.3f}s"
        print(f"  row {r['row']:>7} {r['model'] or '?':<12} "
              f"|{''.join(bar)}| t0={t0:9.2f} ttft={ttft} "
              f"dur={t1 - t0:8.3f}s", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description=__doc__.splitlines()[0])
    ap.add_argument("jsonl", help="flight-recorder export "
                    "(repro.obs.export.to_jsonl)")
    ap.add_argument("--model", default=None,
                    help="restrict dashboards/waterfalls to one model")
    ap.add_argument("--width", type=int, default=72)
    ap.add_argument("--waterfalls", type=int, default=8,
                    help="number of sampled requests to render")
    args = ap.parse_args(argv)

    groups = _load(args.jsonl)
    out = sys.stdout
    meta = groups["meta"][0] if groups["meta"] else {}
    print(f"flight recorder: {args.jsonl}", file=out)
    if meta:
        print(f"  clusters={meta.get('clusters')} "
              f"models={meta.get('models')} "
              f"duration={meta.get('duration', 0.0):.1f}s "
              f"scale_ups={meta.get('scale_ups')} "
              f"scale_downs={meta.get('scale_downs')}", file=out)
    _dashboard(groups, args.model, args.width, out)
    _overload(groups, meta, args.width, out)
    _decisions(groups, args.model, out)
    _waterfalls(groups, args.model, args.waterfalls, args.width, out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
