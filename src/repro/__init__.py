"""repro: production-grade JAX reproduction of Chiron — hierarchical
autoscaling for LLM serving (Patke et al., 2025) — plus the serving,
model, kernel and launch substrate it runs on."""

__version__ = "0.1.0"
