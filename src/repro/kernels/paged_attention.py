"""Paged-attention decode kernel (TPU Pallas).

TPU-native adaptation of vLLM's paged attention (DESIGN.md §3): the KV pool
is a dense HBM array ``(num_pages, page_size, n_kv_heads, head_dim)``; the
grid iterates ``(batch, kv_head, page)`` and the BlockSpec index_map reads
the per-sequence block table (scalar-prefetched) to DMA exactly one page's
K/V tile into VMEM per step. A flash-style online-softmax accumulator lives
in VMEM scratch; the output is written on the final page iteration.

Page tiles are (page_size, head_dim) = multiples of the (8,128) TPU tile as
long as page_size % 8 == 0 and head_dim % 128 == 0 (we use 16/128 defaults).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_PAGE_SIZE = 16
_NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_s, l_s, acc_s, *, page_size: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[b]
    base = p * page_size

    @pl.when(base < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (group, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (page, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        s = jnp.where(idx < length, s, _NEG_INF)       # (group, page)

        m_prev = m_s[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new)
        l_new = alpha * l_s[:, :1] + jnp.sum(pexp, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = jnp.broadcast_to(m_new, m_s.shape)
        l_s[...] = jnp.broadcast_to(l_new, l_s.shape)

    @pl.when(p == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_s[:, :1], 1e-30)
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array,
                    *, page_size: int = DEFAULT_PAGE_SIZE,
                    interpret: bool = False) -> jax.Array:
    """Decode attention over paged KV.

    q            (B, n_kv, group, head_dim)  — one query token per sequence
    k_pool/v_pool(num_pages, page_size, n_kv, head_dim)
    block_tables (B, max_pages) int32        — page ids per sequence
    lengths      (B,) int32                  — tokens in each sequence's KV
    returns      (B, n_kv, group, head_dim)
    """
    B, n_kv, group, hd = q.shape
    max_pages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_kv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, p, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda b, h, p, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, q, k_pool, v_pool)
