"""Jit'd public wrappers around the Pallas kernels.

On TPU the real kernels run; on CPU hosts (this container) callers either
use ``backend="ref"`` (pure-jnp oracle, fast under jit) or
``backend="interpret"`` (executes the actual kernel body in the Pallas
interpreter — used by the correctness tests).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax

from repro.kernels import ref as _ref
from repro.kernels.flash_prefill import flash_prefill as _flash_kernel
from repro.kernels.paged_attention import paged_attention as _paged_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel

Backend = Literal["tpu", "interpret", "ref"]


def default_backend() -> Backend:
    return "tpu" if jax.default_backend() == "tpu" else "ref"


def paged_attention(q, k_pool, v_pool, block_tables, lengths, *,
                    page_size: int = 16, backend: Backend | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return jax.jit(_ref.paged_attention_ref)(q, k_pool, v_pool,
                                                 block_tables, lengths)
    return _paged_kernel(q, k_pool, v_pool, block_tables, lengths,
                         page_size=page_size,
                         interpret=(backend == "interpret"))


def flash_prefill(q, k, v, *, causal: bool = True, block_q: int = 256,
                  block_k: int = 256, backend: Backend | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return jax.jit(functools.partial(_ref.flash_prefill_ref,
                                         causal=causal))(q, k, v)
    return _flash_kernel(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k,
                         interpret=(backend == "interpret"))


def ssd_scan(x, dt, A, B, C, h0=None, *, chunk: int = 256,
             backend: Backend | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return jax.jit(functools.partial(_ref.ssd_scan_ref, chunk=chunk))(
            x, dt, A, B, C, h0)
    s = x.shape[1]
    if s % chunk:
        # pad to a chunk multiple (dt=0 padded steps are identity; the
        # final state is unaffected — see models/ssm.ssd_chunked)
        import jax.numpy as jnp
        pad = chunk - s % chunk
        y, h = ssd_scan(jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        jnp.pad(dt, ((0, 0), (0, pad), (0, 0))), A,
                        jnp.pad(B, ((0, 0), (0, pad), (0, 0))),
                        jnp.pad(C, ((0, 0), (0, pad), (0, 0))),
                        h0, chunk=chunk, backend=backend)
        return y[:, :s], h
    return _ssd_kernel(x, dt, A, B, C, h0, chunk=chunk,
                       interpret=(backend == "interpret"))
