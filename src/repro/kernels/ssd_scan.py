"""Mamba2 SSD chunked-scan kernel (TPU Pallas).

The chunk axis is sequential ("arbitrary") and carries the SSM state
(P, N) in VMEM scratch; per chunk the kernel computes the intra-chunk
quadratic (attention-like) term on the MXU plus the inter-chunk
contribution of the carried state, then updates the state — the same
dataflow as ``repro.models.ssm.ssd_chunked`` (the oracle), but with one
HBM->VMEM DMA per (x, dt, B, C) chunk tile and no (b, nc, cs, cs, h)
intermediate materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref, y_ref, state_ref,
            h_s, *, chunk: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        h_s[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (cs, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)[:, None]  # (cs, 1)
    A = A_ref[0]                                     # scalar
    Bm = B_ref[0].astype(jnp.float32)                # (cs, N)
    Cm = C_ref[0].astype(jnp.float32)                # (cs, N)

    dA = dt * A                                      # (cs, 1)
    dA_cum = jnp.cumsum(dA, axis=0)                  # (cs, 1)

    # intra-chunk: y_diag = ((C B^T) ∘ L ∘ dt_j) x
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    li = dA_cum                                      # (cs,1) broadcast rows
    lj = dA_cum[:, 0][None, :]                       # (1,cs) cols
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(li - lj), 0.0)
    w = scores * L * dt[:, 0][None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y_off = exp(dA_cum) * (C h^T);  h (P,N)
    y += jnp.exp(dA_cum) * jax.lax.dot_general(
        Cm, h_s[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h' = exp(dA_total) h + x^T (decay_to_end * dt * B)
    dA_total = dA_cum[chunk - 1, 0]
    decay = jnp.exp(dA_total - dA_cum)               # (cs,1)
    h_s[...] = jnp.exp(dA_total) * h_s[...] + jax.lax.dot_general(
        x, Bm * (decay * dt), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _finalize():
        state_ref[0, 0] = h_s[...].astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, h0: jax.Array = None, *, chunk: int = 256,
             interpret: bool = False):
    """Chunked SSD scan.

    x (b,s,h,p); dt (b,s,h); A (h,); B (b,s,n); C (b,s,n);
    h0 optional initial state (b,h,p,n)
    -> (y (b,s,h,p), final_state (b,h,p,n))
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    grid = (b, h, nc)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C, h0)
    return y, state
