"""Pure-jnp oracles for every kernel in this package.

These are the correctness references the Pallas kernels are validated
against (interpret=True on CPU), and the jittable fallback path ``ops.py``
uses on hosts without a TPU.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked as _ssd_chunked_model

_NEG_INF = -1e30


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Oracle for kernels.paged_attention.paged_attention.

    q (B, n_kv, group, D); pools (P, page, n_kv, D); block_tables (B, max_pages);
    lengths (B,). Returns (B, n_kv, group, D).
    """
    B, n_kv, group, D = q.shape
    page = k_pool.shape[1]
    max_pages = block_tables.shape[1]
    S = max_pages * page
    # gather pages -> (B, S, n_kv, D)
    k = k_pool[block_tables].reshape(B, S, n_kv, D)
    v = v_pool[block_tables].reshape(B, S, n_kv, D)
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True) -> jax.Array:
    """Oracle for kernels.flash_prefill. q (B,H,S,D); k/v (B,Hkv,S,D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, S, D)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, h0=None, *, chunk: int = 256):
    """Oracle for kernels.ssd_scan — reuses the model-layer SSD (itself
    validated against the sequential recurrence in tests)."""
    return _ssd_chunked_model(x, dt, A, B, C, chunk, h0=h0)


def ssd_sequential_ref(x, dt, A, B, C):
    """Fully sequential SSM recurrence — ground truth for both the chunked
    model implementation and the Pallas kernel.

    x (b,s,h,p); dt (b,s,h); A (h,); B (b,s,n); C (b,s,n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p),(b,h),(b,n),(b,n)
        dA = jnp.exp(dtt * A)  # (b,h)
        upd = (dtt[:, :, None] * xt)[..., None] * Bt[:, None, None, :]
        hstate = dA[:, :, None, None] * hstate + upd
        y = jnp.einsum("bhpn,bn->bhp", hstate, Ct)
        return hstate, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final
