"""Causal flash-attention prefill kernel (TPU Pallas).

Chunked-prefill attention for serving instances: grid (batch, q_head,
q_block, kv_block) with the kv_block axis sequential ("arbitrary") so a
flash online-softmax accumulator can live in VMEM scratch. Blocks above the
causal diagonal are skipped with ``pl.when`` — both the DMA cost model and
the FLOP count see only the lower triangle. GQA is handled by indexing the
KV head as q_head // group in the BlockSpec index_map.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s,
            *, block_q: int, block_k: int, scale: float, causal: bool):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # skip fully-masked blocks above the causal diagonal
    run = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            ki = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qi >= ki, s, _NEG_INF)
        m_prev = m_s[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new)
        l_s[...] = jnp.broadcast_to(
            alpha * l_s[:, :1] + jnp.sum(pexp, axis=1, keepdims=True), l_s.shape)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = jnp.broadcast_to(m_new, m_s.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_s[:, :1], 1e-30)
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  block_q: int = 256, block_k: int = 256,
                  causal: bool = True, interpret: bool = False) -> jax.Array:
    """Flash attention. q (B,H,S,D); k/v (B,Hkv,S,D); returns (B,H,S,D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    scale = 1.0 / math.sqrt(D)
    grid = (B, H, S // block_q, S // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
