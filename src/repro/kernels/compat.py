"""Pallas-TPU API compatibility.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back-compat varies by release); resolve whichever this interpreter ships so
the kernels import on any jax the image bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
