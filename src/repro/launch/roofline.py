"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / peak_FLOP/s          (per-chip: XLA reports
                                                    the partitioned module)
memory term     = HLO_bytes / HBM_bw
collective term = collective_bytes / link_bw

collective_bytes is not in cost_analysis: we parse the optimized HLO text
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

# hardware constants (task-given, TPU v5e class)
PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes per collective kind (result-shape bytes, '-start' ops only
    counted once; '-done' carries the same tuple so we skip it)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = re.compile(r"-done\(")
    for m in _OP_RE.finditer(hlo_text):
        line = m.group(0)
        if seen_done.search(line):
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return out


@dataclass
class RooflineTerms:
    flops: float                 # per-chip HLO FLOPs
    hbm_bytes: float             # per-chip HLO bytes accessed
    coll_bytes: float            # per-chip collective bytes
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0     # 6*N*D (or 6*N_active*D) useful FLOPs/chip

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        if self.flops <= 0:
            return 0.0
        return self.model_flops / self.flops

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "coll_breakdown": self.coll_breakdown,
        }


def extract_terms(compiled, n_chips: int, model_flops_global: float) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops_global / n_chips,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); forward-only
    kinds use 2*N*D (prefill) or 2*N_active per token (decode)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per sequence
