import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST stay the first statements in this module — jax
# locks the device count on first initialization (see task brief).
#
# Multi-pod dry-run: lower + compile every (arch x input-shape) pair on the
# production mesh, print memory/cost analysis, and dump roofline terms.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
#       [--out results.jsonl]

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import extract_terms, model_flops_for
from repro.launch.steps import jit_step, resolve_config
from repro.models import runtime_flags


def _layer_trips(cfg) -> int:
    """Trip count of each over-layers while loop in the lowered module."""
    if cfg.arch_type == "hybrid":
        return cfg.attn_every
    if cfg.arch_type == "audio":
        # encoder and decoder loops share a trip count for our configs
        assert cfg.n_enc_layers == cfg.n_layers
        return cfg.n_layers
    return cfg.n_layers


def _compile_once(cfg, shape, mesh, remat, unroll, zero_opt=False, microbatch=0):
    runtime_flags.set_scan_unroll(unroll)
    runtime_flags.set_mesh(mesh)
    try:
        with mesh:
            jf, args = jit_step(cfg, shape, mesh, remat=remat, zero_opt=zero_opt,
                                microbatch=microbatch)
            lowered = jf.lower(*args)
            compiled = lowered.compile()
    finally:
        runtime_flags.set_mesh(None)
    return compiled


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            remat: bool = True, verbose: bool = True,
            unroll: bool = True, mesh_shape: tuple = None,
            zero_opt: bool = False, microbatch: int = 0) -> dict:
    """Lower + compile one (arch x shape x mesh).

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, so a single rolled compile undercounts FLOPs/bytes by ~n_layers.
    We compile twice (layer-scan unroll=1 and unroll=2): the delta is one
    layer's cost, and  total = R(1) + (trips-1) * (R(2) - R(1)).
    memory_analysis comes from the rolled module — that is what production
    executes (per-iteration buffer reuse).
    """
    shape = INPUT_SHAPES[shape_name]
    cfg = resolve_config(get_config(arch), shape)
    if mesh_shape is not None:
        # §Perf alternative factorization of the same chips, e.g. (32, 8)
        # when the head count doesn't divide a 16-way model axis
        axes = ("pod", "data", "model") if len(mesh_shape) == 3 \
            else ("data", "model")
        mesh = jax.make_mesh(tuple(mesh_shape), axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "multi_pod": multi_pod, "status": "ok"}
    # repro-lint: ok(DET202, real compile timing)
    t0 = time.time()
    try:
        c1 = _compile_once(cfg, shape, mesh, remat, 1, zero_opt, microbatch)
        # repro-lint: ok(DET202, real compile timing)
        t1 = time.time()
        mem = c1.memory_analysis()
        mflops = model_flops_for(cfg, shape)
        terms = extract_terms(c1, n_chips, mflops)
        if unroll:
            c2 = _compile_once(cfg, shape, mesh, remat, 2, zero_opt, microbatch)
            t2 = extract_terms(c2, n_chips, mflops)
            # with microbatching the layer loop nests inside the microbatch
            # loop; both bodies are counted once by cost analysis
            trips = _layer_trips(cfg) * max(microbatch, 1)
            scale = trips - 1
            terms.flops += scale * max(t2.flops - terms.flops, 0.0)
            terms.hbm_bytes += scale * max(t2.hbm_bytes - terms.hbm_bytes, 0.0)
            d_coll = max(t2.coll_bytes - terms.coll_bytes, 0.0)
            terms.coll_bytes += scale * d_coll
            terms.coll_breakdown = {
                k: int(terms.coll_breakdown.get(k, 0) + scale *
                       max(t2.coll_breakdown.get(k, 0) -
                           terms.coll_breakdown.get(k, 0), 0))
                for k in terms.coll_breakdown}
        # repro-lint: ok(DET202, real compile timing)
        t_end = time.time()
        rec.update(
            compile_s=round(t1 - t0, 1), total_s=round(t_end - t0, 1),
            bytes_per_device=int(getattr(mem, "temp_size_in_bytes", 0) +
                                 getattr(mem, "argument_size_in_bytes", 0) +
                                 getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            **terms.as_dict())
        if verbose:
            print(f"[{arch} x {shape_name} @ {rec['mesh']}] OK "
                  f"compile={rec['compile_s']}s "
                  f"mem/dev={rec['bytes_per_device']/2**30:.2f}GiB "
                  f"compute={terms.compute_s*1e3:.2f}ms "
                  f"memory={terms.memory_s*1e3:.2f}ms "
                  f"collective={terms.collective_s*1e3:.2f}ms "
                  f"bottleneck={terms.bottleneck} "
                  f"useful={terms.useful_flops_ratio:.2f}")
            print(f"  memory_analysis: {mem}")
            print(f"  collectives: {terms.coll_breakdown}")
    except Exception as e:  # a failure here is a sharding bug — surface it
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[{arch} x {shape_name} @ {rec['mesh']}] FAIL: "
                  f"{rec['error']}")
            traceback.print_exc()
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep the layer scan rolled (faster compile, "
                         "undercounted rooflines)")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--zero-opt", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--mesh-shape", default=None,
                    help="alternative same-size mesh, e.g. 32x8")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x")) \
        if args.mesh_shape else None

    pairs = []
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            pairs.append((a, s))
    if not (args.all or (args.arch and args.shape)):
        ap.error("pass --arch & --shape, or --all")

    failures = 0
    for a, s in pairs:
        rec = run_one(a, s, multi_pod=args.multi_pod,
                      remat=not args.no_remat, unroll=not args.no_unroll,
                      mesh_shape=mesh_shape, zero_opt=args.zero_opt,
                      microbatch=args.microbatch)
        failures += rec["status"] != "ok"
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"\n{len(pairs) - failures}/{len(pairs)} pairs lowered+compiled")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
