"""Step builders: sharded train_step / prefill_step / serve_step per arch.

``input_specs`` produces ShapeDtypeStruct stand-ins (weak-type-correct, no
allocation) for every input of the chosen step kind, so the dry-run can
lower + compile the full production configs without materializing a byte.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import InputShape, ModelConfig
from repro.launch import shardings as sh
from repro.models import Model
from repro.training.optimizer import AdamWState, adamw_init, adamw_update

LONG_CONTEXT_WINDOW = 4096


def resolve_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-dependent config adjustments (DESIGN.md §6): at 500k-token
    decode every attention-bearing arch runs the sliding-window variant so
    decode state is O(window); SSD chunking must divide the sequence."""
    if shape.name == "long_500k" and cfg.has_attention and \
            cfg.sliding_window == 0:
        cfg = cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.sliding_window > 0:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


# ------------------------------------------------------------ input specs


def _token_spec(batch: int, seq: int):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    out = {"tokens": _token_spec(batch, seq)}
    if cfg.arch_type == "audio":
        out["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model), dt)
    if cfg.arch_type == "vlm":
        out["vision"] = jax.ShapeDtypeStruct((batch, cfg.n_vision_tokens,
                                              cfg.d_model), dt)
    return out


def params_specs(cfg: ModelConfig) -> Any:
    model = Model(cfg)
    return jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Any:
    model = Model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """All ShapeDtypeStruct inputs for the step this shape lowers."""
    cfg = resolve_config(cfg, shape)
    p = params_specs(cfg)
    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, p)
        return {"params": p, "opt_state": opt,
                "batch": batch_specs(cfg, shape.global_batch, shape.seq_len)}
    if shape.kind == "prefill":
        return {"params": p,
                "batch": batch_specs(cfg, shape.global_batch, shape.seq_len)}
    # decode: one token against a cache of seq_len
    clen = cache_len_for(cfg, shape)
    cache = cache_specs(cfg, shape.global_batch, clen)
    return {"params": p,
            "tokens": _token_spec(shape.global_batch, 1),
            "cache": cache}


# ------------------------------------------------------------ step fns


def make_train_step(cfg: ModelConfig, *, remat: bool = True, lr: float = 3e-4,
                    microbatch: int = 0):
    """Build the train step. microbatch=M > 1 splits the global batch into
    M sequential microbatches with f32 gradient accumulation (§Perf B4):
    live activation memory scales ~1/M for the cost of re-reading the
    accumulator M times."""
    model = Model(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=remat))(params)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatch and microbatch > 1:
            m = microbatch

            def split(a):
                return a.reshape(m, a.shape[0] // m, *a.shape[1:])

            mbatches = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                loss, g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, loss

            gacc, losses = jax.lax.scan(body, zeros, mbatches)
            grads = jax.tree.map(lambda g: g / m, gacc)
            loss = jnp.mean(losses)
        else:
            loss, grads = grads_of(params, batch)
        new_params, new_opt, info = adamw_update(grads, opt_state, params,
                                                 lr=lr)
        return new_params, new_opt, {"loss": loss, **info}
    return train_step


def make_prefill_step(cfg: ModelConfig, shape: Optional[InputShape] = None):
    model = Model(cfg)
    clen = cache_len_for(cfg, shape) if shape else None

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=clen)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    model = Model(cfg)

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    return serve_step


# ------------------------------------------------------------ jit + shard


def jit_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
             remat: bool = True, donate: bool = True,
             zero_opt: bool = False, microbatch: int = 0):
    """Build the sharded jitted step for (cfg, shape, mesh); returns
    (jitted_fn, ordered_input_specs_tuple)."""
    cfg = resolve_config(cfg, shape)
    specs = input_specs(cfg, shape)
    p_sh = sh.param_shardings(mesh, specs["params"])
    B = shape.global_batch

    if shape.kind == "train":
        fn = make_train_step(cfg, remat=remat, microbatch=microbatch)
        o_sh = sh.opt_shardings(mesh, specs["opt_state"], p_sh, zero=zero_opt)
        b_sh = sh.batch_shardings(mesh, specs["batch"])
        metrics_sh = jax.tree.map(lambda _: sh.replicated(mesh),
                                  {"loss": 0, "grad_norm": 0})
        jf = jax.jit(fn,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, metrics_sh),
                     donate_argnums=(0, 1) if donate else ())
        args = (specs["params"], specs["opt_state"], specs["batch"])
        return jf, args

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, shape)
        b_sh = sh.batch_shardings(mesh, specs["batch"])
        clen = cache_len_for(cfg, shape)
        c_spec = jax.eval_shape(
            lambda p, b: fn(p, b)[1], specs["params"], specs["batch"])
        c_sh = sh.cache_shardings(mesh, c_spec, B)
        l_sh = sh.logits_sharding(mesh, B, cfg.vocab_size)
        jf = jax.jit(fn, in_shardings=(p_sh, b_sh),
                     out_shardings=(l_sh, c_sh))
        return jf, (specs["params"], specs["batch"])

    # decode
    fn = make_serve_step(cfg)
    c_sh = sh.cache_shardings(mesh, specs["cache"], B)
    t_sh = sh.batch_shardings(mesh, {"tokens": specs["tokens"]})["tokens"]
    l_sh = sh.logits_sharding(mesh, B, cfg.vocab_size)
    jf = jax.jit(fn, in_shardings=(p_sh, t_sh, c_sh),
                 out_shardings=(l_sh, c_sh),
                 donate_argnums=(2,) if donate else ())
    return jf, (specs["params"], specs["tokens"], specs["cache"])
