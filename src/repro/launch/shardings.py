"""Sharding rules: map every param/cache/batch leaf to a PartitionSpec.

Policy (baseline; §Perf iterates on it):
- tensor parallelism on the ``model`` axis: attention QKV/out projections,
  FFN in/out, MoE experts (expert-parallel when n_experts divides the axis,
  else per-expert tensor parallel on d_ff), vocab-sharded embedding/head,
  SSM inner channels;
- data parallelism on ``data`` (and ``pod`` when present): the batch axis
  of inputs and caches;
- every rule checks divisibility and falls back to replication, so any
  (arch x shape x mesh) combination lowers.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P



def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _batch_spec_axis(mesh: Mesh, b: int):
    """Largest prefix of the batch axes that divides b (else None)."""
    sizes = axis_sizes(mesh)
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= sizes[a]
    if b % total == 0:
        return axes if len(axes) > 1 else axes[0]
    if b % sizes["data"] == 0:
        return "data"
    return None


def _key_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return tuple(names)


def _div(shape, dim: int, size: int) -> bool:
    return 0 <= dim < len(shape) and shape[dim] % size == 0


def param_spec(names: Tuple[str, ...], shape: Tuple[int, ...],
               msize: int) -> P:
    """PartitionSpec for one parameter leaf (model-axis TP only)."""
    def spec_at(dim: int) -> P:
        dim = dim % len(shape)
        if not _div(shape, dim, msize):
            return P()
        out = [None] * len(shape)
        out[dim] = "model"
        return P(*out)

    name = names[-1] if names else ""
    in_moe = "moe" in names
    if in_moe and len(shape) == 4:                   # (L, E, d, f) experts
        # f-sharded tensor parallelism (Megatron column/row pairing): the
        # capacity-dispatch block shard_maps over f, and a uniform layout
        # avoids per-layer resharding (§Perf A4). Expert-parallel E
        # sharding is the fallback when f doesn't divide.
        dim = -1 if name in ("w_gate", "w_up") else -2
        if _div(shape, dim % len(shape), msize):
            return spec_at(dim)
        return spec_at(1)                            # expert parallel
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_in"):
        return spec_at(-1)
    if name in ("wo", "w_down", "w_out"):
        return spec_at(-2)
    if name == "router":
        return spec_at(-1)
    if name == "tok":
        return spec_at(0)                            # vocab-sharded embedding
    if name == "head":
        return spec_at(-1)                           # vocab-sharded logits
    if name == "conv_w":
        return spec_at(-1)
    if name in ("A_log", "D", "dt_bias"):
        return spec_at(-1)
    return P()                                       # norms, biases, pos-emb


def cache_spec(key: str, shape: Tuple[int, ...], mesh: Mesh,
               batch: int) -> P:
    sizes = axis_sizes(mesh)
    msize = sizes["model"]
    baxis = _batch_spec_axis(mesh, batch)
    if key in ("pos",):
        return P(baxis)
    if key == "slot_pos":
        # keep the slot->position map sharded like the cache length it
        # masks (§Perf C3)
        if _div(shape, 1, msize):
            return P(baxis, "model")
        return P(baxis, None)
    out = [None] * len(shape)
    out[1] = baxis                                   # (L/G, B, ...) layouts
    if key in ("k", "v", "cross_k", "cross_v"):
        if _div(shape, 3, msize):
            out[3] = "model"                         # kv heads
        elif _div(shape, 2, msize):
            # sequence-sharded KV (§Perf C1): when kv-heads don't divide
            # the model axis, shard the cache length instead — decode
            # scores contract head_dim locally and only the tiny softmax
            # stats + (B,H,D) output need cross-shard reduction, vs
            # all-gathering the whole cache per layer under hd-sharding.
            out[2] = "model"
        elif _div(shape, 4, msize):
            out[4] = "model"                         # head_dim fallback
    elif key == "ssm":
        if _div(shape, 2, msize):
            out[2] = "model"                         # SSM heads
        elif _div(shape, 3, msize):
            out[3] = "model"
    elif key == "conv":
        if _div(shape, 3, msize):
            out[3] = "model"                         # conv channels
    return P(*out)


# ------------------------------------------------------------------ trees


def param_shardings(mesh: Mesh, params_shape) -> Any:
    msize = axis_sizes(mesh)["model"]

    def leaf(path, sds):
        return NamedSharding(mesh, param_spec(_key_names(path), sds.shape,
                                              msize))
    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def cache_shardings(mesh: Mesh, cache_shape, batch: int) -> Any:
    def leaf(path, sds):
        names = _key_names(path)
        return NamedSharding(mesh, cache_spec(names[-1], sds.shape, mesh,
                                              batch))
    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def batch_shardings(mesh: Mesh, batch_shape) -> Any:
    def leaf(path, sds):
        b = sds.shape[0]
        baxis = _batch_spec_axis(mesh, b)
        return NamedSharding(mesh, P(baxis, *([None] * (len(sds.shape) - 1))))
    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def opt_shardings(mesh: Mesh, opt_shape, param_sh, *, zero: bool = False) -> Any:
    """AdamW state: moments follow the params; step replicated.

    zero=True (§Perf B3, ZeRO-1): additionally shard each moment over the
    data axis on the largest param dim that is unsharded and divisible —
    the f32 moments are 4x the bf16 params, so keeping them replicated
    across the data axis dominates per-device argument memory.
    """
    from repro.training.optimizer import AdamWState
    rep = NamedSharding(mesh, P())
    if not zero:
        return AdamWState(rep, param_sh, param_sh)
    dsize = axis_sizes(mesh)["data"]

    def zero_leaf(sh: NamedSharding, sds) -> NamedSharding:
        spec = list(sh.spec) + [None] * (len(sds.shape) - len(sh.spec))
        cands = [(sds.shape[i], i) for i in range(len(sds.shape))
                 if spec[i] is None and sds.shape[i] % dsize == 0]
        if cands:
            _, dim = max(cands)
            spec[dim] = "data"
        return NamedSharding(mesh, P(*spec))

    mom_sh = jax.tree.map(zero_leaf, param_sh, opt_shape.mu)
    return AdamWState(rep, mom_sh, mom_sh)


def logits_sharding(mesh: Mesh, batch: int, vocab: int) -> NamedSharding:
    msize = axis_sizes(mesh)["model"]
    vspec = "model" if vocab % msize == 0 else None
    return NamedSharding(mesh, P(_batch_spec_axis(mesh, batch), vspec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
