"""Training launcher: end-to-end LM training with the repro substrate
(AdamW, remat, checkpointing), on CPU with a reduced config or on a mesh
with the full config.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200 \
      --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import adamw_init


def synthetic_lm_batch(rng, model: Model, batch: int, seq: int):
    """Structured synthetic data (learnable patterns, not pure noise)."""
    cfg = model.cfg
    v = cfg.vocab_size
    base = rng.integers(0, v, size=(batch, 1), dtype=np.int32)
    ramp = (base + np.arange(seq, dtype=np.int32)[None, :] *
            rng.integers(1, 7, size=(batch, 1))) % v
    noise = rng.integers(0, v, size=(batch, seq), dtype=np.int32)
    mask = rng.random((batch, seq)) < 0.1
    toks = np.where(mask, noise, ramp).astype(np.int32)
    b = {"tokens": jnp.asarray(toks)}
    if cfg.arch_type == "audio":
        b["frames"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.arch_type == "vlm":
        b["vision"] = jnp.zeros((batch, cfg.n_vision_tokens, cfg.d_model),
                                jnp.float32)
    return b


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config \
        else get_smoke_config(args.arch)
    model = Model(cfg)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"(reduced={not args.full_config})")

    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, remat=False, lr=args.lr))
    rng = np.random.default_rng(0)

    # repro-lint: ok(DET202, real training wall clock)
    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        batch = synthetic_lm_batch(rng, model, args.batch, args.seq)
        params, opt, m = step_fn(params, opt, batch)
        loss = float(m["loss"])
        if first is None:
            first = loss
        last = loss
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={loss:.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  # repro-lint: ok(DET202, real training wall clock)
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": params},
                        meta={"arch": cfg.name, "steps": args.steps})
        print(f"checkpoint saved to {args.checkpoint}")


if __name__ == "__main__":
    main()
