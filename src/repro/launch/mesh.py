"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host
devices *before* any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / single-host serving)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
