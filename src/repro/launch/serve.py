"""Serving launcher: run a real continuous-batching instance with Chiron's
local autoscaler closed-loop on measured ITL/throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
      --requests 24 --max-slots 8 --itl-slo 0.5

Uses the reduced (smoke) model variant on CPU; on TPU the same code path
serves the full config (params sharded per launch.shardings).
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core.backpressure import LocalMetrics
from repro.core.local_autoscaler import LocalAutoscaler
from repro.serving.engine import Engine
from repro.sim.workload import WorkloadSpec, generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=160)
    ap.add_argument("--itl-slo", type=float, default=0.5)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full assigned config (TPU-scale)")
    ap.add_argument("--autoscale-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config \
        else get_smoke_config(args.arch)
    print(f"serving {cfg.name} ({cfg.arch_type}), "
          f"{cfg.param_count()/1e6:.1f}M params")
    eng = Engine(cfg, max_slots=args.max_slots, max_len=args.max_len,
                 dtype=jnp.float32)
    scaler = LocalAutoscaler(itl_slo=args.itl_slo, init_batch=2,
                             max_batch=args.max_slots)

    spec = WorkloadSpec(n_requests=args.requests, arrival_rate=50.0,
                        interactive_frac=0.7, model=cfg.name)
    reqs = generate(spec)
    for r in reqs:
        r.prompt_len = min(r.prompt_len, args.max_len // 3)
        r.output_len = min(r.output_len, args.max_len // 3)
        eng.submit(r)

    # repro-lint: ok(DET202, real-engine wall clock)
    t0 = time.monotonic()
    steps = 0
    while eng.waiting or eng.n_active:
        stats = eng.step()
        steps += 1
        if steps % args.autoscale_every == 0 and stats.n_active:
            bs = scaler.update(LocalMetrics(
                observed_itl=stats.itl, throughput=stats.throughput or 1.0,
                itl_slo=args.itl_slo))
            eng.set_max_batch_size(bs)
            print(f"step {steps:4d}: active={stats.n_active} itl="
                  f"{stats.itl*1e3:.0f}ms thr={stats.throughput:.1f} tok/s "
                  f"-> max_batch={bs}")

    # repro-lint: ok(DET202, real-engine wall clock)
    wall = time.monotonic() - t0
    done = [r for r in reqs if r.state.value == "finished"]
    toks = sum(r.tokens_generated for r in reqs)
    print(f"\nserved {len(done)}/{len(reqs)} requests, {toks} tokens in "
          f"{wall:.1f}s ({toks/wall:.1f} tok/s), final batch size "
          f"{scaler.max_batch_size}")
    itl_ok = sum(r.itl_met() for r in done)
    print(f"ITL SLO met: {itl_ok}/{len(done)}")


if __name__ == "__main__":
    main()
