"""Paged KV-cache block allocator (vLLM-style, TPU page layout).

Tracks page ownership for every sequence, supports append-one-token growth,
whole-sequence free, and host offload/restore (the mechanism Chiron's mixed
instances use for fast batch-request restart after eviction). The allocator
is pure bookkeeping — the actual pool arrays live with the engine/kernels;
tests drive it with hypothesis to check the no-leak/no-double-alloc
invariants.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class SeqAlloc:
    pages: List[int] = field(default_factory=list)
    n_tokens: int = 0
    on_host: bool = False


class PagedKVManager:
    def __init__(self, num_pages: int, page_size: int):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._seqs: Dict[int, SeqAlloc] = {}

    # ------------------------------------------------------------ queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def has_seq(self, seq_id: int) -> bool:
        return seq_id in self._seqs and not self._seqs[seq_id].on_host

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].pages)

    def seq_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].n_tokens

    def can_allocate(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= self.free_pages

    # ------------------------------------------------------------ mutation
    def allocate(self, seq_id: int, n_tokens: int) -> List[int]:
        if seq_id in self._seqs:
            raise ValueError(f"seq {seq_id} already allocated")
        need = self.pages_needed(n_tokens)
        if need > len(self._free):
            raise OutOfPagesError(
                f"need {need} pages, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(need)]
        self._seqs[seq_id] = SeqAlloc(pages, n_tokens)
        return list(pages)

    def append_token(self, seq_id: int) -> Optional[int]:
        """Grow a sequence by one token; returns the new page id if one was
        allocated, else None. Raises OutOfPagesError when the pool is full."""
        sa = self._seqs[seq_id]
        if sa.on_host:
            raise ValueError(f"seq {seq_id} is offloaded")
        sa.n_tokens += 1
        if sa.n_tokens > len(sa.pages) * self.page_size:
            if not self._free:
                sa.n_tokens -= 1
                raise OutOfPagesError("pool full on append")
            page = self._free.pop()
            sa.pages.append(page)
            return page
        return None

    def free(self, seq_id: int) -> None:
        sa = self._seqs.pop(seq_id)
        if not sa.on_host:
            self._free.extend(sa.pages)

    # ------------------------------------------------- host offload (swap)
    def swap_out(self, seq_id: int) -> SeqAlloc:
        """Release the device pages; the sequence's logical allocation stays
        recorded so swap_in can restore it (engine copies the page data)."""
        sa = self._seqs[seq_id]
        if sa.on_host:
            raise ValueError("already on host")
        self._free.extend(sa.pages)
        sa.pages = []
        sa.on_host = True
        return sa

    def swap_in(self, seq_id: int) -> List[int]:
        sa = self._seqs[seq_id]
        if not sa.on_host:
            raise ValueError("not on host")
        need = self.pages_needed(sa.n_tokens)
        if need > len(self._free):
            raise OutOfPagesError("cannot swap in")
        sa.pages = [self._free.pop() for _ in range(need)]
        sa.on_host = False
        return list(sa.pages)

    # ------------------------------------------------------------ checking
    def check_invariants(self) -> None:
        owned: Set[int] = set()
        for sid, sa in self._seqs.items():
            for p in sa.pages:
                assert p not in owned, f"page {p} double-owned"
                owned.add(p)
            if not sa.on_host:
                assert len(sa.pages) == self.pages_needed(sa.n_tokens) or \
                    sa.n_tokens == 0
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free pages"
        assert not (free & owned), "page both free and owned"
        assert len(free) + len(owned) == self.num_pages, "pages leaked"
