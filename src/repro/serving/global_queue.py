"""Global queue (paper §3, Lifecycle of a Request) — multi-model aware.

All requests enqueue here; interactive requests follow a zero-queuing
discipline (dispatched immediately, footnote 3) while batch requests may
wait and are scheduled as request groups by the global autoscaler.

Every lane is keyed by the request's ``model``: a fleet serving N models
holds N interactive FIFO lanes and N batch heaps behind one facade, and
routing asks for work *for a specific model* so a request can never be
handed to an instance that doesn't serve it. All single-model entry
points (``pop_interactive()``, ``peek_batch()``, ...) keep their
historical semantics by taking the globally-next request across lanes.

The batch side is (per model) a binary heap keyed on ``(deadline,
arrival_time, seq)`` so every pop is O(log n) — draining n requests costs
O(n log n) total instead of the O(n^2 log n) a sort-per-pop policy
degrades to at the cluster scales the paper evaluates. Preempted batch
requests that still hold host-saved KV are parked in a per-model resume
lane served before fresh work, so a restart never re-queues behind
requests that have not prefill'd yet.

Listeners (``attach_batch_listener``) observe every batch add/remove —
optionally filtered to one model — and let each model's global autoscaler
maintain request groups incrementally instead of re-clustering the whole
queue each control tick.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Tuple

from repro.serving.request import Request, RequestType


class GlobalQueue:
    def __init__(self):
        # model -> deque of (seq, request); seq is a global FIFO stamp so
        # cross-lane pops preserve arrival order, and front-requeues take
        # negative stamps (they must precede everything already queued)
        self._ilanes: Dict[str, Deque[Tuple[int, Request]]] = {}
        self._iseq = itertools.count()
        self._ifront = itertools.count(-1, -1)
        self._icount = 0
        # model -> (deadline, arrival_time, seq, request) heap — earliest
        # deadline first, FCFS within a deadline (§5.3), seq breaks ties
        self._bheaps: Dict[str, List[Tuple[float, float, int, Request]]] = {}
        self._bresumes: Dict[str, Deque[Request]] = {}   # preempted, KV host
        self._bseq = itertools.count()
        self._bcount = 0
        self._listeners: List[Tuple[object, Optional[str]]] = []

    # ------------------------------------------------------------ intake
    def push(self, req: Request) -> None:
        if req.request_type == RequestType.INTERACTIVE:
            lane = self._ilanes.get(req.model)
            if lane is None:
                lane = self._ilanes[req.model] = deque()
            lane.append((next(self._iseq), req))
            self._icount += 1
        else:
            h = self._bheaps.get(req.model)
            if h is None:
                h = self._bheaps[req.model] = []
            heapq.heappush(h, (req.deadline, req.arrival_time,
                               next(self._bseq), req))
            self._bcount += 1
            if self._listeners:
                self._notify_add(req)

    def requeue(self, req: Request) -> None:
        """Preempted request returns to the queue.

        Zero-queuing discipline (footnote 3): a preempted interactive
        request goes to the *front* of its model's line — it already
        waited once and must not re-queue behind later arrivals. Batch
        requests with host-saved KV enter the model's resume lane (served
        first, the restart skips re-prefill); otherwise they re-enter the
        heap at their original (deadline, arrival) position.
        """
        if req.request_type == RequestType.INTERACTIVE:
            self._ilanes.setdefault(req.model, deque()).appendleft(
                (next(self._ifront), req))
            self._icount += 1
        elif req.saved_kv is not None:
            self._bresumes.setdefault(req.model, deque()).append(req)
            self._bcount += 1
            self._notify_add(req)
        else:
            self.push(req)

    # ------------------------------------------------- interactive serving
    def interactive_models(self) -> List[str]:
        """Models with queued interactive work (lane insertion order)."""
        return [m for m, d in self._ilanes.items() if d]

    def n_interactive_for(self, model: str) -> int:
        lane = self._ilanes.get(model)
        return len(lane) if lane else 0

    def peek_interactive(self, model: Optional[str] = None) -> Optional[Request]:
        lane = self._pick_ilane(model)
        return lane[0][1] if lane else None

    def pop_interactive(self, model: Optional[str] = None) -> Optional[Request]:
        lane = self._pick_ilane(model)
        if not lane:
            return None
        self._icount -= 1
        return lane.popleft()[1]

    def _pick_ilane(self, model: Optional[str]) -> Optional[Deque]:
        if model is not None:
            lane = self._ilanes.get(model)
            return lane if lane else None
        best = None
        for lane in self._ilanes.values():      # few models: O(M) scan
            if lane and (best is None or lane[0][0] < best[0][0]):
                best = lane
        return best

    # ------------------------------------------------------ batch serving
    def batch_models(self) -> List[str]:
        """Models with queued batch work (lane insertion order)."""
        out = [m for m, h in self._bheaps.items() if h]
        out.extend(m for m, d in self._bresumes.items()
                   if d and m not in out)
        return out

    def n_batch_for(self, model: str) -> int:
        return len(self._bheaps.get(model, ())) + \
            len(self._bresumes.get(model, ()))

    def peek_batch(self, model: Optional[str] = None) -> Optional[Request]:
        lane, kind = self._pick_blane(model)
        if lane is None:
            return None
        return lane[0] if kind == "resume" else lane[0][3]

    def pop_batch_fcfs(self, model: Optional[str] = None) -> Optional[Request]:
        """Earliest deadline first, then arrival order (FCFS within a
        group, §5.3); preempted requests with saved KV resume first."""
        lane, kind = self._pick_blane(model)
        if lane is None:
            return None
        req = lane.popleft() if kind == "resume" else heapq.heappop(lane)[3]
        self._bcount -= 1
        if self._listeners:
            self._notify_remove(req)
        return req

    def _pick_blane(self, model: Optional[str]):
        """The lane the next batch pop serves: a resume deque or a heap."""
        if model is not None:
            res = self._bresumes.get(model)
            if res:
                return res, "resume"
            h = self._bheaps.get(model)
            return (h, "heap") if h else (None, None)
        if self._bresumes:
            for res in self._bresumes.values():  # any resume lane first
                if res:
                    return res, "resume"
        best = None
        for h in self._bheaps.values():         # min head across models
            # seq (slot 2) is globally unique, so the head comparison
            # always resolves before reaching the Request element
            if h and (best is None or h[0] < best[0]):
                best = h
        return (best, "heap") if best is not None else (None, None)

    def drain_model(self, model: str) -> List[Request]:
        """Remove and return every queued request for ``model`` — its
        interactive lane, batch heap, and resume lane — preserving service
        order within each class (interactive first). The fleet plane uses
        this for migration hand-back: a cluster losing a model's placement
        surrenders that model's queued work for re-routing."""
        out: List[Request] = []
        lane = self._ilanes.pop(model, None)
        if lane:
            out.extend(r for _, r in lane)
            self._icount -= len(lane)
        res = self._bresumes.pop(model, None)
        if res:
            for r in res:
                out.append(r)
                self._bcount -= 1
                self._notify_remove(r)
        heap = self._bheaps.pop(model, None)
        if heap:
            heap.sort()                      # deadline/FCFS service order
            for entry in heap:
                out.append(entry[3])
                self._bcount -= 1
                self._notify_remove(entry[3])
        return out

    def iter_batch(self, model: Optional[str] = None) -> Iterator[Request]:
        """Queued batch requests in unspecified order (O(n))."""
        models = (model,) if model is not None else \
            dict.fromkeys(itertools.chain(self._bheaps, self._bresumes))
        for m in models:
            yield from self._bresumes.get(m, ())
            for entry in self._bheaps.get(m, ()):
                yield entry[3]

    # ------------------------------------------------ legacy flat views
    @property
    def interactive(self) -> List[Request]:
        """Snapshot of queued interactive requests in global FIFO order.

        O(n log n) debug/compat view — the routing hot path uses
        ``peek_interactive``/``pop_interactive`` instead.
        """
        entries: List[Tuple[int, Request]] = []
        for lane in self._ilanes.values():
            entries.extend(lane)
        entries.sort(key=lambda e: e[0])
        return [r for _, r in entries]

    @property
    def batch(self) -> List[Request]:
        """Snapshot of queued batch requests, resume lanes first, then
        earliest deadline first. O(n log n) — control-loop consumers
        prefer passing the queue itself (incremental grouping) or
        ``iter_batch``.
        """
        out: List[Request] = []
        for res in self._bresumes.values():
            out.extend(res)
        entries: List[Tuple[float, float, int, Request]] = []
        for h in self._bheaps.values():
            entries.extend(h)
        entries.sort()
        out.extend(e[3] for e in entries)
        return out

    # ------------------------------------------------------------ listeners
    def attach_batch_listener(self, listener,
                              model: Optional[str] = None) -> None:
        """Register an ``on_add(req)`` / ``on_remove(req)`` observer of the
        batch side — all models, or one model's lane when ``model`` is
        given; current (matching) contents are replayed as adds."""
        self._listeners.append((listener, model))
        for req in self.iter_batch(model):
            listener.on_add(req)

    def _notify_add(self, req: Request) -> None:
        for listener, model in self._listeners:
            if model is None or req.model == model:
                listener.on_add(req)

    def _notify_remove(self, req: Request) -> None:
        for listener, model in self._listeners:
            if model is None or req.model == model:
                listener.on_remove(req)

    # ------------------------------------------------------------ sizes
    @property
    def n_interactive(self) -> int:
        return self._icount

    @property
    def n_batch(self) -> int:
        return self._bcount

    def __len__(self) -> int:
        return self._icount + self._bcount
