"""Global queue (paper §3, Lifecycle of a Request) — multi-model aware,
columnar.

All requests enqueue here; interactive requests follow a zero-queuing
discipline (dispatched immediately, footnote 3) while batch requests may
wait and are scheduled as request groups by the global autoscaler.

Every lane is keyed by the request's ``model``: a fleet serving N models
holds N interactive FIFO lanes and N batch lane sets behind one facade,
and routing asks for work *for a specific model* so a request can never
be handed to an instance that doesn't serve it. All single-model entry
points (``pop_interactive()``, ``peek_batch()``, ...) keep their
historical semantics by taking the globally-next request across lanes.

Struct-of-arrays layout (:class:`GlobalQueue`, the default): every lane
is a :class:`_Lane` — preallocated, amortized-doubling NumPy key columns
(``seq``, ``arrival``, ``deadline``, ``row``) plus the ``req_objs``
payload list, with O(1) head/tail cursors. The per-lane **min cursor is
the head**: batch arrivals enter in nondecreasing arrival order and a
lane holds one TTFT-SLO class, so ``(deadline, arrival, seq)`` is
nondecreasing along the lane and the earliest entry is always
``columns[head]`` — no heap sift per push/pop. The rare out-of-order
entry (a requeue of an old arrival, fleet hand-back) falls into a
per-model overflow heap merged at peek time. Snapshots and drains are
vectorized (``np.lexsort`` over the concatenated key columns) instead of
sorting Python tuples. ``Request`` objects ride along as the payload —
they are only *touched* again at the admit edge (the scheduler-batch
idiom of keeping scheduling state columnar and crossing into object land
at the boundary).

:class:`ReferenceGlobalQueue` keeps the pre-columnar object flavour —
per-model deques and ``(deadline, arrival, seq, Request)`` binary heaps —
as the decision-equivalence baseline (the engines' ``reference=True``
mode); both flavours produce bit-identical pop orders.

The mirror registry ``QUEUE_MIRRORS`` maps each mirrored ``Request``
attribute to its lane column; the static auditor (``repro.analysis``,
rule MIR103) checks that every payload write also writes the key
columns, and the runtime shadow verifier rebuilds the columns from the
payload objects and asserts exact agreement.

Preempted batch requests that still hold host-saved KV are parked in a
per-model resume lane served before fresh work, so a restart never
re-queues behind requests that have not prefill'd yet.

Listeners (``attach_batch_listener``) observe every batch add/remove —
optionally filtered to one model — and let each model's global
autoscaler maintain request groups incrementally instead of
re-clustering the whole queue each control tick. Attach replays the
current contents in *service order* (resume lanes, then earliest
deadline first) so the replay stream is a property of the queue's
contents, not of either flavour's internal layout.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.serving.request import Request, RequestType

# Mirror registry: ``Request`` attribute -> lane key column holding the
# same value for every queued entry (``lane.<col>[i]`` mirrors
# ``lane.req_objs[i].<attr>``). The static auditor (rule MIR103) checks
# every payload write pairs with the key-column writes, and the runtime
# shadow verifier rebuilds the columns from the objects and asserts
# exact agreement — extend both when adding a key column.
QUEUE_MIRRORS: Dict[str, str] = {
    "arrival_time": "arrival",
    "deadline": "deadline",
    "row": "row",
}
# Every key column a payload write must refresh: the mirrored ones plus
# the queue-internal FIFO stamp (no Request twin — it exists to make the
# cross-lane pop order total).
QUEUE_KEY_COLUMNS: Tuple[str, ...] = ("seq", "arrival", "deadline", "row")

_LANE_CAP0 = 32


class _Lane:
    """One columnar lane: a FIFO over preallocated, amortized-doubling
    key columns plus the ``req_objs`` payload list.

    ``head``/``tail`` cursors bound the live window; ``head`` is the
    O(1) min cursor (see module docstring). ``push_front`` supports the
    interactive front-requeue discipline by writing at ``head - 1``
    (regrowing with front headroom when the window touches 0), so front
    entries pop most-recent-first exactly like ``deque.appendleft``.
    """

    __slots__ = ("model", "cap", "head", "tail",
                 "seq", "arrival", "deadline", "row", "req_objs")

    def __init__(self, model: str, cap: int = _LANE_CAP0):
        self.model = model
        self.cap = cap
        self.head = 0
        self.tail = 0
        self.seq = np.empty(cap, dtype=np.int64)
        self.arrival = np.empty(cap, dtype=np.float64)
        self.deadline = np.empty(cap, dtype=np.float64)
        self.row = np.empty(cap, dtype=np.int64)
        self.req_objs: List[Optional[Request]] = [None] * cap

    def __len__(self) -> int:
        return self.tail - self.head

    def _regrow(self, front_gap: int) -> None:
        """Reallocate the columns, landing the live window at offset
        ``front_gap`` (amortized doubling; also compacts a drained
        head)."""
        head, tail = self.head, self.tail
        live = tail - head
        cap = self.cap
        while cap < live + front_gap + 1:
            cap *= 2
        for name in ("seq", "arrival", "deadline", "row"):
            old = getattr(self, name)
            new = np.empty(cap, dtype=old.dtype)
            new[front_gap:front_gap + live] = old[head:tail]
            setattr(self, name, new)
        self.req_objs = [None] * front_gap + self.req_objs[head:tail] \
            + [None] * (cap - front_gap - live)
        self.cap = cap
        self.head = front_gap
        self.tail = front_gap + live

    def push(self, s: int, req: Request) -> None:
        t = self.tail
        if t == self.cap:
            self._regrow(0)
            t = self.tail
        self.seq[t] = s
        self.arrival[t] = req.arrival_time
        self.deadline[t] = req.deadline
        self.row[t] = req.row
        self.req_objs[t] = req
        self.tail = t + 1

    def push_front(self, s: int, req: Request) -> None:
        h = self.head
        if h == 0:
            self._regrow(max(4, self.cap // 4))
            h = self.head
        h -= 1
        self.seq[h] = s
        self.arrival[h] = req.arrival_time
        self.deadline[h] = req.deadline
        self.row[h] = req.row
        self.req_objs[h] = req
        self.head = h

    def popleft(self) -> Request:
        h = self.head
        req = self.req_objs[h]
        # mirror-sync: ok(clearing the freed payload cell; the key cells
        # behind the head cursor are dead)
        self.req_objs[h] = None
        h += 1
        if h == self.tail:
            self.head = self.tail = 0
        else:
            self.head = h
        return req

    def peek(self) -> Request:
        return self.req_objs[self.head]

    # ------------------------------------------------- vectorized views
    def key_slices(self):
        """Live (seq, arrival, deadline, payload) column views — the
        vectorized drain/snapshot surface."""
        h, t = self.head, self.tail
        return (self.seq[h:t], self.arrival[h:t], self.deadline[h:t],
                self.req_objs[h:t])


class GlobalQueue:
    """Columnar struct-of-arrays queue plane (see module docstring)."""

    columnar = True              # shadow verifier / introspection marker

    def __init__(self):
        # model -> interactive FIFO lane; the seq column is a global FIFO
        # stamp so cross-lane pops preserve arrival order, and
        # front-requeues take negative stamps (they must precede
        # everything already queued)
        self._ilanes: Dict[str, _Lane] = {}
        self._iseq = 0
        self._ifront = -1
        self._icount = 0
        # model -> {ttft-slo class -> lane}: one TTFT class per lane
        # keeps (deadline, arrival, seq) nondecreasing along the lane
        # (the O(1) min-cursor invariant); out-of-order entries fall
        # into the per-model overflow heap
        self._blanes: Dict[str, Dict[float, _Lane]] = {}
        self._boflow: Dict[str, List[Tuple[float, float, int, Request]]] = {}
        self._bfresh: Dict[str, int] = {}    # model -> lane+overflow count
        self._bresumes: Dict[str, _Lane] = {}   # preempted, KV on host
        self._bseq = 0
        self._bcount = 0
        self._listeners: List[Tuple[object, Optional[str]]] = []

    # ------------------------------------------------------------ intake
    def push(self, req: Request) -> None:
        if req.request_type == RequestType.INTERACTIVE:
            lane = self._ilanes.get(req.model)
            if lane is None:
                lane = self._ilanes[req.model] = _Lane(req.model)
            s = self._iseq
            self._iseq = s + 1
            lane.push(s, req)
            self._icount += 1
        else:
            self._push_batch(req)

    def _push_batch(self, req: Request) -> None:
        model = req.model
        lanes = self._blanes.get(model)
        if lanes is None:
            lanes = self._blanes[model] = {}
            self._boflow[model] = []
            self._bfresh[model] = 0
        seq = self._bseq
        self._bseq = seq + 1
        slo_class = req.slo.ttft
        lane = lanes.get(slo_class)
        if lane is None:
            lane = lanes[slo_class] = _Lane(model)
        t = lane.tail
        d = req.deadline
        if t == lane.head:
            lane.push(seq, req)
        else:
            dt = lane.deadline[t - 1]
            if d > dt or (d == dt
                          and req.arrival_time >= lane.arrival[t - 1]):
                lane.push(seq, req)      # in-order: the overwhelming case
            else:
                # an old arrival re-entering (failure displacement, fleet
                # hand-back): it must sort before the lane tail, so it
                # takes the per-model overflow heap instead
                heapq.heappush(self._boflow[model],
                               (d, req.arrival_time, seq, req))
        self._bfresh[model] += 1
        self._bcount += 1
        if self._listeners:
            self._notify_add(req)

    def requeue(self, req: Request) -> None:
        """Preempted request returns to the queue.

        Zero-queuing discipline (footnote 3): a preempted interactive
        request goes to the *front* of its model's line — it already
        waited once and must not re-queue behind later arrivals. Batch
        requests with host-saved KV enter the model's resume lane (served
        first, the restart skips re-prefill); otherwise they re-enter at
        their original (deadline, arrival) position.
        """
        if req.request_type == RequestType.INTERACTIVE:
            lane = self._ilanes.get(req.model)
            if lane is None:
                lane = self._ilanes[req.model] = _Lane(req.model)
            s = self._ifront
            self._ifront = s - 1
            lane.push_front(s, req)
            self._icount += 1
        elif req.saved_kv is not None:
            lane = self._bresumes.get(req.model)
            if lane is None:
                lane = self._bresumes[req.model] = _Lane(req.model)
            s = self._bseq
            self._bseq = s + 1
            lane.push(s, req)
            self._bcount += 1
            self._notify_add(req)
        else:
            self.push(req)

    # ------------------------------------------------- interactive serving
    def interactive_models(self) -> List[str]:
        """Models with queued interactive work (lane insertion order)."""
        return [m for m, lane in self._ilanes.items()
                if lane.tail > lane.head]

    def n_interactive_for(self, model: str) -> int:
        lane = self._ilanes.get(model)
        return lane.tail - lane.head if lane is not None else 0

    def peek_interactive(self, model: Optional[str] = None) -> Optional[Request]:
        lane = self._pick_ilane(model)
        return lane.req_objs[lane.head] if lane is not None else None

    def pop_interactive(self, model: Optional[str] = None) -> Optional[Request]:
        lane = self._pick_ilane(model)
        if lane is None:
            return None
        self._icount -= 1
        return lane.popleft()

    def _pick_ilane(self, model: Optional[str]) -> Optional[_Lane]:
        lanes = self._ilanes
        if model is not None:
            lane = lanes.get(model)
            return lane if lane is not None and lane.tail > lane.head \
                else None
        if len(lanes) == 1:              # single-model fast path: no scan
            lane = next(iter(lanes.values()))
            return lane if lane.tail > lane.head else None
        best = None
        best_seq = 0
        for lane in lanes.values():      # few models: O(M) head compare
            if lane.tail > lane.head:
                s = lane.seq[lane.head]
                if best is None or s < best_seq:
                    best, best_seq = lane, s
        return best

    # ------------------------------------------------- overload sweeping
    def sweep_interactive(self, now: float, *, grace: float = 0.0,
                          wait_by_model: Optional[Dict[str, float]] = None
                          ) -> Tuple[List[Request], List[Request]]:
        """Vectorized overload sweep over the interactive lanes; batch
        lanes are never touched (defer, don't drop).

        Returns ``(expired, shed)``: entries whose deadline (+``grace``)
        already passed are removed as EXPIRED candidates; when
        ``wait_by_model`` gives a per-queued-request service delay
        (brownout mode), entries whose estimated service start
        ``now + position * delay`` would still miss the deadline are
        removed as SHED candidates. Interactive lane deadlines are *not*
        monotone (several SLO classes share one per-model FIFO, and
        front-requeues take negative stamps), so this is a masked sweep
        over the deadline column, not a bisect. The caller owns the
        terminal state / ledger / retry bookkeeping for what comes back.
        """
        expired: List[Request] = []
        shed: List[Request] = []
        for lane in self._ilanes.values():
            h, t = lane.head, lane.tail
            if t <= h:
                continue
            dl = lane.deadline[h:t]
            gone = dl + grace < now
            doomed = None
            if wait_by_model is not None:
                w = wait_by_model.get(lane.model, 0.0)
                if w > 0.0:
                    start = now + np.arange(t - h, dtype=np.float64) * w
                    doomed = (start > dl + grace) & ~gone
                    if not doomed.any():
                        doomed = None
            if doomed is None and not gone.any():
                continue
            gidx = np.nonzero(gone)[0]
            expired.extend(lane.req_objs[h + int(i)] for i in gidx)
            if doomed is not None:
                shed.extend(lane.req_objs[h + int(i)]
                            for i in np.nonzero(doomed)[0])
                keep = ~(gone | doomed)
            else:
                keep = ~gone
            self._compact_ilane(lane, keep)
        return expired, shed

    def _compact_ilane(self, lane: _Lane, keep: np.ndarray) -> None:
        """Drop the masked-out entries, preserving order (and the key
        column / payload mirror) for the survivors."""
        h = lane.head
        dropped = int(keep.size) - int(np.count_nonzero(keep))
        kidx = np.nonzero(keep)[0] + h
        k = int(kidx.size)
        lane.seq[h:h + k] = lane.seq[kidx]
        lane.arrival[h:h + k] = lane.arrival[kidx]
        lane.deadline[h:h + k] = lane.deadline[kidx]
        lane.row[h:h + k] = lane.row[kidx]
        lane.req_objs[h:h + k] = [lane.req_objs[int(i)] for i in kidx]
        for i in range(h + k, lane.tail):
            # mirror-sync: ok(freed payload cells; their key cells are dead)
            lane.req_objs[i] = None
        if k == 0:
            lane.head = lane.tail = 0
        else:
            lane.tail = h + k
        self._icount -= dropped

    # ------------------------------------------------------ batch serving
    def batch_models(self) -> List[str]:
        """Models with queued batch work (lane insertion order)."""
        out = [m for m, n in self._bfresh.items() if n]
        out.extend(m for m, lane in self._bresumes.items()
                   if lane.tail > lane.head and m not in out)
        return out

    def n_batch_for(self, model: str) -> int:
        res = self._bresumes.get(model)
        return self._bfresh.get(model, 0) + \
            (res.tail - res.head if res is not None else 0)

    def peek_batch(self, model: Optional[str] = None) -> Optional[Request]:
        lane, kind = self._pick_blane(model)
        if lane is None:
            return None
        return lane[0][3] if kind == "heap" else lane.req_objs[lane.head]

    def pop_batch_fcfs(self, model: Optional[str] = None) -> Optional[Request]:
        """Earliest deadline first, then arrival order (FCFS within a
        group, §5.3); preempted requests with saved KV resume first."""
        lane, kind = self._pick_blane(model)
        if lane is None:
            return None
        if kind == "heap":
            req = heapq.heappop(lane)[3]
            self._bfresh[req.model] -= 1
        else:
            req = lane.popleft()
            if kind == "lane":
                self._bfresh[req.model] -= 1
        self._bcount -= 1
        if self._listeners:
            self._notify_remove(req)
        return req

    def _pick_blane(self, model: Optional[str]):
        """The source the next batch pop serves: a resume lane (kind
        ``"resume"``), an SLO-class lane (``"lane"``), or the overflow
        heap (``"heap"``) — the min head across candidates."""
        if model is not None:
            res = self._bresumes.get(model)
            if res is not None and res.tail > res.head:
                return res, "resume"
            if not self._bfresh.get(model, 0):
                return None, None
            return self._min_fresh(self._blanes[model],
                                   self._boflow[model])
        for res in self._bresumes.values():      # any resume lane first
            if res.tail > res.head:
                return res, "resume"
        best = best_key = None
        best_kind = None
        for m, n in self._bfresh.items():        # min head across models
            if not n:
                continue
            lane, kind = self._min_fresh(self._blanes[m], self._boflow[m])
            key = lane[0] if kind == "heap" else \
                (lane.deadline[lane.head], lane.arrival[lane.head],
                 lane.seq[lane.head])
            # seq (slot 2) is globally unique, so the comparison always
            # resolves before reaching a heap entry's Request element
            if best_key is None or key < best_key:
                best, best_key, best_kind = lane, key, kind
        return (best, best_kind) if best is not None else (None, None)

    @staticmethod
    def _min_fresh(lanes: Dict[float, _Lane], oflow: list):
        """Min head among one model's SLO-class lanes and overflow heap
        (caller guarantees at least one entry exists)."""
        best = best_key = None
        for lane in lanes.values():
            h = lane.head
            if h == lane.tail:
                continue
            key = (lane.deadline[h], lane.arrival[h], lane.seq[h])
            if best_key is None or key < best_key:
                best, best_key = lane, key
        if oflow and (best_key is None or oflow[0] < best_key):
            return oflow, "heap"
        return best, "lane"

    def _batch_sorted(self, model: str) -> List[Request]:
        """One model's fresh batch entries in service order — a
        vectorized ``np.lexsort`` merge of its SLO-class lanes and
        overflow heap (deadline, then arrival, then seq)."""
        lanes = self._blanes.get(model)
        if lanes is None:
            return []
        seqs, arrs, dls, objs = [], [], [], []
        for lane in lanes.values():
            s, a, d, o = lane.key_slices()
            if len(o):
                seqs.append(s)
                arrs.append(a)
                dls.append(d)
                objs.extend(o)
        for d, a, s, req in self._boflow.get(model, ()):
            seqs.append(np.array([s], dtype=np.int64))
            arrs.append(np.array([a]))
            dls.append(np.array([d]))
            objs.append(req)
        if not objs:
            return []
        order = np.lexsort((np.concatenate(seqs), np.concatenate(arrs),
                            np.concatenate(dls)))
        return [objs[i] for i in order.tolist()]

    def drain_model(self, model: str) -> List[Request]:
        """Remove and return every queued request for ``model`` — its
        interactive lane, batch lanes, and resume lane — preserving
        service order within each class (interactive first). The fleet
        plane uses this for migration hand-back: a cluster losing a
        model's placement surrenders that model's queued work for
        re-routing."""
        out: List[Request] = []
        lane = self._ilanes.pop(model, None)
        if lane is not None:
            live = lane.req_objs[lane.head:lane.tail]
            out.extend(live)
            self._icount -= len(live)
        res = self._bresumes.pop(model, None)
        if res is not None:
            for r in res.req_objs[res.head:res.tail]:
                out.append(r)
                self._bcount -= 1
                self._notify_remove(r)
        ordered = self._batch_sorted(model)      # deadline/FCFS order
        self._blanes.pop(model, None)
        self._boflow.pop(model, None)
        self._bfresh.pop(model, None)
        for r in ordered:
            out.append(r)
            self._bcount -= 1
            self._notify_remove(r)
        return out

    def iter_batch(self, model: Optional[str] = None) -> Iterator[Request]:
        """Queued batch requests in unspecified order (O(n))."""
        models = (model,) if model is not None else \
            dict.fromkeys(itertools.chain(self._blanes, self._bresumes))
        for m in models:
            res = self._bresumes.get(m)
            if res is not None:
                yield from res.req_objs[res.head:res.tail]
            for lane in self._blanes.get(m, {}).values():
                yield from lane.req_objs[lane.head:lane.tail]
            for entry in self._boflow.get(m, ()):
                yield entry[3]

    # ------------------------------------------------ legacy flat views
    @property
    def interactive(self) -> List[Request]:
        """Snapshot of queued interactive requests in global FIFO order.

        Vectorized debug/compat view (argsort over the concatenated seq
        columns) — the routing hot path uses ``peek_interactive`` /
        ``pop_interactive`` instead.
        """
        seqs, objs = [], []
        for lane in self._ilanes.values():
            s, _, _, o = lane.key_slices()
            if len(o):
                seqs.append(s)
                objs.extend(o)
        if not objs:
            return []
        order = np.argsort(np.concatenate(seqs), kind="stable")
        return [objs[i] for i in order.tolist()]

    @property
    def batch(self) -> List[Request]:
        """Snapshot of queued batch requests, resume lanes first, then
        earliest deadline first (vectorized lexsort merge)."""
        out: List[Request] = []
        for res in self._bresumes.values():
            out.extend(res.req_objs[res.head:res.tail])
        for m in self._blanes:
            out.extend(self._batch_sorted(m))
        return out

    # ------------------------------------------------------------ listeners
    def attach_batch_listener(self, listener,
                              model: Optional[str] = None) -> None:
        """Register an ``on_add(req)`` / ``on_remove(req)`` observer of
        the batch side — all models, or one model's lanes when ``model``
        is given; current (matching) contents are replayed as adds in
        service order (resume lanes first, then earliest deadline)."""
        self._listeners.append((listener, model))
        for req in self._replay_order(model):
            listener.on_add(req)

    def _replay_order(self, model: Optional[str]) -> List[Request]:
        models = (model,) if model is not None else \
            dict.fromkeys(itertools.chain(self._blanes, self._bresumes))
        out: List[Request] = []
        for m in models:
            res = self._bresumes.get(m)
            if res is not None:
                out.extend(res.req_objs[res.head:res.tail])
            out.extend(self._batch_sorted(m))
        return out

    def _notify_add(self, req: Request) -> None:
        for listener, model in self._listeners:
            if model is None or req.model == model:
                listener.on_add(req)

    def _notify_remove(self, req: Request) -> None:
        for listener, model in self._listeners:
            if model is None or req.model == model:
                listener.on_remove(req)

    # --------------------------------------------------------- audit hooks
    def audit_lanes(self):
        """Yield ``(kind, model, lane)`` for every columnar lane — the
        shadow verifier's rebuild surface (kinds: ``interactive``,
        ``batch``, ``resume``)."""
        for m, lane in self._ilanes.items():
            yield "interactive", m, lane
        for m, lanes in self._blanes.items():
            for lane in lanes.values():
                yield "batch", m, lane
        for m, lane in self._bresumes.items():
            yield "resume", m, lane

    def audit_counts(self) -> Tuple[int, int]:
        """Recount (interactive, batch) entries from the lanes (the
        shadow verifier checks them against ``_icount``/``_bcount``)."""
        n_i = sum(lane.tail - lane.head for lane in self._ilanes.values())
        n_b = sum(lane.tail - lane.head
                  for lanes in self._blanes.values()
                  for lane in lanes.values())
        n_b += sum(len(h) for h in self._boflow.values())
        n_b += sum(lane.tail - lane.head
                   for lane in self._bresumes.values())
        return n_i, n_b

    # ------------------------------------------------------------ sizes
    @property
    def n_interactive(self) -> int:
        return self._icount

    @property
    def n_batch(self) -> int:
        return self._bcount

    def __len__(self) -> int:
        return self._icount + self._bcount


class ReferenceGlobalQueue:
    """Pre-columnar object flavour: per-model deques of ``(seq, Request)``
    and ``(deadline, arrival, seq, Request)`` binary heaps. Kept as the
    decision-equivalence baseline (``reference=True``) — pop order is
    bit-identical to :class:`GlobalQueue`."""

    columnar = False

    def __init__(self):
        self._ilanes: Dict[str, Deque[Tuple[int, Request]]] = {}
        self._iseq = itertools.count()
        self._ifront = itertools.count(-1, -1)
        self._icount = 0
        # model -> (deadline, arrival_time, seq, request) heap — earliest
        # deadline first, FCFS within a deadline (§5.3), seq breaks ties
        self._bheaps: Dict[str, List[Tuple[float, float, int, Request]]] = {}
        self._bresumes: Dict[str, Deque[Request]] = {}   # preempted, KV host
        self._bseq = itertools.count()
        self._bcount = 0
        self._listeners: List[Tuple[object, Optional[str]]] = []

    # ------------------------------------------------------------ intake
    def push(self, req: Request) -> None:
        if req.request_type == RequestType.INTERACTIVE:
            lane = self._ilanes.get(req.model)
            if lane is None:
                lane = self._ilanes[req.model] = deque()
            lane.append((next(self._iseq), req))
            self._icount += 1
        else:
            h = self._bheaps.get(req.model)
            if h is None:
                h = self._bheaps[req.model] = []
            heapq.heappush(h, (req.deadline, req.arrival_time,
                               next(self._bseq), req))
            self._bcount += 1
            if self._listeners:
                self._notify_add(req)

    def requeue(self, req: Request) -> None:
        """See :meth:`GlobalQueue.requeue` (identical discipline)."""
        if req.request_type == RequestType.INTERACTIVE:
            self._ilanes.setdefault(req.model, deque()).appendleft(
                (next(self._ifront), req))
            self._icount += 1
        elif req.saved_kv is not None:
            self._bresumes.setdefault(req.model, deque()).append(req)
            self._bcount += 1
            self._notify_add(req)
        else:
            self.push(req)

    # ------------------------------------------------- interactive serving
    def interactive_models(self) -> List[str]:
        return [m for m, d in self._ilanes.items() if d]

    def n_interactive_for(self, model: str) -> int:
        lane = self._ilanes.get(model)
        return len(lane) if lane else 0

    def peek_interactive(self, model: Optional[str] = None) -> Optional[Request]:
        lane = self._pick_ilane(model)
        return lane[0][1] if lane else None

    def pop_interactive(self, model: Optional[str] = None) -> Optional[Request]:
        lane = self._pick_ilane(model)
        if not lane:
            return None
        self._icount -= 1
        return lane.popleft()[1]

    def _pick_ilane(self, model: Optional[str]) -> Optional[Deque]:
        lanes = self._ilanes
        if model is not None:
            lane = lanes.get(model)
            return lane if lane else None
        if len(lanes) == 1:              # single-model fast path: no scan
            lane = next(iter(lanes.values()))
            return lane if lane else None
        best = None
        for lane in lanes.values():      # few models: O(M) scan
            if lane and (best is None or lane[0][0] < best[0][0]):
                best = lane
        return best

    # ------------------------------------------------------ batch serving
    def batch_models(self) -> List[str]:
        out = [m for m, h in self._bheaps.items() if h]
        out.extend(m for m, d in self._bresumes.items()
                   if d and m not in out)
        return out

    def n_batch_for(self, model: str) -> int:
        return len(self._bheaps.get(model, ())) + \
            len(self._bresumes.get(model, ()))

    def peek_batch(self, model: Optional[str] = None) -> Optional[Request]:
        lane, kind = self._pick_blane(model)
        if lane is None:
            return None
        return lane[0] if kind == "resume" else lane[0][3]

    def pop_batch_fcfs(self, model: Optional[str] = None) -> Optional[Request]:
        lane, kind = self._pick_blane(model)
        if lane is None:
            return None
        req = lane.popleft() if kind == "resume" else heapq.heappop(lane)[3]
        self._bcount -= 1
        if self._listeners:
            self._notify_remove(req)
        return req

    def _pick_blane(self, model: Optional[str]):
        """The lane the next batch pop serves: a resume deque or a heap."""
        if model is not None:
            res = self._bresumes.get(model)
            if res:
                return res, "resume"
            h = self._bheaps.get(model)
            return (h, "heap") if h else (None, None)
        if self._bresumes:
            for res in self._bresumes.values():  # any resume lane first
                if res:
                    return res, "resume"
        best = None
        for h in self._bheaps.values():         # min head across models
            # seq (slot 2) is globally unique, so the head comparison
            # always resolves before reaching the Request element
            if h and (best is None or h[0] < best[0]):
                best = h
        return (best, "heap") if best is not None else (None, None)

    def drain_model(self, model: str) -> List[Request]:
        """See :meth:`GlobalQueue.drain_model` (identical order)."""
        out: List[Request] = []
        lane = self._ilanes.pop(model, None)
        if lane:
            out.extend(r for _, r in lane)
            self._icount -= len(lane)
        res = self._bresumes.pop(model, None)
        if res:
            for r in res:
                out.append(r)
                self._bcount -= 1
                self._notify_remove(r)
        heap = self._bheaps.pop(model, None)
        if heap:
            heap.sort()                      # deadline/FCFS service order
            for entry in heap:
                out.append(entry[3])
                self._bcount -= 1
                self._notify_remove(entry[3])
        return out

    def iter_batch(self, model: Optional[str] = None) -> Iterator[Request]:
        """Queued batch requests in unspecified order (O(n))."""
        models = (model,) if model is not None else \
            dict.fromkeys(itertools.chain(self._bheaps, self._bresumes))
        for m in models:
            yield from self._bresumes.get(m, ())
            for entry in self._bheaps.get(m, ()):
                yield entry[3]

    # ------------------------------------------------ legacy flat views
    @property
    def interactive(self) -> List[Request]:
        entries: List[Tuple[int, Request]] = []
        for lane in self._ilanes.values():
            entries.extend(lane)
        entries.sort(key=lambda e: e[0])
        return [r for _, r in entries]

    @property
    def batch(self) -> List[Request]:
        out: List[Request] = []
        for res in self._bresumes.values():
            out.extend(res)
        entries: List[Tuple[float, float, int, Request]] = []
        for h in self._bheaps.values():
            entries.extend(h)
        entries.sort()
        out.extend(e[3] for e in entries)
        return out

    # ------------------------------------------------------------ listeners
    def attach_batch_listener(self, listener,
                              model: Optional[str] = None) -> None:
        """See :meth:`GlobalQueue.attach_batch_listener` — the replay
        runs in the same canonical service order (resume lanes first,
        then sorted heap entries) so both flavours feed listeners an
        identical stream."""
        self._listeners.append((listener, model))
        models = (model,) if model is not None else \
            dict.fromkeys(itertools.chain(self._bheaps, self._bresumes))
        for m in models:
            for req in self._bresumes.get(m, ()):
                listener.on_add(req)
            for entry in sorted(self._bheaps.get(m, ())):
                listener.on_add(entry[3])

    def _notify_add(self, req: Request) -> None:
        for listener, model in self._listeners:
            if model is None or req.model == model:
                listener.on_add(req)

    def _notify_remove(self, req: Request) -> None:
        for listener, model in self._listeners:
            if model is None or req.model == model:
                listener.on_remove(req)

    # ------------------------------------------------------------ sizes
    @property
    def n_interactive(self) -> int:
        return self._icount

    @property
    def n_batch(self) -> int:
        return self._bcount

    def __len__(self) -> int:
        return self._icount + self._bcount


def make_queue(reference: bool = False):
    """The engines' queue factory: the columnar plane by default, the
    object flavour under ``reference=True``."""
    return ReferenceGlobalQueue() if reference else GlobalQueue()
