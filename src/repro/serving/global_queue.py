"""Global queue (paper §3, Lifecycle of a Request).

All requests enqueue here; interactive requests follow a zero-queuing
discipline (dispatched immediately, footnote 3) while batch requests may
wait and are scheduled as request groups by the global autoscaler.

The batch side is a binary heap keyed on ``(deadline, arrival_time, seq)``
so every pop is O(log n) — draining n requests costs O(n log n) total
instead of the O(n^2 log n) a sort-per-pop policy degrades to at the
cluster scales the paper evaluates (thousands of queued requests).
Preempted batch requests that still hold host-saved KV are parked in a
separate resume lane served before fresh work, so a restart never
re-queues behind requests that have not prefill'd yet.

Listeners (``attach_batch_listener``) observe every batch add/remove and
let the global autoscaler maintain request groups incrementally instead of
re-clustering the whole queue each control tick.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Deque, Iterator, List, Optional, Tuple

from repro.serving.request import Request, RequestType


class GlobalQueue:
    def __init__(self):
        self.interactive: Deque[Request] = deque()
        # (deadline, arrival_time, seq, request) — earliest deadline first,
        # FCFS within a deadline (§5.3), seq breaks exact ties stably.
        self._batch_heap: List[Tuple[float, float, int, Request]] = []
        self._resume: Deque[Request] = deque()   # preempted, KV on host
        self._seq = itertools.count()
        self._listeners: List[object] = []

    # ------------------------------------------------------------ intake
    def push(self, req: Request) -> None:
        if req.request_type == RequestType.INTERACTIVE:
            self.interactive.append(req)
        else:
            heapq.heappush(self._batch_heap,
                           (req.deadline, req.arrival_time,
                            next(self._seq), req))
            self._notify_add(req)

    def requeue(self, req: Request) -> None:
        """Preempted request returns to the queue.

        Zero-queuing discipline (footnote 3): a preempted interactive
        request goes to the *front* of the interactive line — it already
        waited once and must not re-queue behind later arrivals. Batch
        requests with host-saved KV enter the resume lane (served first,
        the restart skips re-prefill); otherwise they re-enter the heap at
        their original (deadline, arrival) position.
        """
        if req.request_type == RequestType.INTERACTIVE:
            self.interactive.appendleft(req)
        elif req.saved_kv is not None:
            self._resume.append(req)
            self._notify_add(req)
        else:
            self.push(req)

    # ------------------------------------------------------------ serving
    def pop_interactive(self) -> Optional[Request]:
        return self.interactive.popleft() if self.interactive else None

    def peek_batch(self) -> Optional[Request]:
        if self._resume:
            return self._resume[0]
        return self._batch_heap[0][3] if self._batch_heap else None

    def pop_batch_fcfs(self) -> Optional[Request]:
        """Earliest deadline first, then arrival order (FCFS within a
        group, §5.3); preempted requests with saved KV resume first."""
        if self._resume:
            req = self._resume.popleft()
        elif self._batch_heap:
            req = heapq.heappop(self._batch_heap)[3]
        else:
            return None
        self._notify_remove(req)
        return req

    def iter_batch(self) -> Iterator[Request]:
        """All queued batch requests in unspecified order (O(n))."""
        yield from self._resume
        for entry in self._batch_heap:
            yield entry[3]

    @property
    def batch(self) -> List[Request]:
        """Snapshot of queued batch requests, earliest deadline first.

        O(n log n) — for control-loop consumers prefer passing the queue
        itself (incremental grouping) or ``iter_batch`` over this.
        """
        out = sorted(self._batch_heap)
        return list(self._resume) + [e[3] for e in out]

    # ------------------------------------------------------------ listeners
    def attach_batch_listener(self, listener) -> None:
        """Register an ``on_add(req)`` / ``on_remove(req)`` observer of the
        batch side; current contents are replayed as adds on attach."""
        self._listeners.append(listener)
        for req in self.iter_batch():
            listener.on_add(req)

    def _notify_add(self, req: Request) -> None:
        for l in self._listeners:
            l.on_add(req)

    def _notify_remove(self, req: Request) -> None:
        for l in self._listeners:
            l.on_remove(req)

    # ------------------------------------------------------------ sizes
    @property
    def n_interactive(self) -> int:
        return len(self.interactive)

    @property
    def n_batch(self) -> int:
        return len(self._batch_heap) + len(self._resume)

    def __len__(self) -> int:
        return self.n_interactive + self.n_batch
