"""Global queue (paper §3, Lifecycle of a Request).

All requests enqueue here; interactive requests follow a zero-queuing
discipline (dispatched immediately, footnote 3) while batch requests may
wait and are scheduled as request groups by the global autoscaler.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional

from repro.serving.request import Request, RequestType


class GlobalQueue:
    def __init__(self):
        self.interactive: Deque[Request] = deque()
        self.batch: List[Request] = []

    def push(self, req: Request) -> None:
        if req.request_type == RequestType.INTERACTIVE:
            self.interactive.append(req)
        else:
            self.batch.append(req)

    def pop_interactive(self) -> Optional[Request]:
        return self.interactive.popleft() if self.interactive else None

    def pop_batch_fcfs(self) -> Optional[Request]:
        """FCFS by (group deadline, arrival) — groups are recomputed by the
        controller; within the queue we serve earliest deadline first, then
        arrival order (FCFS within a group, §5.3)."""
        if not self.batch:
            return None
        self.batch.sort(key=lambda r: (r.deadline, r.arrival_time))
        return self.batch.pop(0)

    def requeue(self, req: Request) -> None:
        """Preempted request returns to the queue (keeps saved KV)."""
        self.push(req)

    @property
    def n_interactive(self) -> int:
        return len(self.interactive)

    @property
    def n_batch(self) -> int:
        return len(self.batch)

    def __len__(self) -> int:
        return self.n_interactive + self.n_batch
