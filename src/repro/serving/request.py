"""Request model: SLO classes, lifecycle states, timing bookkeeping."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional

_req_counter = itertools.count()


def request_id_counter():
    """The shared ``req_id`` source — bulk constructors (columnar
    ``Trace.materialize``) draw from the same counter the dataclass
    default does, so ids stay globally unique either way."""
    return _req_counter


class RequestType(enum.Enum):
    INTERACTIVE = "interactive"
    BATCH = "batch"


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"     # evicted from a mixed instance, KV on host
    FINISHED = "finished"
    # overload-plane terminal states (never served):
    REJECTED = "rejected"       # refused at admission (infeasible TTFT)
    SHED = "shed"               # proactively dropped under brownout
    EXPIRED = "expired"         # deadline passed while still queued


# States a request can never leave (the accounting identity
# finished + rejected + shed + expired == n holds over completed runs)
TERMINAL_STATES = (RequestState.FINISHED, RequestState.REJECTED,
                   RequestState.SHED, RequestState.EXPIRED)


# The paper's production-derived SLO defaults (§6 Workloads)
INTERACTIVE_TTFT_SLO = 10.0     # seconds
INTERACTIVE_ITL_SLO = 0.2       # seconds/token
BATCH_TTFT_SLO = 3600.0         # one hour
BATCH_ITL_SLO = 2.0             # seconds/token


@dataclass
class SLO:
    ttft: float
    itl: float

    @classmethod
    def interactive(cls) -> "SLO":
        return cls(INTERACTIVE_TTFT_SLO, INTERACTIVE_ITL_SLO)

    @classmethod
    def batch(cls) -> "SLO":
        return cls(BATCH_TTFT_SLO, BATCH_ITL_SLO)


@dataclass
class Request:
    prompt_len: int
    output_len: int                 # ground truth; schedulers must not read
    request_type: RequestType
    slo: SLO
    arrival_time: float = 0.0
    req_id: int = field(default_factory=lambda: next(_req_counter))
    model: str = "llama-8b"
    # originating region (multi-cluster fleets): the router measures
    # network latency / egress from here; None = single-region workload
    origin: Optional[str] = None
    # paying tenant (per-tenant attainment rollups); None = single-tenant
    tenant: Optional[str] = None

    # lifecycle
    state: RequestState = RequestState.QUEUED
    tokens_generated: int = 0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    itl_samples: List[float] = field(default_factory=list)
    preemptions: int = 0
    # client retry attempts consumed so far (overload plane): incremented
    # when a rejected/shed request re-arrives with backoff; mirrored into
    # the ledger ``retries`` column
    retries: int = 0
    # per-attempt deadline re-arm (overload plane): a retry re-arrival at
    # ``tr`` sets this to ``tr + slo.ttft`` so the queue's deadline sweep
    # gives each attempt its own SLO window. ``arrival_time`` stays the
    # *first* submission — SLO attainment and goodput remain end-to-end.
    deadline_at: Optional[float] = None
    # host-offloaded KV (real engine: actual arrays; sim: token count)
    saved_kv: Optional[object] = None
    # optional explicit prompt token ids (enables prefix caching; the
    # engine synthesizes random tokens when absent)
    prompt_tokens: Optional[object] = None
    # columnar ledger row id (repro.sim.ledger.RequestLedger): the event
    # core records this request's outcomes by integer row instead of — in
    # addition to — mutating the object; -1 = not tracked by a ledger
    row: int = -1

    @property
    def deadline(self) -> float:
        """TTFT-SLO-based deadline for first token (re-armed per client
        retry attempt — see ``deadline_at``)."""
        if self.deadline_at is not None:
            return self.deadline_at
        return self.arrival_time + self.slo.ttft

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def is_interactive(self) -> bool:
        return self.request_type == RequestType.INTERACTIVE

    def ttft_met(self) -> bool:
        return self.ttft is not None and self.ttft <= self.slo.ttft

    def itl_met(self, tolerance: float = 1.0) -> bool:
        """ITL SLO attainment: mean observed ITL within the SLO."""
        if not self.itl_samples:
            return True
        mean_itl = sum(self.itl_samples) / len(self.itl_samples)
        return mean_itl <= self.slo.itl * tolerance

    def slo_met(self) -> bool:
        return self.state == RequestState.FINISHED and self.ttft_met() and self.itl_met()


def make_interactive(prompt_len: int, output_len: int, arrival: float = 0.0,
                     model: str = "llama-8b") -> Request:
    return Request(prompt_len, output_len, RequestType.INTERACTIVE,
                   SLO.interactive(), arrival, model=model)


def make_batch(prompt_len: int, output_len: int, arrival: float = 0.0,
               model: str = "llama-8b", ttft_slo: float = BATCH_TTFT_SLO) -> Request:
    return Request(prompt_len, output_len, RequestType.BATCH,
                   SLO(ttft_slo, BATCH_ITL_SLO), arrival, model=model)
