"""Continuous-batching engine with real JAX execution.

This is the data plane of a serving instance: slot-based KV/state pool,
iteration-level scheduling (admit -> decode-one-token -> retire), preemption
of batch requests with host KV offload (Chiron's mixed-instance eviction),
and the ITL / throughput measurements the local autoscaler closes its loop
on. The max batch size is the knob Algorithm 1 turns.

The engine serves any architecture behind the unified ``Model`` API —
dense, MoE, SSM, hybrid, enc-dec, VLM — because caches are written/read
through the generic slot-pool protocol below.
"""
from __future__ import annotations

# mirror-sync: module ok(real engine has no RequestLedger/InstancePlane)
# The columnar mirrors exist only in the simulated data plane.
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.serving.request import Request, RequestState, RequestType

_SCALAR_KEYS = ("pos",)
_ROW_KEYS = ("slot_pos",)


@dataclass
class StepStats:
    now: float
    n_active: int
    new_tokens: int
    finished: List[Request] = field(default_factory=list)
    itl: float = 0.0                 # seconds for this decode iteration
    throughput: float = 0.0          # tokens/s over the sliding window
    preempted: List[Request] = field(default_factory=list)


@dataclass
class _Slot:
    request: Optional[Request] = None
    token: Optional[jax.Array] = None   # next input token (1,)

    @property
    def active(self) -> bool:
        return self.request is not None


class Engine:
    def __init__(self, cfg: ModelConfig, *, key=None, params=None,
                 max_slots: int = 8, max_len: int = 256,
                 max_batch_size: Optional[int] = None,
                 clock=time.monotonic, dtype=jnp.float32,
                 prefix_cache_entries: int = 0,
                 prefill_chunk: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.dtype = dtype
        key = key if key is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else self.model.init(key, dtype=dtype)
        self.max_slots = max_slots
        self.max_len = max_len
        self.max_batch_size = max_batch_size or max_slots
        self.clock = clock
        # serving-optimization knobs (transformer family only; paper Fig.11)
        chunkable = cfg.arch_type in ("dense", "moe")
        self.prefill_chunk = prefill_chunk if chunkable else 0
        self.prefix_cache = None
        if prefix_cache_entries > 0 and chunkable:
            from repro.serving.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(prefix_cache_entries)
        self.pool = self.model.init_cache(max_slots, max_len, dtype=dtype)
        self.slots: List[_Slot] = [_Slot() for _ in range(max_slots)]
        self.waiting: Deque[Request] = deque()
        self._decode = jax.jit(self.model.decode_step)
        self._last_step_t: Optional[float] = None
        self._window: Deque = deque(maxlen=32)   # (t, tokens) samples
        self._rng = np.random.default_rng(0)

    # ------------------------------------------------------------ metrics
    @property
    def n_active(self) -> int:
        return sum(s.active for s in self.slots)

    @property
    def n_waiting(self) -> int:
        return len(self.waiting)

    def utilization(self) -> float:
        return self.n_active / max(self.max_batch_size, 1)

    def running_types(self) -> List[RequestType]:
        return [s.request.request_type for s in self.slots if s.active]

    def throughput(self) -> float:
        if len(self._window) < 2:
            return 0.0
        dt = self._window[-1][0] - self._window[0][0]
        toks = sum(t for _, t in list(self._window)[1:])
        return toks / dt if dt > 0 else 0.0

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        req.state = RequestState.QUEUED
        self.waiting.append(req)

    def set_max_batch_size(self, b: int) -> None:
        self.max_batch_size = max(1, min(int(b), self.max_slots))

    # --------------------------------------------------------- slot cache
    def _write_slot(self, slot: int, sub: Dict[str, jax.Array]) -> None:
        """Write a batch-of-1 cache pytree into the pool at ``slot``."""
        for k, v in sub.items():
            if k in _SCALAR_KEYS:
                self.pool[k] = self.pool[k].at[slot].set(v[0])
            elif k in _ROW_KEYS:
                S = v.shape[1]
                row = jnp.full((self.max_len,), -1, v.dtype).at[:S].set(v[0])
                self.pool[k] = self.pool[k].at[slot].set(row)
            else:
                pool = self.pool[k]
                if v.ndim >= 3 and v.shape[2] != pool.shape[2]:
                    S = v.shape[2]
                    self.pool[k] = pool.at[:, slot, :S].set(v[:, 0])
                else:
                    self.pool[k] = pool.at[:, slot].set(v[:, 0])

    def _read_slot(self, slot: int) -> Dict[str, np.ndarray]:
        out = {}
        for k, v in self.pool.items():
            if k in _SCALAR_KEYS:
                out[k] = np.asarray(v[slot:slot + 1])
            elif k in _ROW_KEYS:
                out[k] = np.asarray(v[slot:slot + 1])
            else:
                out[k] = np.asarray(v[:, slot:slot + 1])
        return out

    def _restore_slot(self, slot: int, saved: Dict[str, np.ndarray]) -> None:
        for k, v in saved.items():
            arr = jnp.asarray(v)
            if k in _SCALAR_KEYS or k in _ROW_KEYS:
                self.pool[k] = self.pool[k].at[slot].set(arr[0])
            else:
                self.pool[k] = self.pool[k].at[:, slot].set(arr[:, 0])

    # ------------------------------------------------------------ admit
    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if not s.active:
                return i
        return None

    def _prompt_tokens(self, req: Request) -> np.ndarray:
        if req.prompt_tokens is not None:
            return np.asarray(req.prompt_tokens, np.int32).reshape(-1)
        return self._rng.integers(0, self.cfg.vocab_size,
                                  size=(req.prompt_len,), dtype=np.int32)

    def _prompt_batch(self, req: Request, toks: Optional[np.ndarray] = None):
        toks = toks if toks is not None else self._prompt_tokens(req)
        batch = {"tokens": jnp.asarray(toks)[None]}
        if self.cfg.arch_type == "audio":
            batch["frames"] = jnp.zeros((1, self.cfg.enc_seq, self.cfg.d_model),
                                        self.dtype)
        if self.cfg.arch_type == "vlm":
            batch["vision"] = jnp.zeros((1, self.cfg.n_vision_tokens,
                                         self.cfg.d_model), self.dtype)
        return batch

    def _prefill(self, req: Request):
        """Prefill a prompt, via the prefix cache and/or in chunks when
        those knobs are enabled; returns (last_logits, cache)."""
        toks = self._prompt_tokens(req)
        past = None
        if self.prefix_cache is not None:
            past, consumed = self.prefix_cache.lookup(toks)
            remaining = toks[consumed:]
        else:
            remaining = toks
        chunk = self.prefill_chunk or len(remaining)
        logits = None
        for lo in range(0, len(remaining), chunk):
            piece = remaining[lo:lo + chunk]
            logits, past = self.model.prefill(
                self.params, self._prompt_batch(req, piece),
                dtype=self.dtype, past_cache=past)
        if self.prefix_cache is not None:
            self.prefix_cache.store(toks, past)
        return logits, past

    def _admit(self, req: Request, now: float) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        if req.saved_kv is not None:
            self._restore_slot(slot, req.saved_kv)
            req.saved_kv = None
            tok = jnp.zeros((1,), jnp.int32)
        else:
            logits, cache = self._prefill(req)
            self._write_slot(slot, jax.tree.map(lambda a: a, cache))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            req.tokens_generated += 1
            if req.first_token_time is None:
                req.first_token_time = now
        req.state = RequestState.RUNNING
        self.slots[slot] = _Slot(req, tok)
        return True

    def preempt_one_batch(self, now: float) -> Optional[Request]:
        """Evict the most recently admitted batch request (KV to host)."""
        for i in reversed(range(self.max_slots)):
            s = self.slots[i]
            if s.active and s.request.request_type == RequestType.BATCH:
                req = s.request
                req.saved_kv = self._read_slot(i)
                req.state = RequestState.PREEMPTED
                req.preemptions += 1
                self.slots[i] = _Slot()
                return req
        return None

    # ------------------------------------------------------------ step
    def step(self) -> StepStats:
        now = self.clock()
        stats = StepStats(now=now, n_active=0, new_tokens=0)

        # 1. admit (interactive first — zero-queuing), preempting batch
        #    requests on a full instance if an interactive request waits.
        self.waiting = deque(sorted(
            self.waiting, key=lambda r: (not r.is_interactive, r.arrival_time)))
        while self.waiting and self.n_active < self.max_batch_size:
            req = self.waiting[0]
            if not self._admit(req, now):
                break
            self.waiting.popleft()
        if self.waiting and self.waiting[0].is_interactive and \
                self.n_active >= self.max_batch_size:
            victim = self.preempt_one_batch(now)
            if victim is not None:
                stats.preempted.append(victim)
                self._admit(self.waiting.popleft(), now)

        active_idx = [i for i, s in enumerate(self.slots) if s.active]
        stats.n_active = len(active_idx)
        if not active_idx:
            self._last_step_t = now
            return stats

        # 2. one decode iteration over the whole slot pool
        tokens = jnp.stack([
            s.token[0] if s.active else jnp.zeros((), jnp.int32)
            for s in self.slots])[:, None]
        logits, self.pool = self._decode(self.params, tokens, self.pool)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t_end = self.clock()
        itl = (t_end - self._last_step_t) if self._last_step_t else (t_end - now)
        self._last_step_t = t_end
        stats.itl = itl

        # 3. bookkeeping: ITL samples, finishes
        for i in active_idx:
            s = self.slots[i]
            req = s.request
            req.itl_samples.append(itl)
            req.tokens_generated += 1
            stats.new_tokens += 1
            if req.first_token_time is None:
                req.first_token_time = t_end
            if req.tokens_generated >= req.output_len or \
                    int(self.pool["pos"][i]) >= self.max_len - 1:
                req.state = RequestState.FINISHED
                req.finish_time = t_end
                stats.finished.append(req)
                self.slots[i] = _Slot()
            else:
                s.token = next_tok[i:i + 1]

        self._window.append((t_end, stats.new_tokens))
        stats.throughput = self.throughput()
        return stats
