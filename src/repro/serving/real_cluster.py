"""Real-plane serving cluster: the SAME ChironController that drives the
simulator drives actual JAX engines here (duck-typed to the SimInstance /
SimCluster protocol the controllers use). This is Chiron in its deployable
form — on CPU with reduced models in this container, on TPU meshes with
the full configs via the identical code path.

Also implements Llumnix-style cross-instance request migration on top of
the engine's slot read/restore (used for rebalancing mixed instances).
"""
from __future__ import annotations

# mirror-sync: module ok(real engine has no RequestLedger/InstancePlane)
# The columnar mirrors exist only in the simulated data plane.
import itertools
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.local_autoscaler import LocalAutoscaler
from repro.core.backpressure import LocalMetrics
from repro.serving.engine import Engine, StepStats
from repro.serving.request import Request, RequestState, RequestType
from repro.sim.cluster import SLOW_SUSPECT_RATIO, InstanceState, InstanceType
from repro.sim.perf_model import PerfModel

_inst_ids = itertools.count(1000)


class RealInstance:
    """Engine + instance type + local autoscaler; SimInstance-compatible."""

    def __init__(self, cfg: ModelConfig, itype: InstanceType, now: float, *,
                 max_slots: int = 6, max_len: int = 128,
                 local_autoscaler: Optional[LocalAutoscaler] = None,
                 static_batch: Optional[int] = None,
                 load_time: float = 0.0, params=None, seed: int = 0,
                 model: str = "llama-8b"):
        self.id = next(_inst_ids)
        self.cfg = cfg
        self.model = model           # served model (multi-model routing key)
        self.itype = itype
        self.state = InstanceState.LOADING
        self.ready_time = now + load_time
        self.local = local_autoscaler
        self.static_batch = static_batch
        self.engine = Engine(cfg, key=jax.random.PRNGKey(seed),
                             params=params, max_slots=max_slots,
                             max_len=max_len,
                             max_batch_size=(local_autoscaler.max_batch_size
                                             if local_autoscaler
                                             else static_batch or max_slots),
                             dtype=jnp.float32)
        self._last_stats: Optional[StepStats] = None
        # slow-node health protocol (SimInstance parity): the routing
        # layer reads ``suspected_slow``; a real deployment would EWMA
        # observed step time against a per-hardware baseline, but the
        # reduced CPU engines here have no meaningful expected-ITL model,
        # so real instances never self-report degradation
        self.health_ewma = 1.0

    def update_health(self, alpha: float = 0.5) -> None:
        pass

    @property
    def suspected_slow(self) -> bool:
        return self.health_ewma > SLOW_SUSPECT_RATIO

    # ------------------------------------------------ protocol: state
    def activate_if_ready(self, now: float) -> None:
        # Real engine: no simulated-float drift between ready_time and now.
        # repro-lint: ok(DET205, both times come from one monotonic clock)
        if self.state == InstanceState.LOADING and now >= self.ready_time:
            self.state = InstanceState.ACTIVE

    @property
    def active(self) -> bool:
        return self.state == InstanceState.ACTIVE

    @property
    def max_batch_size(self) -> int:
        if self.local is not None:
            return self.local.max_batch_size
        return self.static_batch or self.engine.max_slots

    @property
    def n_running(self) -> int:
        return self.engine.n_active

    @property
    def running(self):
        """SimInstance-protocol: items expose ``.request``."""
        return [s for s in self.engine.slots if s.active]

    def slot_utilization(self) -> float:
        return self.engine.n_active / max(self.max_batch_size, 1)

    def kv_utilization(self) -> float:
        return self.slot_utilization()

    def runs_interactive(self) -> bool:
        return any(s.request.is_interactive for s in self.running)

    def n_running_batch(self) -> int:
        return sum(1 for s in self.running
                   if not s.request.is_interactive)

    def min_itl_slo(self) -> float:
        return min((s.request.slo.itl for s in self.running),
                   default=float("inf"))

    def spare_throughput(self) -> float:
        spare = self.max_batch_size - self.n_running
        thr = self.engine.throughput()
        if spare <= 0 or self.n_running == 0 or thr <= 0:
            return 0.0
        return thr * spare / self.n_running

    # ------------------------------------------------ protocol: intake
    def can_admit(self, req: Request) -> bool:
        if not self.active or self.n_running >= self.max_batch_size:
            return False
        if req.model != self.model:
            return False            # never serve a wrong-model request
        return self.engine._free_slot() is not None

    def admit(self, req: Request, now: float) -> None:
        self.engine.submit(req)

    def evict_one_batch(self, now: float) -> Optional[Request]:
        return self.engine.preempt_one_batch(now)

    # ------------------------------------------------ execution
    def step(self, now: float) -> StepStats:
        stats = self.engine.step()
        self._last_stats = stats
        return stats

    def update_local_autoscaler(self) -> None:
        if self.local is None or self._last_stats is None or \
                self._last_stats.n_active == 0:
            return
        self.local.update(LocalMetrics(
            observed_itl=self._last_stats.itl,
            throughput=max(self._last_stats.throughput, 1e-6),
            itl_slo=self.min_itl_slo(),
            n_active=self._last_stats.n_active,
            batch_size=self.local.max_batch_size))
        self.engine.set_max_batch_size(self.local.max_batch_size)

    # ------------------------------------------------ migration
    def migrate_out(self, req_id: int) -> Optional[Request]:
        """Remove a running request, carrying its KV state (Llumnix-style
        live migration)."""
        for i, s in enumerate(self.engine.slots):
            if s.active and s.request.req_id == req_id:
                req = s.request
                req.saved_kv = self.engine._read_slot(i)
                req.state = RequestState.PREEMPTED
                self.engine.slots[i] = type(s)()
                return req
        return None


class RealCluster:
    """SimCluster-compatible manager over real engines.

    Instances share one set of initialized params per model config (real
    clusters load the same checkpoint); `load_time` models bring-up delay
    in the driver's clock without sleeping.
    """

    def __init__(self, cfg: ModelConfig, *, max_chips: int = 64,
                 chips_per_instance: int = 1, max_slots: int = 6,
                 max_len: int = 128, load_time: float = 0.0):
        self.cfg = cfg
        self.max_chips = max_chips
        self.chips_per_instance = chips_per_instance
        self.max_slots = max_slots
        self.max_len = max_len
        self.load_time = load_time
        self.instances: List[RealInstance] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.chip_seconds = 0.0
        self.peak_chips = 0
        model_seed = jax.random.PRNGKey(0)
        from repro.models import Model
        self._shared_params = Model(cfg).init(model_seed, dtype=jnp.float32)
        # planning estimate for Algorithm 2's Theta (perf model of the
        # full-size family member; production would calibrate online)
        self.perf_factory: Callable[[str], PerfModel] = \
            lambda name: PerfModel(name if name in
                                   ("llama-8b", "llama-70b") else "llama-8b")

    # ------------------------------------------------ protocol
    def by_type(self, itype: InstanceType) -> List[RealInstance]:
        return [i for i in self.instances if i.itype == itype]

    def by_model(self, model: str, itype: InstanceType) -> List[RealInstance]:
        return [i for i in self.instances
                if i.itype == itype and i.model == model]

    def instances_of(self, model: str) -> List[RealInstance]:
        return [i for i in self.instances if i.model == model]

    def active_instances(self) -> List[RealInstance]:
        return [i for i in self.instances if i.active]

    def used_chips(self) -> int:
        return len(self.instances) * self.chips_per_instance

    def provision(self, model: str, itype: InstanceType, now: float,
                  **inst_kw) -> Optional[RealInstance]:
        if self.used_chips() + self.chips_per_instance > self.max_chips:
            return None
        inst = RealInstance(self.cfg, itype, now, max_slots=self.max_slots,
                            max_len=self.max_len,
                            load_time=self.load_time,
                            params=self._shared_params, model=model,
                            **inst_kw)
        self.instances.append(inst)
        self.scale_ups += 1
        self.peak_chips = max(self.peak_chips, self.used_chips())
        return inst

    def retire(self, inst: RealInstance) -> List[Request]:
        displaced = []
        for i, s in enumerate(inst.engine.slots):
            if s.active:
                r = inst.migrate_out(s.request.req_id)
                if r is not None:
                    displaced.append(r)
        displaced.extend(inst.engine.waiting)
        inst.engine.waiting.clear()
        inst.state = InstanceState.RETIRED
        self.instances.remove(inst)
        self.scale_downs += 1
        return displaced

    def tick_accounting(self, dt: float) -> None:
        self.chip_seconds += self.used_chips() * dt

    # ------------------------------------------------ migration
    def migrate(self, req_id: int, src: RealInstance,
                dst: RealInstance) -> bool:
        """Move a running request between instances, KV state and all."""
        if not dst.active or dst.engine._free_slot() is None:
            return False
        req = src.migrate_out(req_id)
        if req is None:
            return False
        dst.engine.submit(req)
        return True

    def rebalance(self, now: float, threshold: float = 0.9) -> int:
        """Move batch requests off crowded mixed instances onto idle ones
        (Llumnix-style defragmentation); returns migrations performed."""
        moved = 0
        insts = self.active_instances()
        for src in insts:
            if src.slot_utilization() < threshold:
                continue
            dsts = [d for d in insts
                    if d is not src and d.slot_utilization() < 0.5
                    and d.engine._free_slot() is not None]
            if not dsts:
                continue
            victims = [s.request for s in src.running
                       if s.request.request_type == RequestType.BATCH]
            if not victims:
                continue
            dst = min(dsts, key=lambda d: d.slot_utilization())
            if self.migrate(victims[-1].req_id, src, dst):
                moved += 1
        return moved


def serve_forever(requests: List[Request], controller, cluster: RealCluster,
                  *, max_steps: int = 2000, control_every: int = 5,
                  clock=None) -> Dict:
    """Drive a real cluster: arrivals -> controller.route (shared with the
    sim) -> engine steps -> local autoscaler updates."""
    from repro.serving.global_queue import GlobalQueue
    clock = clock or time.monotonic
    t0 = clock()
    queue = GlobalQueue()
    pending = sorted(requests, key=lambda r: r.arrival_time)
    pi = 0
    steps = 0
    while steps < max_steps:
        now = clock() - t0
        while pi < len(pending) and pending[pi].arrival_time <= now:
            queue.push(pending[pi])
            pi += 1
        for inst in cluster.instances:
            inst.activate_if_ready(now)
        if steps % control_every == 0:
            controller.control(cluster, queue, now)
            for inst in cluster.active_instances():
                inst.update_local_autoscaler()
        controller.route(cluster, queue, now)
        for inst in cluster.active_instances():
            inst.step(now)
        cluster.tick_accounting(0.0)
        steps += 1
        if pi >= len(pending) and len(queue) == 0 and \
                all(i.n_running == 0 and i.engine.n_waiting == 0
                    for i in cluster.instances):
            break
    done = [r for r in requests if r.state == RequestState.FINISHED]
    return {"steps": steps, "finished": len(done), "total": len(requests),
            "wall_s": clock() - t0,
            "scale_ups": cluster.scale_ups,
            "scale_downs": cluster.scale_downs}
