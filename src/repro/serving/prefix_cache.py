"""Prompt-prefix KV cache (paper Fig. 11's "prefix caching" knob, real).

Stores finished prompts' KV caches keyed by their token sequence; a new
request reuses the longest stored prefix and prefills only the suffix
(via the model layer's ``past_cache`` chunked-prefill path). LRU-bounded.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Optional, Tuple

import jax


class PrefixCache:
    def __init__(self, max_entries: int = 16):
        self.max_entries = max_entries
        self._store: "OrderedDict[Tuple[int, ...], Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0

    @staticmethod
    def _common_prefix(a: Tuple[int, ...], b: Tuple[int, ...]) -> int:
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n

    @staticmethod
    def _slice_cache(cache, n: int):
        """Truncate a transformer-family cache to its first n positions."""
        import jax.numpy as jnp
        return {
            "k": cache["k"][:, :, :n],
            "v": cache["v"][:, :, :n],
            "pos": jnp.full_like(cache["pos"], n),
            "slot_pos": cache["slot_pos"][:, :n],
        }

    def lookup(self, tokens, min_tokens: int = 1) -> Tuple[Optional[Any], int]:
        """Longest common prefix between ``tokens`` and any stored prompt
        (leaving at least one token to prefill); the stored cache is sliced
        to the shared length. Returns (cache, n_reused)."""
        key = tuple(int(t) for t in tokens)
        best_key, best_n = None, 0
        for k in self._store:
            n = min(self._common_prefix(k, key), len(key) - 1)
            if n > best_n:
                best_key, best_n = k, n
        if best_key is None or best_n < min_tokens:
            self.misses += 1
            return None, 0
        self._store.move_to_end(best_key)
        self.hits += 1
        self.hit_tokens += best_n
        cache = self._store[best_key]
        if best_n < len(best_key):
            cache = self._slice_cache(cache, best_n)
        return cache, best_n

    def store(self, tokens, cache) -> None:
        key = tuple(int(t) for t in tokens)
        if not key:
            return
        self._store[key] = jax.tree.map(lambda a: a, cache)
        self._store.move_to_end(key)
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)

    def __len__(self) -> int:
        return len(self._store)
