"""Granite-8B (code): llama-arch dense GQA. [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    norm="rmsnorm",
    ffn="swiglu",
    source="arXiv:2405.04324",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512)
