"""InternVL2-2B: InternLM2 language backbone; InternViT vision encoder +
projector are a stub providing precomputed patch embeddings. [arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    n_vision_tokens=256,   # one 448x448 tile -> 256 patch embeddings
    norm="rmsnorm",
    ffn="swiglu",
    source="arXiv:2404.16821",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512, n_vision_tokens=16)
