"""Model/architecture configuration for the repro framework.

One ``ModelConfig`` describes everything the model layer, serving runtime,
launcher and dry-run need to know about an architecture. Every assigned
architecture gets its own module in this package exporting ``CONFIG`` (the
exact assigned spec) and ``smoke_config()`` (a reduced same-family variant for
CPU smoke tests: <=2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    n_shared_experts: int = 0     # always-on shared experts
    experts_per_token: int = 0    # top-k
    d_ff: int = 0                 # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01  # load-balance loss weight


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 0            # N, the SSM state size per head
    head_dim: int = 64            # P, channels per SSM head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256         # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free layers
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparametric
    ffn: str = "swiglu"           # swiglu | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hybrid (zamba2-style): a single shared attention block applied every
    # `attn_every` backbone layers.
    attn_every: int = 0
    # encoder-decoder (whisper-style backbone)
    n_enc_layers: int = 0
    enc_seq: int = 0              # number of (stubbed) frame embeddings
    # vlm: number of (stubbed) vision patch embeddings prepended to the text
    n_vision_tokens: int = 0
    # long-context: sliding-window attention (0 = full causal attention).
    # Beyond-paper option used to run long_500k on dense families.
    sliding_window: int = 0
    dtype: str = "bfloat16"
    source: str = ""              # citation for the assigned config

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm.state_dim else 0

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is sub-quadratic / O(window) in context."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (used by roofline + perf model) ----
    def param_count(self) -> int:
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d            # wq, wk, wv, wo
        if self.ffn == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        n = 0
        if self.arch_type in ("dense", "vlm"):
            n = self.n_layers * (attn + mlp)
        elif self.arch_type == "moe":
            m = self.moe
            expert = (3 * d * m.d_ff) if self.ffn == "swiglu" else (2 * d * m.d_ff)
            per_layer = attn + (m.n_experts + m.n_shared_experts) * expert + d * m.n_experts
            n = self.n_layers * per_layer
        elif self.arch_type == "ssm":
            n = self.n_layers * self._ssm_layer_params()
        elif self.arch_type == "hybrid":
            n = self.n_layers * self._ssm_layer_params()
            # one shared attention block (attn + mlp), reused
            n += attn + mlp
        elif self.arch_type == "audio":
            n = (self.n_layers + self.n_enc_layers) * (attn + mlp)
            n += self.n_layers * (attn)               # cross-attention
        emb = V * d * (1 if self.tie_embeddings else 2)
        return n + emb

    def _ssm_layer_params(self) -> int:
        # B/C are per-group (single group), not per-head — matches
        # models/ssm.init_mamba_layer exactly.
        d, di, N = self.d_model, self.d_inner, self.ssm.state_dim
        H = self.n_ssm_heads
        in_proj = d * (2 * di + 2 * N + H)            # z, x, B, C, dt
        conv = (di + 2 * N) * self.ssm.conv_width
        out = di * d
        return in_proj + conv + out + 3 * H + di + d  # + A,D,dt_bias,norms

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k routed only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        m = self.moe
        expert = (3 * d * m.d_ff) if self.ffn == "swiglu" else (2 * d * m.d_ff)
        inactive = (m.n_experts - m.experts_per_token) * expert
        return self.param_count() - self.n_layers * inactive


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
