"""Zamba2-2.7B: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    attn_every=6,   # shared attention block applied every 6 mamba layers
    norm="rmsnorm",
    ffn="swiglu",
    # at 500k-token decode the shared attention blocks run sliding-window so
    # hybrid state stays O(window); mamba state is O(1) regardless.
    sliding_window=4096,
    source="arXiv:2411.15242",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=256, vocab_size=512, attn_every=2,
                        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2,
                                      conv_width=4, chunk_size=32),
                        sliding_window=0)
