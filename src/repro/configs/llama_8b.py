"""Llama-3.1-8B — the paper's "small model" used in Chiron's own evaluation."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    norm="rmsnorm",
    ffn="swiglu",
    rope_theta=500000.0,
    source="arXiv:2302.13971 (paper's evaluation model)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512)
