"""DeepSeekMoE-16B: 2 shared + 64 routed top-6, fine-grained. [arXiv:2401.06066]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(n_experts=64, n_shared_experts=2, experts_per_token=6,
                  d_ff=1408, capacity_factor=1.25),
    norm="rmsnorm",
    ffn="swiglu",
    source="arXiv:2401.06066",
)


def smoke_config() -> ModelConfig:
    # no-drop capacity factor: see qwen2_moe_a2_7b.smoke_config
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=64, vocab_size=512,
                        moe=MoEConfig(n_experts=4, n_shared_experts=1,
                                      experts_per_token=2, d_ff=64,
                                      capacity_factor=8.0))
