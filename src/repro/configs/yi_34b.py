"""Yi-34B: llama-arch dense GQA. [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    arch_type="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    norm="rmsnorm",
    ffn="swiglu",
    source="arXiv:2403.04652",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=224, n_heads=7, n_kv_heads=1,
                        d_ff=448, vocab_size=512)
