"""OLMo-1B: dense, non-parametric LayerNorm. [arXiv:2402.00838]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    arch_type="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric",
    ffn="swiglu",
    source="arXiv:2402.00838",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=256, vocab_size=512)
