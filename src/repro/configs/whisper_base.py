"""Whisper-base: enc-dec transformer backbone; conv/mel frontend is a stub
providing precomputed frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    n_layers=6,           # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    enc_seq=1500,         # 30 s of audio at 50 frames/s (post-conv stub)
    norm="layernorm",
    ffn="gelu",
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
                        n_kv_heads=4, d_ff=256, vocab_size=512, enc_seq=32)
