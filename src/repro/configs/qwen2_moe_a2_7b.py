"""Qwen1.5-MoE-A2.7B: 4 shared + 60 routed top-4. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(n_experts=60, n_shared_experts=4, experts_per_token=4,
                  d_ff=1408, capacity_factor=1.25),
    norm="rmsnorm",
    ffn="swiglu",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)


def smoke_config() -> ModelConfig:
    # capacity_factor high enough that no token is ever dropped: makes the
    # batched-forward and one-token-decode paths exactly equivalent, which
    # the decode-consistency tests rely on (production keeps 1.25 + drops).
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=64, vocab_size=512,
                        moe=MoEConfig(n_experts=4, n_shared_experts=1,
                                      experts_per_token=2, d_ff=64,
                                      capacity_factor=8.0))
