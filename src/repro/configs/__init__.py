"""Architecture config registry.

``get_config("yi-34b")`` returns the exact assigned full config;
``get_smoke_config("yi-34b")`` returns the reduced same-family variant.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, MoEConfig, SSMConfig

# arch id -> module name
_ARCH_MODULES = {
    "olmo-1b": "olmo_1b",
    "granite-8b": "granite_8b",
    "zamba2-2.7b": "zamba2_2_7b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "yi-34b": "yi_34b",
    "mamba2-1.3b": "mamba2_1_3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-base": "whisper_base",
    "internvl2-2b": "internvl2_2b",
    # the paper's own evaluation models (used by the simulator / perf model)
    "llama-8b": "llama_8b",
    "llama-70b": "llama_70b",
}

ASSIGNED_ARCHS: List[str] = [
    "olmo-1b", "granite-8b", "zamba2-2.7b", "phi3-mini-3.8b", "yi-34b",
    "mamba2-1.3b", "qwen2-moe-a2.7b", "deepseek-moe-16b", "whisper-base",
    "internvl2-2b",
]


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def list_archs() -> List[str]:
    return list(ASSIGNED_ARCHS)


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "InputShape", "INPUT_SHAPES",
    "ASSIGNED_ARCHS", "get_config", "get_smoke_config", "list_archs",
]
