"""Mamba2-1.3B: attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    norm="rmsnorm",
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, vocab_size=512,
                        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2,
                                      conv_width=4, chunk_size=32))
