"""Llama-3.1-70B — the paper's "large model" used in Chiron's own evaluation."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-70b",
    arch_type="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    norm="rmsnorm",
    ffn="swiglu",
    rope_theta=500000.0,
    source="arXiv:2302.13971 (paper's evaluation model)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        d_ff=256, vocab_size=512)
