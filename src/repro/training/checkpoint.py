"""Minimal dependency-free checkpointing: pytree <-> .npz + structure file."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def save_checkpoint(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"),
             **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
    with open(os.path.join(path, "structure.json"), "w") as f:
        json.dump({"treedef": str(treedef), "n_leaves": len(leaves),
                   "meta": meta or {}}, f)


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree.flatten(like)
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert arr.shape == ref.shape, (i, arr.shape, ref.shape)
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return treedef.unflatten(out)


def checkpoint_meta(path: str) -> dict:
    with open(os.path.join(path, "structure.json")) as f:
        return json.load(f)["meta"]
