"""AdamW optimizer in pure JAX (float32 moments over any-dtype params)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def adamw_update(grads, state: AdamWState, params, *, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        pn = p.astype(jnp.float32) - lr * (mh / (jnp.sqrt(vh) + eps)
                                           + weight_decay * p.astype(jnp.float32))
        return pn.astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
