"""Queue waiting-time estimation (QLM-style; paper §5.3, Eq. 1).

W_q = sum_{i<q} O_i / Theta, with unknown output lengths O_i modelled as a
Normal(mu_o, sigma_o) fitted online from completed requests. By the CLT the
sum over q-1 requests ahead is Normal(q*mu, sqrt(q)*sigma) for any
underlying output distribution, so estimates sharpen as the queue grows
(paper Fig. 14: R^2 -> 0.99 at ~2000 queued requests).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass
class OutputLengthModel:
    """Online mean/std of completed-request output lengths.

    ``observe`` runs once per completion on the event core's hot path, so
    it only accumulates the moment sums; ``mu``/``sigma`` refresh lazily
    on read (control ticks). The values are bit-identical to eager
    recomputation — both reduce to the same ``_sum/_n`` arithmetic at the
    same observation count."""
    _n: int = 0
    _sum: float = 0.0
    _sumsq: float = 0.0
    _mu: float = 256.0              # prior before any observations
    _sigma: float = 128.0
    _stale: bool = False

    def observe(self, output_len: int) -> None:
        self._n += 1
        self._sum += output_len
        self._sumsq += output_len * output_len
        self._stale = True

    def _refresh(self) -> None:
        self._stale = False
        if self._n >= 2:
            self._mu = self._sum / self._n
            var = max(self._sumsq / self._n - self._mu ** 2, 1.0)
            self._sigma = math.sqrt(var)

    @property
    def mu(self) -> float:
        if self._stale:
            self._refresh()
        return self._mu

    @mu.setter
    def mu(self, value: float) -> None:
        self._mu = value

    @property
    def sigma(self) -> float:
        if self._stale:
            self._refresh()
        return self._sigma

    @sigma.setter
    def sigma(self, value: float) -> None:
        self._sigma = value

    @property
    def n_observed(self) -> int:
        return self._n


@dataclass
class WaitingTimeEstimator:
    """Estimates queue waiting time given per-instance token throughput.

    ``token_throughput`` is Theta in Eq. 1 — assumed constant through the
    generation due to the statistical averaging of continuous batching.
    """
    output_model: OutputLengthModel = field(default_factory=OutputLengthModel)
    quantile_z: float = 0.0         # >0 for conservative upper estimates

    def expected_tokens(self, n_requests: int) -> float:
        mean = n_requests * self.output_model.mu
        if self.quantile_z > 0 and n_requests > 0:
            mean += self.quantile_z * math.sqrt(n_requests) * self.output_model.sigma
        return mean

    def waiting_time(self, n_requests_ahead: int, token_throughput: float,
                     n_instances: int = 1) -> float:
        """Eq. 1: W_q = sum O_i / Theta across ``n_instances`` instances."""
        if n_requests_ahead <= 0:
            return 0.0
        theta = max(token_throughput * max(n_instances, 1), 1e-9)
        return self.expected_tokens(n_requests_ahead) / theta
