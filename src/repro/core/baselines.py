"""Baseline autoscalers the paper compares against (§6 Experiment Setup).

- ``LlumnixAutoscaler``: Llumnix-style — keeps average token (memory/slot)
  utilization across instances inside a configurable [low, high] band by
  adding/removing one serving instance at a time; SLO-unaware; no request
  queuing (instances are added immediately on backlog). The "tuned"
  variant is the same policy with a per-workload parameter sweep (see
  benchmarks/fig9/fig10 which sweep the band).
- ``StaticAutoscaler``: fixed instance count (ablation support).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class LlumnixAutoscaler:
    """Utilization-band autoscaler. update() returns +1 / 0 / -1 instances."""
    low: float = 0.3
    high: float = 0.8
    min_instances: int = 1
    scale_up_step: int = 1          # Llumnix adds capacity gradually (§6.2)

    def update(self, avg_utilization: float, n_instances: int,
               n_queued: int = 0) -> int:
        # queued work immediately counts as pressure (no SLO-aware queuing)
        if n_queued > 0 or avg_utilization > self.high:
            return self.scale_up_step
        if avg_utilization < self.low and n_instances > self.min_instances:
            return -1
        return 0


@dataclass
class StaticAutoscaler:
    n_instances: int = 1

    def update(self, avg_utilization: float, n_instances: int,
               n_queued: int = 0) -> int:
        return self.n_instances - n_instances


@dataclass
class UtilizationGlobalScaler:
    """Chiron's global autoscaler replaced by a pure utilization policy —
    the "Local" ablation arm in Fig. 18 (local autoscaler kept, global
    replaced)."""
    low: float = 0.3
    high: float = 0.8
    min_instances: int = 1

    def update(self, avg_utilization: float, n_instances: int,
               n_queued: int = 0) -> int:
        if avg_utilization > self.high or n_queued > 0:
            return 1
        if avg_utilization < self.low and n_instances > self.min_instances:
            return -1
        return 0
