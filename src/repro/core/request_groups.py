"""Request groups (SHEPHERD-style, via 1-D k-means on TTFT deadlines).

Queued batch requests with similar TTFT-SLO deadlines are clustered and
scheduled as a unit (FCFS within a group), which minimizes autoscaling
hysteresis (paper §2.3, Fig. 6: 20x fewer scaling actions, 2.5x throughput).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

from repro.serving.request import Request


@dataclass
class RequestGroup:
    requests: List[Request] = field(default_factory=list)
    centroid_deadline: float = 0.0

    @property
    def deadline(self) -> float:
        """Earliest TTFT-SLO deadline in the group (conservative)."""
        return min(r.deadline for r in self.requests)

    @property
    def n(self) -> int:
        return len(self.requests)

    def total_expected_tokens(self, mean_output: float) -> float:
        return self.n * mean_output

    def sorted_fcfs(self) -> List[Request]:
        return sorted(self.requests, key=lambda r: r.arrival_time)


def kmeans_1d(values: Sequence[float], k: int, iters: int = 25) -> List[int]:
    """MacQueen-style 1-D k-means; returns a cluster id per value."""
    n = len(values)
    if n == 0:
        return []
    k = max(1, min(k, n))
    vs = sorted(values)
    # init centroids at quantiles
    cents = [vs[int(i * (n - 1) / max(k - 1, 1))] for i in range(k)]
    assign = [0] * n
    for _ in range(iters):
        changed = False
        for i, v in enumerate(values):
            j = min(range(k), key=lambda c: abs(v - cents[c]))
            if assign[i] != j:
                assign[i] = j
                changed = True
        for j in range(k):
            members = [values[i] for i in range(n) if assign[i] == j]
            if members:
                cents[j] = sum(members) / len(members)
        if not changed:
            break
    return assign


def make_request_groups(requests: Sequence[Request], k: int = 0,
                        deadline_tolerance: float = 300.0) -> List[RequestGroup]:
    """Cluster queued requests by TTFT deadline.

    k=0 -> choose k from the deadline spread: one group per
    ``deadline_tolerance`` seconds of spread (min 1, max 8).
    """
    reqs = list(requests)
    if not reqs:
        return []
    if k >= len(reqs) > 0:
        # degenerate: one group per request (grouping disabled ablation)
        out = [RequestGroup([r], r.deadline) for r in reqs]
        out.sort(key=lambda g: g.deadline)
        return out
    deadlines = [r.deadline for r in reqs]
    if k <= 0:
        spread = max(deadlines) - min(deadlines)
        k = int(min(8, max(1, round(spread / deadline_tolerance))))
    if len(reqs) > 3000:
        # cluster a stride sample, then one nearest-centroid pass for all
        stride = len(reqs) // 1000
        sample = deadlines[::stride]
        sample_assign = kmeans_1d(sample, k)
        kk = max(sample_assign) + 1
        cents = [0.0] * kk
        counts = [0] * kk
        for v, a in zip(sample, sample_assign):
            cents[a] += v
            counts[a] += 1
        cents = [c / max(n, 1) for c, n in zip(cents, counts)]
        assign = [min(range(kk), key=lambda j: abs(v - cents[j]))
                  for v in deadlines]
    else:
        assign = kmeans_1d(deadlines, k)
    groups = {}
    for r, a in zip(reqs, assign):
        groups.setdefault(a, RequestGroup())
        groups[a].requests.append(r)
    out = []
    for g in groups.values():
        g.centroid_deadline = sum(r.deadline for r in g.requests) / g.n
        out.append(g)
    out.sort(key=lambda g: g.deadline)
    return out
