"""Request groups (SHEPHERD-style, via 1-D k-means on TTFT deadlines).

Queued batch requests with similar TTFT-SLO deadlines are clustered and
scheduled as a unit (FCFS within a group), which minimizes autoscaling
hysteresis (paper §2.3, Fig. 6: 20x fewer scaling actions, 2.5x throughput).

Two grouping paths:

- ``make_request_groups``: one-shot clustering of a queue snapshot
  (benchmarks, tests, the real-cluster control loop).
- ``IncrementalGrouper``: maintained online over the queue's add/remove
  stream so the control loop never re-clusters the whole queue each tick;
  greedy nearest-centroid assignment with a periodic k-means rebuild to
  bound drift.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.serving.request import Request


@dataclass
class RequestGroup:
    requests: List[Request] = field(default_factory=list)
    centroid_deadline: float = 0.0

    @property
    def deadline(self) -> float:
        """Earliest TTFT-SLO deadline in the group (conservative)."""
        return min(r.deadline for r in self.requests)

    @property
    def n(self) -> int:
        return len(self.requests)

    def total_expected_tokens(self, mean_output: float) -> float:
        return self.n * mean_output

    def sorted_fcfs(self) -> List[Request]:
        return sorted(self.requests, key=lambda r: r.arrival_time)


@dataclass
class GroupStat:
    """Lightweight (deadline, size) view of a group — all the batch
    autoscaler's BBP computation needs (Eq. 2 reads nothing else)."""
    deadline: float
    n: int


def kmeans_1d(values: Sequence[float], k: int, iters: int = 25) -> List[int]:
    """MacQueen-style 1-D k-means; returns a cluster id per value."""
    n = len(values)
    if n == 0:
        return []
    k = max(1, min(k, n))
    vs = sorted(values)
    # init centroids at quantiles
    cents = [vs[int(i * (n - 1) / max(k - 1, 1))] for i in range(k)]
    assign = [0] * n
    for _ in range(iters):
        changed = False
        for i, v in enumerate(values):
            j = min(range(k), key=lambda c: abs(v - cents[c]))
            if assign[i] != j:
                assign[i] = j
                changed = True
        for j in range(k):
            members = [values[i] for i in range(n) if assign[i] == j]
            if members:
                cents[j] = sum(members) / len(members)
        if not changed:
            break
    return assign


def auto_k(deadlines: Sequence[float], deadline_tolerance: float,
           max_groups: int = 8) -> int:
    """One group per ``deadline_tolerance`` seconds of spread (min 1)."""
    spread = max(deadlines) - min(deadlines)
    return int(min(max_groups, max(1, round(spread / deadline_tolerance))))


def cluster_deadlines(deadlines: Sequence[float], k: int) -> List[int]:
    """Cluster deadline values into ≤k groups; subsamples large inputs."""
    if len(deadlines) > 3000:
        # cluster a stride sample, then one nearest-centroid pass for all
        stride = len(deadlines) // 1000
        sample = deadlines[::stride]
        sample_assign = kmeans_1d(sample, k)
        kk = max(sample_assign) + 1
        cents = [0.0] * kk
        counts = [0] * kk
        for v, a in zip(sample, sample_assign):
            cents[a] += v
            counts[a] += 1
        cents = [c / max(n, 1) for c, n in zip(cents, counts)]
        return [min(range(kk), key=lambda j: abs(v - cents[j]))
                for v in deadlines]
    return kmeans_1d(deadlines, k)


def make_request_groups(requests: Sequence[Request], k: int = 0,
                        deadline_tolerance: float = 300.0) -> List[RequestGroup]:
    """Cluster queued requests by TTFT deadline.

    k=0  -> choose k from the deadline spread (``auto_k``).
    k>0  -> at most min(k, n) clusters; requests with identical or nearby
            deadlines still collapse into one group, so a short queue never
            degenerates into one-group-per-request (which would inflate BBP
            and scaling actions).
    k=-1 -> the explicit grouping-disabled ablation (Fig. 6): one group per
            request. Only this sentinel selects the degenerate path.
    """
    reqs = list(requests)
    if not reqs:
        return []
    if k < 0:
        # explicit ablation: one group per request
        out = [RequestGroup([r], r.deadline) for r in reqs]
        out.sort(key=lambda g: g.deadline)
        return out
    deadlines = [r.deadline for r in reqs]
    if k == 0:
        k = auto_k(deadlines, deadline_tolerance)
    k = min(k, len(reqs))
    assign = cluster_deadlines(deadlines, k)
    groups: Dict[int, RequestGroup] = {}
    for r, a in zip(reqs, assign):
        groups.setdefault(a, RequestGroup())
        groups[a].requests.append(r)
    out = []
    for g in groups.values():
        g.centroid_deadline = sum(r.deadline for r in g.requests) / g.n
        out.append(g)
    out.sort(key=lambda g: g.deadline)
    return out


class _IncGroup:
    """One maintained cluster: size/centroid aggregates plus a lazy-deleted
    min-heap over member deadlines for the conservative group deadline."""

    __slots__ = ("gid", "n", "sum_deadline", "_heap")

    def __init__(self, gid: int):
        self.gid = gid
        self.n = 0
        self.sum_deadline = 0.0
        self._heap: List[tuple] = []        # (deadline, req_id)

    @property
    def centroid(self) -> float:
        return self.sum_deadline / self.n if self.n else 0.0

    def add(self, req_id: int, deadline: float) -> None:
        self.n += 1
        self.sum_deadline += deadline
        heapq.heappush(self._heap, (deadline, req_id))

    def remove(self, deadline: float) -> None:
        self.n -= 1
        self.sum_deadline -= deadline

    def min_deadline(self, member_of: Dict[int, int]) -> float:
        while self._heap and member_of.get(self._heap[0][1]) != self.gid:
            heapq.heappop(self._heap)       # stale (departed) member
        return self._heap[0][0] if self._heap else self.centroid


class IncrementalGrouper:
    """Deadline clusters maintained over a queue's add/remove stream.

    Implements the ``GlobalQueue`` batch-listener protocol (``on_add`` /
    ``on_remove``). New requests are greedily assigned to the nearest
    centroid (a new group opens when none lies within
    ``deadline_tolerance`` and fewer than ``max_groups`` exist); a full
    k-means rebuild runs only after the membership has churned by
    ``rebuild_factor`` of its size, bounding drift at O(changes) amortized
    cost instead of a from-scratch re-cluster every control tick.
    """

    def __init__(self, k: int = 0, deadline_tolerance: float = 300.0,
                 max_groups: int = 8, rebuild_factor: float = 1.0,
                 min_rebuild_changes: int = 256):
        self.k = k
        self.deadline_tolerance = deadline_tolerance
        # a positive k bounds the greedy path too, not just rebuilds —
        # otherwise a k-configured run tracks up to max_groups clusters
        # until the first rebuild, diverging from the one-shot semantics
        self.max_groups = k if k > 0 else max_groups
        self.rebuild_factor = rebuild_factor
        self.min_rebuild_changes = min_rebuild_changes
        self._gid = itertools.count()
        self._groups: Dict[int, _IncGroup] = {}
        self._member_of: Dict[int, int] = {}    # req_id -> gid
        self._deadline: Dict[int, float] = {}   # req_id -> deadline
        self._changes = 0
        self.rebuilds = 0

    # ------------------------------------------------------- listener API
    def on_add(self, req: Request) -> None:
        d = req.deadline
        gid = self._nearest(d)
        if gid is None:
            gid = next(self._gid)
            self._groups[gid] = _IncGroup(gid)
        self._groups[gid].add(req.req_id, d)
        self._member_of[req.req_id] = gid
        self._deadline[req.req_id] = d
        self._bump()

    def on_remove(self, req: Request) -> None:
        gid = self._member_of.pop(req.req_id, None)
        if gid is None:
            return
        d = self._deadline.pop(req.req_id)
        g = self._groups[gid]
        g.remove(d)
        if g.n <= 0:
            del self._groups[gid]
        self._bump()

    # ------------------------------------------------------------ queries
    @property
    def n_members(self) -> int:
        return len(self._member_of)

    def group_stats(self) -> List[GroupStat]:
        """Current groups as (deadline, n), earliest deadline first."""
        self._maybe_rebuild()
        stats = [GroupStat(g.min_deadline(self._member_of), g.n)
                 for g in self._groups.values() if g.n > 0]
        stats.sort(key=lambda s: s.deadline)
        return stats

    # ------------------------------------------------------------ internal
    def _nearest(self, deadline: float) -> Optional[int]:
        best, best_dist = None, float("inf")
        for gid, g in self._groups.items():
            dist = abs(deadline - g.centroid)
            if dist < best_dist:
                best, best_dist = gid, dist
        if best is None:
            return None
        if best_dist > self.deadline_tolerance and \
                len(self._groups) < self.max_groups:
            return None                      # open a new group
        return best

    def _bump(self) -> None:
        self._changes += 1

    def _maybe_rebuild(self) -> None:
        threshold = max(self.min_rebuild_changes,
                        int(self.rebuild_factor * len(self._member_of)))
        if self._changes < threshold or not self._member_of:
            return
        self._changes = 0
        self.rebuilds += 1
        ids = list(self._member_of)
        deadlines = [self._deadline[i] for i in ids]
        k = self.k if self.k > 0 else auto_k(deadlines,
                                             self.deadline_tolerance,
                                             self.max_groups)
        k = min(k, len(ids))
        assign = cluster_deadlines(deadlines, k)
        self._groups.clear()
        remap: Dict[int, int] = {}
        for rid, d, a in zip(ids, deadlines, assign):
            gid = remap.get(a)
            if gid is None:
                gid = next(self._gid)
                remap[a] = gid
                self._groups[gid] = _IncGroup(gid)
            self._groups[gid].add(rid, d)
            self._member_of[rid] = gid
