"""Global autoscaler — interactive (IBP / Theta) + batch (Algorithm 2).

Interactive autoscaling (§5.2): keep the over-provisioning ratio
IBP = running_interactive / (interactive + mixed) inside [Theta-delta,
Theta+delta]; Theta comes from historical arrival spikes (tail spike 3x ->
Theta = 1/3).

Batch instance autoscaling (§5.3, Algorithm 2): group queued batch requests
by TTFT deadline, estimate each group's waiting time via QLM, add the
MINIMUM number of batch instances that makes BBP (groups past deadline)
zero; retire all batch instances when no batch work remains.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.request_groups import (GroupStat, IncrementalGrouper,
                                       RequestGroup, make_request_groups)
from repro.core.waiting_time import WaitingTimeEstimator
from repro.serving.request import Request


@dataclass
class InteractiveScalingDecision:
    delta_instances: int            # +n add (interactive+mixed), -n remove
    ibp: float


@dataclass
class InteractiveAutoscaler:
    theta: float = 1.0 / 3.0        # target over-provisioning level
    delta: float = 0.1              # hysteresis band (footnote 2)
    min_instances: int = 1

    def update(self, n_running_interactive: int, n_interactive: int,
               n_mixed: int) -> InteractiveScalingDecision:
        total = n_interactive + n_mixed
        ibp = (n_running_interactive / total) if total else 1.0
        if ibp > self.theta + self.delta:
            # instances needed so that running/total == theta
            needed = math.ceil(n_running_interactive / max(self.theta, 1e-9))
            return InteractiveScalingDecision(max(needed - total, 1), ibp)
        if ibp < self.theta - self.delta and total > self.min_instances:
            target = math.ceil(max(n_running_interactive, 1) /
                               max(self.theta, 1e-9))
            remove = min(total - max(target, self.min_instances),
                         total - self.min_instances)
            return InteractiveScalingDecision(-max(remove, 0), ibp)
        return InteractiveScalingDecision(0, ibp)


@dataclass
class BatchScalingDecision:
    add_instances: int
    retire_all: bool
    bbp_before: int
    groups: List[RequestGroup] = field(default_factory=list)
    remove_instances: int = 0           # excess instances while BBP stays 0


@dataclass
class BatchAutoscaler:
    estimator: WaitingTimeEstimator
    instance_token_throughput: float    # Theta per batch instance (tokens/s)
    max_add_per_cycle: int = 64
    group_k: int = 0                    # 0 = auto; -1 = groups disabled
                                        # (one group per request — the
                                        # hysteresis ablation of Fig. 6)
    # multi-model fleets run one BatchAutoscaler per model; when set, only
    # that model's queue lane is grouped/observed (None = whole queue)
    model: Optional[str] = None
    # Scale-down damping: an instance is only surrendered if BBP stays 0
    # with the remaining capacity derated by this factor, so a boundary
    # estimate cannot oscillate add/remove every control tick; at most one
    # instance goes per cycle, bounding the in-flight work a removal can
    # displace back into the queue.
    scale_down_derate: float = 0.8
    max_remove_per_cycle: int = 1
    # QLM waiting-time estimate for the full backlog at the last
    # ``compute_bbp`` call (NaN before any call / with no groups) — the
    # flight recorder exports it as the per-tick ``wait_est`` signal
    last_wait: float = float("nan")
    _grouper: Optional[IncrementalGrouper] = field(default=None, repr=False)
    _grouper_src: Optional[object] = field(default=None, repr=False)

    def compute_bbp(self, groups: Sequence[RequestGroup], now: float,
                    total_throughput: float) -> int:
        """BBP (Eq. 2): groups whose estimated wait blows the TTFT deadline.

        Requests ahead of group g = all requests in groups with earlier
        deadlines plus g itself (FCFS across group order).
        """
        bbp = 0
        ahead = 0
        w = float("nan")
        for g in groups:
            ahead += g.n
            w = self.estimator.waiting_time(ahead, total_throughput, 1)
            if now + w > g.deadline:
                bbp += 1
        self.last_wait = w
        return bbp

    def _iter_batch(self, queue):
        """Model-filtered batch iteration, tolerating single-model queues
        whose ``iter_batch`` takes no model argument."""
        try:
            return queue.iter_batch(self.model)
        except TypeError:
            return queue.iter_batch()

    def _groups_for(self, queued_batch) -> List[RequestGroup]:
        """Request groups for either a queue snapshot (one-shot k-means) or
        a ``GlobalQueue`` (incrementally maintained via its listener API,
        filtered to ``self.model`` when set)."""
        if callable(getattr(queued_batch, "attach_batch_listener", None)):
            if self.group_k < 0:
                # grouping-disabled ablation: one group per request
                return [GroupStat(r.deadline, 1) for r in
                        sorted(self._iter_batch(queued_batch),
                               key=lambda r: r.deadline)]
            if self._grouper is None or self._grouper_src is not queued_batch:
                self._grouper = IncrementalGrouper(k=self.group_k)
                self._grouper_src = queued_batch
                try:
                    queued_batch.attach_batch_listener(self._grouper,
                                                       model=self.model)
                except TypeError:   # legacy listener API: no model filter
                    queued_batch.attach_batch_listener(self._grouper)
            return self._grouper.group_stats()
        if hasattr(queued_batch, "iter_batch"):
            # queue-like without the listener API: re-cluster a snapshot
            # every tick (the pre-incremental behaviour)
            queued_batch = list(self._iter_batch(queued_batch))
        k = -1 if self.group_k < 0 else self.group_k
        return make_request_groups(queued_batch, k=k)

    def update(self, queued_batch, now: float, *,
               n_batch_instances: int, spare_mixed_throughput: float = 0.0,
               n_active_batch_requests: int = 0) -> BatchScalingDecision:
        """Algorithm 2 over ``queued_batch`` — a Sequence[Request] snapshot
        or a ``GlobalQueue`` (preferred in the control loop: groups are then
        maintained incrementally instead of re-clustered every tick)."""
        groups = self._groups_for(queued_batch)
        if not groups:
            self.last_wait = float("nan")
            retire = (n_active_batch_requests == 0 and n_batch_instances > 0)
            return BatchScalingDecision(0, retire, 0, [])

        def throughput_with(extra: int) -> float:
            return (n_batch_instances + extra) * self.instance_token_throughput \
                + spare_mixed_throughput

        bbp0 = self.compute_bbp(groups, now, max(throughput_with(0), 1e-9))
        dispatch = 0
        bbp = bbp0
        # Algorithm 2: keep adding instances until backpressure is 0
        while bbp > 0 and dispatch < self.max_add_per_cycle:
            dispatch += 1
            bbp = self.compute_bbp(groups, now, throughput_with(dispatch))

        # Minimality (Algorithm 2's claim): with BBP already 0 and no adds,
        # surrender instances that remain unnecessary even after derating
        # the surviving capacity — otherwise excess batch instances linger
        # at BBP = 0 while groups trickle in.
        remove = 0
        if dispatch == 0 and bbp0 == 0 and n_batch_instances > 0:
            limit = min(n_batch_instances, self.max_remove_per_cycle)
            while remove < limit and self.compute_bbp(
                    groups, now,
                    max(self.scale_down_derate * throughput_with(-(remove + 1)),
                        1e-9)) == 0:
                remove += 1
        return BatchScalingDecision(dispatch, False, bbp0, groups,
                                    remove_instances=remove)
