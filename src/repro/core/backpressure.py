"""Hierarchical backpressure metrics — the heart of Chiron (§4.1, §5.1).

Local (per serving instance):
  LBP = observed_ITL / ITL_SLO              (>1 -> ITL SLO being violated)
  TBP = throughput_prev / throughput_curr   (>1 -> batch growth stopped paying)
  local backpressure = max(LBP, TBP)

Global (cluster):
  IBP = instances_running_interactive / (interactive + mixed instances)
  BBP = #(request groups whose estimated waiting time exceeds TTFT SLO)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

_EPS = 1e-9


def latency_backpressure(observed_itl: float, itl_slo: float) -> float:
    return observed_itl / max(itl_slo, _EPS)


def throughput_backpressure(throughput_prev: Optional[float],
                            throughput_curr: float) -> float:
    """>1 when throughput dropped after the last batch-size increase."""
    if throughput_prev is None or throughput_prev <= 0:
        return 0.0
    return throughput_prev / max(throughput_curr, _EPS)


def local_backpressure(observed_itl: float, itl_slo: float,
                       throughput_prev: Optional[float],
                       throughput_curr: float) -> float:
    return max(latency_backpressure(observed_itl, itl_slo),
               throughput_backpressure(throughput_prev, throughput_curr))


def interactive_backpressure(n_running_interactive: int,
                             n_interactive_instances: int,
                             n_mixed_instances: int) -> float:
    denom = n_interactive_instances + n_mixed_instances
    if denom == 0:
        return 1.0 if n_running_interactive > 0 else 0.0
    return n_running_interactive / denom


@dataclass
class LocalMetrics:
    """What an instance reports to its local autoscaler each interval."""
    observed_itl: float        # seconds/token, mean over the interval
    throughput: float          # tokens/s over the interval
    itl_slo: float             # min ITL SLO among resident requests
    n_active: int = 0
    batch_size: int = 0
